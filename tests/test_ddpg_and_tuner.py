"""DDPG learner + Magpie tuning-loop behaviour tests."""

import numpy as np
import pytest

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.core.action_mapping import ParamSpace, ParamSpec
from repro.core.baselines import BestConfigTuner, GridSearchTuner
from repro.core.ddpg import ddpg_init, ddpg_update
from repro.core.scalarization import MetricSpec
from repro.envs import LustreSimEnv
from repro.envs.base import TuningEnvironment


def test_ddpg_update_reduces_critic_loss():
    cfg = DDPGConfig(state_dim=3, action_dim=2)
    state, (atx, ctx) = ddpg_init(__import__("jax").random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    s = rng.random((64, 3)).astype(np.float32)
    a = rng.random((64, 2)).astype(np.float32)
    r = (a[:, 0] - 0.5 * a[:, 1]).astype(np.float32)  # known value surface
    s2 = rng.random((64, 3)).astype(np.float32)
    losses = []
    for _ in range(150):
        state, m = ddpg_update(state, (s, a, r, s2), cfg, atx, ctx)
        losses.append(float(m["critic_loss"]))
    assert losses[-1] < losses[0] * 0.5


def test_agent_save_load_roundtrip(tmp_path):
    cfg = DDPGConfig(state_dim=2, action_dim=2)
    agent = MagpieAgent(cfg, seed=0)
    st = np.ones(2, np.float32) * 0.5
    for _ in range(4):
        a = agent.act(st)
        agent.observe(st, a, 0.1, st)
    agent.learn(updates=4)
    a_before = agent.act(st, explore=False)
    path = tmp_path / "agent.pkl"
    agent.save(str(path))
    agent2 = MagpieAgent(cfg, seed=99)
    agent2.load(str(path))
    a_after = agent2.act(st, explore=False)
    np.testing.assert_allclose(a_before, a_after, atol=1e-6)


class _QuadraticEnv(TuningEnvironment):
    """Deterministic toy env: objective peaks at (0.7, 0.3)."""

    def __init__(self):
        self.param_space = ParamSpace(specs=(
            ParamSpec("x", "continuous", 0.0, 1.0, default=0.0),
            ParamSpec("y", "continuous", 0.0, 1.0, default=0.0),
        ))
        self.metric_specs = {"perf": MetricSpec("perf", 0.0, 1.0)}
        self.state_metrics = ["perf"]

    def apply(self, config, eval_run=False):
        p = 1.0 - (config["x"] - 0.7) ** 2 - (config["y"] - 0.3) ** 2
        return {"perf": max(0.0, p)}

    def restart_cost(self, config, prev_config):
        return 15.0 if config != prev_config else 0.0


def test_magpie_finds_near_optimum_on_toy_env():
    env = _QuadraticEnv()
    sc = Scalarizer(weights={"perf": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig(state_dim=1, action_dim=2), seed=0)
    res = Tuner(env, sc, agent).run(30)
    assert res.best_metrics["perf"] > 0.97  # default is 0.42
    assert res.simulated_restart_seconds > 0


def test_progressive_tuning_monotone_best():
    env = _QuadraticEnv()
    sc = Scalarizer(weights={"perf": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig(state_dim=1, action_dim=2), seed=1)
    tuner = Tuner(env, sc, agent)
    r1 = tuner.run(10)
    r2 = tuner.run(10)  # resumes: history grows, best never regresses
    assert len(r2.history) == 20
    assert r2.best_metrics["perf"] >= r1.best_metrics["perf"] - 1e-9


def test_bestconfig_on_toy_env():
    env = _QuadraticEnv()
    sc = Scalarizer(weights={"perf": 1.0}, specs=env.metric_specs)
    res = BestConfigTuner(env, sc, seed=0, round_size=10).run(30)
    assert res.best_metrics["perf"] > 0.9


def test_magpie_improves_lustre_throughput():
    """End-to-end on the paper environment: noticeable gain over default."""
    env = LustreSimEnv("seq_write", seed=0)
    sc = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(
        DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim),
        seed=0)
    res = Tuner(env, sc, agent).run(30)
    assert res.gain("throughput") > 0.5  # paper: +250% on this workload


def test_grid_search_locates_simulator_optimum():
    env = LustreSimEnv("seq_write", seed=0)
    sc = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    res = GridSearchTuner(env, sc, points_per_dim=8, eval_runs=2).run()
    true_cfg, _ = env.true_optimum({"throughput": 1.0})
    assert res.best_config["stripe_count"] >= 5  # optimum is wide striping
    assert true_cfg["stripe_count"] == 6
