"""Infrastructure tests: data pipeline determinism/skip-ahead, checkpoint
atomicity + corruption detection + keep-k, trainer resume/preemption/
watchdog, gradient compression error feedback."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import configs, optim
from repro.data import TokenPipeline
from repro.models import init_params, model_defs
from repro.training import TrainConfig, Trainer, TrainerConfig, make_train_step
from repro.training.compression import topk_error_feedback
from repro.training.trainer import StragglerAbort

# Model-training infrastructure (trainer steps on real model configs,
# compile-heavy): slow lane alongside the model/sharding suites.
pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_skip_ahead():
    p = TokenPipeline(vocab_size=100, global_batch=4, seq_len=16, seed=7)
    b1 = p.batch(123)
    b2 = p.batch(123)          # same step -> identical (O(1) skip-ahead)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_pipeline_shards_disjoint_and_resharding():
    p = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=7,
                      num_shards=2, shard_index=0)
    q = p.shard(1, 2)
    a, b = p.batch(5)["tokens"], q.batch(5)["tokens"]
    assert not np.array_equal(a, b)
    assert p.local_batch == q.local_batch == 4


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(vocab_size=100, global_batch=2, seq_len=16, seed=0)
    b = p.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
            "opt_state": {"count": np.zeros((), np.int32)}}


def test_checkpoint_roundtrip_and_latest(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 10, _tree())
    ckpt.save_checkpoint(d, 20, _tree())
    assert ckpt.latest_step(d) == 20
    step, flat, _ = ckpt.restore_checkpoint(d)
    assert step == 20
    restored = ckpt.restore_into(_tree(), flat)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  _tree()["params"]["w"])


def test_checkpoint_keep_k_prunes(tmp_path):
    d = str(tmp_path)
    for s in range(6):
        ckpt.save_checkpoint(d, s, _tree(), keep=3)
    assert ckpt.list_steps(d) == [3, 4, 5]


def test_checkpoint_corruption_detected(tmp_path):
    d = str(tmp_path)
    path = ckpt.save_checkpoint(d, 1, _tree())
    # corrupt the array file
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(-200, os.SEEK_END)
        f.write(b"\x00" * 64)
    with pytest.raises(Exception):
        ckpt.restore_checkpoint(d, 1)


def test_checkpoint_stale_tmp_cleaned(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_000000001.tmp-999"))
    ckpt.save_checkpoint(d, 2, _tree())
    assert not any(".tmp-" in n for n in os.listdir(d))


# ---------------------------------------------------------------------------
# Trainer: loss goes down, resume == uninterrupted, preemption, watchdog
# ---------------------------------------------------------------------------

def _make_trainer(tmp_dir: str, total: int, ckpt_every: int = 5):
    cfg = configs.get_smoke_config("phi4-mini-3.8b")
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    tx = optim.adamw(1e-3)
    opt = tx.init(params)
    step = jax.jit(make_train_step(cfg, tx, TrainConfig()))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=4,
                         seq_len=32, seed=0)
    return Trainer(step, pipe, params, opt,
                   TrainerConfig(total_steps=total, checkpoint_every=ckpt_every,
                                 checkpoint_dir=tmp_dir, log_every=1000),
                   to_batch=lambda b: {k: jnp.asarray(v)
                                       for k, v in b.items()})


def test_trainer_loss_decreases(tmp_path):
    t = _make_trainer("", total=30)
    out = t.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_resume_equals_uninterrupted(tmp_path):
    # uninterrupted 10 steps
    t_full = _make_trainer("", total=10)
    full = t_full.run()
    # interrupted at 5 (checkpoint) then resumed to 10
    d = str(tmp_path / "ck")
    t_a = _make_trainer(d, total=5, ckpt_every=5)
    t_a.run()
    t_b = _make_trainer(d, total=10, ckpt_every=5)
    assert t_b.try_resume() and t_b.step == 5
    resumed = t_b.run()
    # deterministic pipeline + identical state -> exactly the same loss
    np.testing.assert_allclose(resumed["metrics"][-1]["loss"],
                               full["metrics"][-1]["loss"], rtol=1e-5)


def test_preemption_checkpoints_and_stops(tmp_path):
    d = str(tmp_path / "ck")
    t = _make_trainer(d, total=100)
    orig = t.train_step

    def step_and_preempt(*a):
        if t.step == 3:
            t._preempted = True      # simulate SIGTERM delivery
        return orig(*a)

    t.train_step = step_and_preempt
    out = t.run()
    assert out["preempted"] and out["step"] == 4
    assert ckpt.latest_step(d) == 4


def test_watchdog_raises_on_stragglers(tmp_path):
    t = _make_trainer(str(tmp_path / "ck"), total=100)
    t.tcfg.watchdog_warmup = 2
    t.tcfg.watchdog_limit = 2
    t.tcfg.watchdog_factor = 5.0
    orig = t.train_step
    import time as _time

    def slow_step(*a):
        if t.step >= 6:
            _time.sleep(1.0)         # injected straggler
        return orig(*a)

    t.train_step = slow_step
    with pytest.raises(StragglerAbort):
        t.run()
    assert ckpt.latest_step(str(tmp_path / "ck")) is not None


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_topk_error_feedback_conserves_signal():
    tx = topk_error_feedback(fraction=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((8, 8)), jnp.float32)}
    state = tx.init(g)
    total_sent = jnp.zeros((8, 8))
    for _ in range(30):
        sent, state = tx.update(g, state)
        total_sent = total_sent + sent["w"]
        nz = int(jnp.sum(sent["w"] != 0))
        assert nz <= 17  # ~25% of 64 + ties
    # error feedback: cumulative sent approaches cumulative true gradient
    err = jnp.max(jnp.abs(total_sent - 30 * g["w"]))
    assert float(err) < float(jnp.max(jnp.abs(g["w"]))) * 4.0


def test_compression_composes_with_adamw():
    cfg = configs.get_smoke_config("yi-9b")
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    tx = optim.chain(topk_error_feedback(0.1), optim.adamw(1e-3))
    opt = tx.init(params)
    step = jax.jit(make_train_step(cfg, tx, TrainConfig()))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=2,
                         seq_len=16, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
