"""Streaming chunked fleet runtime (core/episode.py + core/fleet.py).

Load-bearing properties:
  * chunking is pure scheduling — for any chunk size C (including C=1 and a
    ragged last chunk) the per-session decision trajectory (configs, restart
    accounting, best config) is EXACTLY the monolithic run's, on the 2-D and
    the 8-D space. Float fields: bitwise-tight (<= 4 ulps) when C equals the
    monolithic width (same compiled program), and <= 32 f32 ulps across
    DIFFERENT chunk widths — XLA CPU lowers transcendental ops (exp/tanh in
    the env surface) to different scalar/SIMD kernels at different batch
    widths, measured at <= 11 ulps on the 8-D surface and <= 3 on the 2-D
    one (the same reason the host/scan contract is ulps, not bits);
  * shape bucketing — ONE compiled episode executable serves every chunk of
    every grid shape run at the same chunk size, and ``precompile`` warms it
    so ``run`` never compiles;
  * ``memory_plan()`` predictions equal the live buffer sizes;
  * compact trace storage round-trips exactly: action indices decode to the
    host engine's configs, int32 fixed-point restarts decode to the exact
    float32 seconds;
  * the bf16 replay-storage mode is opt-in (default f32 stays bitwise) and
    computes in f32 at gather.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DDPGConfig, FleetTuner, last_fleet_run_stats
from repro.core.action_mapping import ParamSpace, ParamSpec, jax_coord_maps
from repro.core.episode import (
    RESTART_FP_MAX_SECONDS,
    _encode_restart,
    decode_restarts,
    resolve_chunk,
)
from repro.envs import LustreSimEnv, LustreSimV2

from tests.test_episode import _assert_bitwise_equal_runs


def _fleet(env_cls, chunk, seeds=(0, 1, 2, 3, 4), updates=4, warmup=3,
           workloads=("seq_write",), extra_cfg=None):
    env = env_cls("seq_write")
    cfg = extra_cfg or DDPGConfig.for_env(env, updates_per_step=updates)
    return FleetTuner.from_grid(
        list(workloads), [{"throughput": 1.0}], list(seeds),
        env_cls=env_cls, engine="scan", ddpg_config=cfg, eval_runs=1,
        warmup_steps=warmup, chunk=chunk)


# ---------------------------------------------------------------------------
# Chunked == monolithic (acceptance: C in {1, 3, N}, ragged last chunk)
# ---------------------------------------------------------------------------

def _check_chunk_equivalence(env_cls, steps=6):
    n = 5
    mono = _fleet(env_cls, None).run(steps)
    for c in (1, 3, n):  # 3 -> ragged last chunk (5 = 3 + 2)
        got = _fleet(env_cls, c).run(steps)
        stats = last_fleet_run_stats()
        assert stats["chunk"] == c and stats["sessions"] == n
        assert stats["padded_sessions"] == (1 if c == 3 else 0)
        assert len(got.results) == n  # padding sliced out of FleetResult
        # same width (c == n) shares the monolithic executable -> tight;
        # different widths compile different SIMD kernels -> a few ulps on
        # transcendental-heavy surfaces (measured <= 11; see module doc)
        maxulp = 4 if c == n else 32
        for rm, rg in zip(mono.results, got.results):
            _assert_bitwise_equal_runs(rm, rg, maxulp=maxulp)


def test_chunked_matches_monolithic_2d():
    _check_chunk_equivalence(LustreSimEnv)


def test_chunked_matches_monolithic_8d():
    _check_chunk_equivalence(LustreSimV2)


def test_overlap_staging_is_bitwise_pure_scheduling():
    """Double-buffered chunk staging (stage k+1 / drain k-1 under chunk k's
    compute) changes WHEN transfers happen, never what is computed: same
    chunk width -> same compiled program -> results are bitwise identical
    with overlap off and on (maxulp=0), including across progressive runs."""
    on, off = _fleet(LustreSimEnv, 2), _fleet(LustreSimEnv, 2)
    off.overlap = False
    for steps in (4, 3):
        r_on, r_off = on.run(steps), off.run(steps)
        assert last_fleet_run_stats()["overlap"] is False
        for a, b in zip(r_on.results, r_off.results):
            _assert_bitwise_equal_runs(a, b, maxulp=0)
    on.run(2)
    assert last_fleet_run_stats()["overlap"] is True


def test_policy_none_is_bitwise_the_default_chunked_fleet():
    """PR 7 threaded DeploymentPolicy through the chunked runtime;
    ``policy=None`` (explicit or implied) must stay maxulp=0 the
    pre-guardrail engine — same compiled program, same results."""
    env = LustreSimEnv("seq_write")
    cfg = DDPGConfig.for_env(env, updates_per_step=4)

    def grid(**kw):
        return FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [0, 1, 2],
            env_cls=LustreSimEnv, engine="scan", ddpg_config=cfg,
            eval_runs=1, warmup_steps=3, chunk=2, **kw)

    default, explicit = grid(), grid(policy=None)
    for steps in (4, 2):
        for a, b in zip(default.run(steps).results,
                        explicit.run(steps).results):
            _assert_bitwise_equal_runs(a, b, maxulp=0)
            assert a.guardrail_stats is None and b.guardrail_stats is None


def test_progressive_runs_survive_chunking():
    """Chunked fleets resume across run() calls exactly like monolithic ones
    (agent state, FIFO and noise streams stream back to host between runs)."""
    mono, chunked = _fleet(LustreSimEnv, None), _fleet(LustreSimEnv, 2)
    for steps in (3, 4):
        rm, rc = mono.run(steps), chunked.run(steps)
        for a, b in zip(rm.results, rc.results):
            _assert_bitwise_equal_runs(a, b, maxulp=32)  # cross-width run
    assert all(len(r.history) == 7 for r in rc.results)


# ---------------------------------------------------------------------------
# Shape bucketing: one executable, many grid shapes; precompile warms it
# ---------------------------------------------------------------------------

def test_one_executable_serves_two_grid_shapes():
    # distinctive cfg so this test owns a fresh episode program (the jit
    # cache is keyed on cfg; other tests' shape buckets must not count here)
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=5)
    f1 = _fleet(LustreSimEnv, 2, seeds=(0, 1, 2), extra_cfg=cfg)
    f1.run(3)
    s1 = last_fleet_run_stats()
    assert s1["num_chunks"] == 2 and s1["executable_cache_size"] == 1

    # different grid shape (2 workloads x 2 seeds), same chunk size
    f2 = _fleet(LustreSimEnv, 2, seeds=(0, 1),
                workloads=("seq_write", "file_server"), extra_cfg=cfg)
    f2.run(3)
    s2 = last_fleet_run_stats()
    assert s2["program"] is s1["program"]  # same jitted episode program
    assert s2["executable_cache_size"] == 1  # ... and ONE compiled shape


def test_precompile_means_run_never_compiles():
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=3)
    fleet = _fleet(LustreSimEnv, 2, seeds=(0, 1, 2), extra_cfg=cfg)
    fn = fleet.precompile(steps=4)
    assert fn._cache_size() == 1
    fleet.run(4)
    stats = last_fleet_run_stats()
    assert stats["program"] is fn
    assert stats["executable_cache_size"] == 1  # run reused the warm compile


def test_resolve_chunk_pads_at_most_one_chunk():
    for n in (1, 5, 64, 1000):
        for chunk in (None, 1, 3, 16, 4096):
            for ndev in (1, 2, 8):
                c = resolve_chunk(n, chunk, ndev)
                assert c >= 1
                if ndev > 1:
                    assert c % ndev == 0
                num_chunks = -(-n // c)
                assert num_chunks * c - n < c
    with pytest.raises(ValueError):
        resolve_chunk(4, 0)


# ---------------------------------------------------------------------------
# memory_plan: prediction == live allocation
# ---------------------------------------------------------------------------

def test_memory_plan_matches_live_buffers():
    fleet = _fleet(LustreSimV2, 2, seeds=(0, 1, 2))
    plan = fleet.memory_plan(steps=10)
    assert plan["matches_live"], plan
    per = plan["per_session"]
    assert per["learner_bytes"] == plan["live"]["learner_bytes_per_session"]
    assert per["replay_bytes"] == plan["live"]["replay_bytes_per_session"]
    # streaming: one chunk's device bytes < the fleet's host bytes
    assert plan["chunk_device_bytes"] < plan["fleet_host_bytes"]
    assert plan["chunk"] == 2 and plan["sessions"] == 3


def test_memory_plan_bf16_halves_replay_bytes():
    f32 = _fleet(LustreSimEnv, None, seeds=(0,)).memory_plan(steps=5)
    fleet = FleetTuner.from_grid(
        ["seq_write"], [{"throughput": 1.0}], [0], engine="scan",
        eval_runs=1, replay_dtype=jnp.bfloat16)
    bf16 = fleet.memory_plan(steps=5)
    assert bf16["matches_live"], bf16
    assert bf16["per_session"]["replay_bytes"] * 2 == \
        f32["per_session"]["replay_bytes"]


# ---------------------------------------------------------------------------
# Compact trace: exact round-trips
# ---------------------------------------------------------------------------

def test_restart_fixed_point_roundtrip_is_exact():
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        np.zeros(3, np.float32),
        rng.uniform(12.0, 20.0, 50).astype(np.float32),   # workload restarts
        rng.uniform(42.0, 50.0, 50).astype(np.float32),   # + DFS scope
        rng.uniform(5.0, 30.0, 50).astype(np.float32),    # synthetic model
        np.array([4.0, 1023.0, RESTART_FP_MAX_SECONDS], np.float32),
    ])
    fp = np.asarray(_encode_restart(jnp.asarray(vals)))
    np.testing.assert_array_equal(decode_restarts(fp), vals)


def test_action_indices_decode_to_host_configs():
    space = ParamSpace(specs=(
        ParamSpec("d", "discrete", 2, 9, default=2),
        ParamSpec("b", "boolean", default=False),
        ParamSpec("l", "log2_int", 4, 256, default=4),
        ParamSpec("c", "choice", values=(3, 7, 11, 19), default=3),
    ))
    assert space.index_dtype() == np.uint8
    maps = jax_coord_maps(space)
    rng = np.random.default_rng(1)
    actions = rng.random((64, space.dim)).astype(np.float32)
    idx = np.stack([
        np.asarray(jax.vmap(lambda a, j=j: maps[j](a)["idx"])(
            jnp.asarray(actions[:, j])))
        for j in range(space.dim)], axis=1).astype(space.index_dtype())
    assert space.configs_from_indices(idx) == space.to_configs(actions)


def test_index_dtype_scales_with_cardinality():
    wide = ParamSpace(specs=(
        ParamSpec("big", "discrete", 0, 4000, default=0),))
    assert wide.index_dtype() == np.uint16
    huge = ParamSpace(specs=(
        ParamSpec("huge", "discrete", 0, 80_000, default=0),))
    assert huge.index_dtype() == np.uint32
    with pytest.raises(ValueError):
        ParamSpace(specs=(
            ParamSpec("x", "continuous", 0.0, 1.0, default=0.0),
        )).index_dtype()


def test_index_dtype_rejects_beyond_float32_exact_integers():
    """The index trace is computed in float32 (jax_coord_maps), exact only
    to 2**24 — a knob past that boundary would silently decode to a
    NEIGHBOURING level, so it must be a loud error instead."""
    at_edge = ParamSpace(specs=(
        ParamSpec("edge", "discrete", 0, 2 ** 24, default=0),))
    assert at_edge.index_dtype() == np.uint32
    with pytest.raises(ValueError, match="2\\*\\*24"):
        ParamSpace(specs=(
            ParamSpec("over", "discrete", 0, 2 ** 24 + 1, default=0),
        )).index_dtype()


def test_300_level_space_round_trips_through_uint16_trace():
    """Regression for the uint16 band: a 300-level knob (past uint8, the
    realistic ceiling for DFS stripe/queue-depth style knobs) keeps the
    compact index trace lossless end to end — scan == host decision-wise,
    and every traced index decodes to the exact host config."""
    from repro.core import MagpieAgent, Scalarizer, Tuner
    from repro.envs import ModelEnv, SyntheticSurfaceModel
    from tests.test_episode import _assert_bitwise_equal_runs

    space = ParamSpace(specs=(
        ParamSpec("levels300", "discrete", 0, 299, default=0),
        ParamSpec("flag", "boolean", default=False),
    ))
    assert space.index_dtype() == np.uint16

    def build(engine):
        model = SyntheticSurfaceModel(space, n_metrics=3, surface_seed=13)
        env = ModelEnv(model, seed=4)
        scal = Scalarizer(weights={"m0": 1.0}, specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=2),
                            seed=4, warmup_steps=2, buffer_capacity=8)
        return Tuner(env, scal, agent, engine=engine, eval_runs=1)

    host = build("host").run(8)
    scan = build("scan").run(8)
    _assert_bitwise_equal_runs(host, scan, maxulp=4)
    assert {h.config["levels300"] for h in scan.history} == \
        {h.config["levels300"] for h in host.history}


# ---------------------------------------------------------------------------
# bf16 replay storage: opt-in, f32 compute at gather
# ---------------------------------------------------------------------------

def test_bf16_replay_mode_is_opt_in_and_runs():
    default = _fleet(LustreSimEnv, 2, seeds=(0, 1))
    assert default.agent.buffer.storage_dtype == np.dtype(jnp.float32)

    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=4)
    fleet = FleetTuner.from_grid(
        ["seq_write"], [{"throughput": 1.0}], [0, 1], engine="scan",
        ddpg_config=cfg, eval_runs=1, warmup_steps=3, chunk=2,
        replay_dtype=jnp.bfloat16)
    buf = fleet.agent.buffer
    assert buf.storage_dtype == np.dtype(jnp.bfloat16)
    res = fleet.run(6)
    assert len(res.results) == 2
    (s, a, r, s2), _ = buf.storage()
    assert all(np.dtype(x.dtype) == np.dtype(jnp.bfloat16)
               for x in (s, a, r, s2))
    assert len(buf) > 0
    keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
    batch = buf.sample(keys, batch_size=4)
    assert all(x.dtype == jnp.float32 for x in batch)  # f32 at gather
    for res_i in res.results:
        assert np.isfinite([h.objective for h in res_i.history]).all()
