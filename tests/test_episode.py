"""Episode-engine tests: pure-JAX env models, the ModelEnv adapter, and the
fused whole-episode ``lax.scan`` engine.

Load-bearing properties:
  * the fused ``run_episode_scan`` path (``Tuner(engine="scan")``) is
    trajectory-equal to the host-loop ``Tuner`` driving the same pure model
    through the ``ModelEnv`` adapter — decision trajectory (configs, restart
    accounting, best config) exactly, float fields to within a few float32
    ulps of XLA CPU cross-program codegen variance — on the paper's 2-D
    space, the 8-knob V2 space, and (hypothesis) random mixed-kind
    quantized spaces with random step counts;
  * ``ModelEnv.apply_batch`` (the baselines' probe-batch fast path) is
    bitwise the sequential applies;
  * the pure Lustre model's noise-free surface matches the calibrated numpy
    surface to float32 accuracy;
  * ``evaluate_config`` sums-then-divides (regression: per-run division
    drifted), and the evaluation path keeps fleet-of-1 parity.
"""

import numpy as np
import pytest

from repro.core import (
    DDPGConfig,
    MagpieAgent,
    Scalarizer,
    Tuner,
    evaluate_config,
)
from repro.core.action_mapping import ParamSpace, ParamSpec
from repro.envs import LustreSimEnv, LustreSimV2, ModelEnv, SyntheticSurfaceModel


def _tuner(env_cls, engine, seed=3, steps_updates=6, warmup=4, workload="seq_write"):
    env = env_cls(workload, seed=seed).to_model_env()
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(
        DDPGConfig.for_env(env, updates_per_step=steps_updates),
        seed=seed, warmup_steps=warmup)
    return Tuner(env, scal, agent, engine=engine, eval_runs=2)


def _ulp_equal(a: float, b: float, maxulp: int) -> bool:
    if a == b:
        return True
    np.testing.assert_array_max_ulp(np.float32(a), np.float32(b),
                                    maxulp=maxulp)
    return True


def _assert_bitwise_equal_runs(host, scan, maxulp: int = 4):
    """Histories and outcomes identical (timings excluded).

    The engines run the same float32 arithmetic step for step, but XLA CPU
    compiles the host loop's standalone dispatches and the fused episode as
    different programs, and its codegen (FMA/vectorization choices) is
    context-dependent — so cancellation-prone values can land a few ulps
    apart (observed ≤ 2 ULP; the fusion-island barriers in ``core.episode``
    keep it that tight). The contract pinned here: the DECISION trajectory —
    every config, the restart accounting, the best config — is exactly
    equal, and every float field agrees to ``maxulp`` float32 ulps."""
    assert len(host.history) == len(scan.history)
    for h, s in zip(host.history, scan.history):
        assert h.config == s.config
        assert _ulp_equal(h.restart_seconds, s.restart_seconds, maxulp)
        assert set(h.metrics) == set(s.metrics)
        for k in h.metrics:
            assert _ulp_equal(h.metrics[k], s.metrics[k], maxulp), k
        assert _ulp_equal(h.objective, s.objective, maxulp)
        assert _ulp_equal(h.reward, s.reward, maxulp)
    assert host.best_config == scan.best_config
    assert _ulp_equal(host.best_objective, scan.best_objective, maxulp)
    for k in host.best_metrics:
        assert _ulp_equal(host.best_metrics[k], scan.best_metrics[k], maxulp)
    assert host.default_metrics == scan.default_metrics  # pre-episode: exact


# ---------------------------------------------------------------------------
# Scan engine == host loop, bitwise (acceptance: 2-D and 8-D)
# ---------------------------------------------------------------------------

def test_scan_engine_matches_host_loop_learn_free():
    """Learning-free episodes (pure act → env → reward sweeps, the §III-E
    evaluation mode) hold the same equivalence contract on both spaces."""
    for env_cls in (LustreSimEnv, LustreSimV2):
        host = _tuner(env_cls, "host").run(12, learn=False)
        scan = _tuner(env_cls, "scan").run(12, learn=False)
        _assert_bitwise_equal_runs(host, scan, maxulp=4)


def test_scan_engine_matches_host_loop_2d():
    host = _tuner(LustreSimEnv, "host").run(9)
    scan = _tuner(LustreSimEnv, "scan").run(9)
    _assert_bitwise_equal_runs(host, scan, maxulp=4)


def test_scan_engine_matches_host_loop_8d():
    host = _tuner(LustreSimV2, "host").run(9)
    scan = _tuner(LustreSimV2, "scan").run(9)
    _assert_bitwise_equal_runs(host, scan, maxulp=4)


def test_scan_engine_progressive_runs_match_host():
    """Engines stay aligned across repeated run() calls (Fig. 7 progressive
    tuning): agent, buffer, noise and env key chain all resume identically."""
    th = _tuner(LustreSimEnv, "host", seed=7)
    ts = _tuner(LustreSimEnv, "scan", seed=7)
    for steps in (3, 5):
        _assert_bitwise_equal_runs(th.run(steps), ts.run(steps), maxulp=4)
    assert len(ts.history) == 8


def test_scan_engine_restart_accounting_matches_host():
    th, ts = _tuner(LustreSimV2, "host"), _tuner(LustreSimV2, "scan")
    th.run(8), ts.run(8)
    sh, ss = th.env.restart_summary(), ts.env.restart_summary()
    for scope in ("workload", "dfs"):
        assert sh[scope]["count"] == ss[scope]["count"]
        assert np.isclose(sh[scope]["seconds"], ss[scope]["seconds"])
    assert np.isclose(th.simulated_restart_seconds,
                      ts.simulated_restart_seconds)


def test_scan_engine_requires_model_env():
    env = LustreSimEnv("seq_write", seed=0)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    with pytest.raises(ValueError, match="pure-model"):
        Tuner(env, scal, MagpieAgent(DDPGConfig.for_env(env)), engine="scan")
    with pytest.raises(ValueError, match="engine"):
        Tuner(env, scal, MagpieAgent(DDPGConfig.for_env(env)), engine="warp")


def test_model_env_rejects_continuous_spaces():
    space = ParamSpace(specs=(
        ParamSpec("x", "continuous", 0.0, 1.0, default=0.0),))
    with pytest.raises(ValueError, match="host"):
        SyntheticSurfaceModel(space)  # jax_coord_maps refuses continuous

    class _FakeModel:
        param_space = space

    with pytest.raises(ValueError, match="quantized"):
        ModelEnv(_FakeModel())


# ---------------------------------------------------------------------------
# Hypothesis: random quantized spaces, random step counts
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs it (requirements.txt); skip locally without
    HAVE_HYPOTHESIS = False


def _random_space(rng: np.random.Generator, dim: int) -> ParamSpace:
    kinds = ["discrete", "boolean", "log2_int", "choice", "categorical"]
    specs = []
    for j in range(dim):
        kind = kinds[int(rng.integers(len(kinds)))]
        if kind == "discrete":
            lo = int(rng.integers(0, 4))
            specs.append(ParamSpec(f"p{j}", "discrete", lo,
                                   lo + int(rng.integers(1, 7)), default=lo))
        elif kind == "boolean":
            specs.append(ParamSpec(f"p{j}", "boolean", default=bool(j % 2)))
        elif kind == "log2_int":
            e_lo = int(rng.integers(0, 4))
            e_hi = e_lo + int(rng.integers(1, 6))
            specs.append(ParamSpec(f"p{j}", "log2_int", 2 ** e_lo, 2 ** e_hi,
                                   default=2 ** e_lo))
        else:
            k = int(rng.integers(2, 7))
            values = tuple(sorted(rng.choice(
                np.arange(1, 64), size=k, replace=False).tolist()))
            specs.append(ParamSpec(f"p{j}", kind, values=values,
                                   default=values[0]))
    return ParamSpace(specs=tuple(specs))


def _check_random_space_parity(dim, steps, space_seed, seed):
    rng = np.random.default_rng(space_seed)
    space = _random_space(rng, dim)
    dfs = tuple(n for n in space.names if rng.uniform() < 0.3)

    def build(engine):
        model = SyntheticSurfaceModel(space, n_metrics=3,
                                      surface_seed=space_seed, dfs_scope=dfs)
        env = ModelEnv(model, seed=seed)
        scal = Scalarizer(weights={"m0": 0.7, "m2": 0.3},
                          specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=2),
                            seed=seed, warmup_steps=2, buffer_capacity=8)
        return Tuner(env, scal, agent, engine=engine, eval_runs=1)

    _assert_bitwise_equal_runs(build("host").run(steps),
                               build("scan").run(steps), maxulp=4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=6, deadline=None)
    @given(data=st.data())
    def test_scan_engine_bitwise_on_random_spaces(data):
        """run_episode_scan == host-loop Tuner over random mixed-kind spaces
        (2-D and 8-D) and random step counts, bit for bit."""
        _check_random_space_parity(
            dim=data.draw(st.sampled_from([2, 8]), label="dim"),
            steps=data.draw(st.integers(1, 6), label="steps"),
            space_seed=data.draw(st.integers(0, 2 ** 16), label="space_seed"),
            seed=data.draw(st.integers(0, 2 ** 16), label="seed"))
else:
    @pytest.mark.parametrize("dim,steps,space_seed,seed", [
        (2, 3, 101, 7), (8, 5, 2025, 13), (8, 1, 77, 3)])
    def test_scan_engine_bitwise_on_random_spaces(dim, steps, space_seed,
                                                  seed):
        """Fixed-seed fallback when hypothesis is unavailable — same check,
        three representative draws."""
        _check_random_space_parity(dim, steps, space_seed, seed)


# ---------------------------------------------------------------------------
# Pure model fidelity + adapter batch path
# ---------------------------------------------------------------------------

def test_lustre_model_surface_matches_numpy_to_f32():
    """The in-graph surface is the calibrated numpy surface, at float32."""
    rng = np.random.default_rng(0)
    for env_cls in (LustreSimEnv, LustreSimV2):
        for workload in ("seq_write", "file_server", "random_rw"):
            env = env_cls(workload, seed=0)
            model = env.as_model()
            configs = env.param_space.to_configs(
                rng.uniform(size=(20, env.param_space.dim)))
            for c in configs:
                ref, got = env.mean_performance(c), model.mean_performance(c)
                for k in ("throughput", "iops", "util"):
                    assert np.isclose(ref[k], got[k], rtol=1e-5), (
                        workload, k, c)


def test_model_env_apply_batch_bitwise_matches_sequential():
    e1 = LustreSimV2("seq_write", seed=4).to_model_env()
    e2 = LustreSimV2("seq_write", seed=4).to_model_env()
    rng = np.random.default_rng(1)
    configs = e1.param_space.to_configs(rng.uniform(size=(7, e1.param_space.dim)))
    batch_metrics, batch_costs = e1.apply_batch(configs)
    prev = dict(e2.param_space.default_config())
    for c, bm, bc in zip(configs, batch_metrics, batch_costs):
        m = e2.apply(c)
        cost = e2.restart_cost(c, prev)
        assert m == bm
        assert cost == float(bc)
        prev = c
    assert e1.restart_summary() == e2.restart_summary()


def test_model_env_restart_scope_attribution():
    env = LustreSimV2("seq_write", seed=0).to_model_env()
    base = env.param_space.default_config()
    env.apply(base)
    env.restart_cost(base, {})
    flipped = dict(base, checksums=not base["checksums"])  # DFS-scope knob
    env.apply(flipped)
    env.restart_cost(flipped, base)
    summary = env.restart_summary()
    assert summary["dfs"]["count"] >= 1
    assert summary["dfs"]["seconds"] >= 42.0  # 12-20 s workload + 30 s DFS


# ---------------------------------------------------------------------------
# evaluate_config regression (satellite bugfix)
# ---------------------------------------------------------------------------

class _SequenceEnv:
    """Returns scripted metric values per apply call."""

    def __init__(self, values):
        self.values = list(values)
        self.calls = 0

    def apply(self, config, eval_run=False):
        v = self.values[self.calls % len(self.values)]
        self.calls += 1
        return {"m": v}


def test_evaluate_config_sums_then_divides_once():
    # 3 runs of 0.1: the old per-run `v / runs` accumulation yields
    # fl(fl(0.1/3)+fl(0.1/3))+fl(0.1/3) = 0.09999999999999999 — drifted and
    # order-dependent. The fix divides the exact sum once.
    env = _SequenceEnv([0.1, 0.1, 0.1])
    got = evaluate_config(env, {}, runs=3)["m"]
    assert got == (0.1 + 0.1 + 0.1) / 3
    drifted = 0.0
    for _ in range(3):
        drifted += 0.1 / 3
    assert got != drifted  # the bug this test pins


def test_scan_fleet_of_one_matches_host_loop_tuner():
    """Acceptance: a fleet-of-1 fused episode reproduces the host-loop
    ``Tuner`` session — decision trajectory exact, floats within ulps — on
    the 2-D and the 8-D space."""
    from repro.core import FleetTuner
    for env_cls in (LustreSimEnv, LustreSimV2):
        seed, steps = 5, 8
        env = env_cls("seq_write", seed=seed).to_model_env()
        scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env), seed=seed)
        single = Tuner(env, scal, agent, engine="host").run(steps)

        fleet = FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [seed],
            env_cls=env_cls, engine="scan")
        got = fleet.run(steps).results[0]
        _assert_bitwise_equal_runs(single, got, maxulp=4)


def test_evaluation_path_fleet_of_one_parity():
    """Default + final evaluations (the evaluate_config path) agree bitwise
    between the single host Tuner and the fleet — the regression the per-run
    division bug would reintroduce."""
    from repro.core import FleetTuner
    seed, workload = 11, "video_server"
    env = LustreSimEnv(workload, seed=seed)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=seed)
    single = Tuner(env, scal, agent).run(4)
    fleet = FleetTuner.from_grid([workload], [{"throughput": 1.0}], [seed])
    got = fleet.run(4).results[0]
    assert got.default_metrics == single.default_metrics
    assert got.best_metrics == single.best_metrics
