"""Lustre-simulator calibration checks: the response surface must reproduce
the paper's tuning-headroom structure (DESIGN.md §2)."""

import numpy as np
import pytest

from repro.envs import WORKLOADS, LustreSimEnv
from repro.envs.lustre_sim import NET_CAP, paper_param_space

# optimum-over-default throughput headroom targets (paper-derived):
# seq_write ~3.5x (paper +250.4%); 5-workload average ~1.92x (paper +91.8%)
HEADROOM_BANDS = {
    "file_server": (1.25, 1.65),
    "video_server": (1.45, 1.95),
    "seq_write": (3.0, 4.0),
    "seq_read": (1.5, 2.0),
    "random_rw": (1.2, 1.6),
}


@pytest.mark.parametrize("workload", list(WORKLOADS))
def test_headroom_within_band(workload):
    env = LustreSimEnv(workload)
    default = env.mean_performance(env.param_space.default_config())
    best = max(env.mean_performance(c)["throughput"]
               for c in env.param_space.grid(16))
    ratio = best / default["throughput"]
    lo, hi = HEADROOM_BANDS[workload]
    assert lo <= ratio <= hi, (workload, ratio)


def test_average_headroom_matches_paper():
    ratios = []
    for wl in WORKLOADS:
        env = LustreSimEnv(wl)
        default = env.mean_performance(env.param_space.default_config())
        best = max(env.mean_performance(c)["throughput"]
                   for c in env.param_space.grid(16))
        ratios.append(best / default["throughput"])
    avg_gain = np.mean([r - 1 for r in ratios])
    assert 0.75 <= avg_gain <= 1.10  # paper: 0.918


def test_throughput_never_exceeds_physical_caps():
    for wl in WORKLOADS:
        env = LustreSimEnv(wl)
        for cfg in env.param_space.grid(12):
            perf = env.mean_performance(cfg)
            assert perf["throughput"] <= NET_CAP * 0.95 + 1e-6
            assert perf["throughput"] > 0


def test_striping_gate_interaction():
    """Wide striping must NOT pay off with tiny stripes (the ridge)."""
    env = LustreSimEnv("seq_write")
    tiny = env.mean_performance({"stripe_count": 6, "stripe_size": 65536})
    good = env.mean_performance({"stripe_count": 6, "stripe_size": 8388608})
    narrow = env.mean_performance({"stripe_count": 1, "stripe_size": 65536})
    assert good["throughput"] > 2.0 * tiny["throughput"]
    assert tiny["throughput"] < 1.5 * narrow["throughput"]


def test_metrics_consistent_with_throughput():
    """Internal metrics must carry signal about delivered performance."""
    env = LustreSimEnv("seq_write", seed=0)
    lo = env.apply({"stripe_count": 1, "stripe_size": 1048576})
    hi = env.apply({"stripe_count": 6, "stripe_size": 8388608})
    assert hi["throughput"] > lo["throughput"]
    assert hi["write_rpcs_in_flight"] > lo["write_rpcs_in_flight"]
    assert hi["ram_used_percent"] > lo["ram_used_percent"]


def test_eval_run_lower_variance():
    env = LustreSimEnv("file_server", seed=0)
    cfg = env.param_space.default_config()
    short = [env.apply(cfg)["throughput"] for _ in range(30)]
    env2 = LustreSimEnv("file_server", seed=0)
    long = [env2.apply(cfg, eval_run=True)["throughput"] for _ in range(30)]
    assert np.std(long) < np.std(short)


def test_restart_costs_in_paper_ranges():
    env = LustreSimEnv("seq_read", seed=0, extended=True)
    base = env.param_space.default_config()
    same = env.restart_cost(dict(base), dict(base))
    assert same == 0.0
    wl_restart = env.restart_cost({**base, "stripe_count": 3}, base)
    assert 12.0 <= wl_restart <= 20.0
    dfs_restart = env.restart_cost({**base, "service_threads": 128}, base)
    assert 42.0 <= dfs_restart <= 50.0  # 30 s DFS + 12-20 s workload


def test_cache_warmth_visible_in_state():
    """The explainable variance must be observable via cache_hit_ratio."""
    env = LustreSimEnv("seq_read", seed=3)
    cfg = env.param_space.default_config()
    pairs = []
    for _ in range(40):
        m = env.apply(cfg)
        pairs.append((m["cache_hit_ratio"], m["throughput"]))
    hits, tputs = np.array(pairs).T
    corr = np.corrcoef(hits, tputs)[0, 1]
    assert corr > 0.3, corr  # warm cache <-> higher measured throughput


def test_paper_param_space_matches_paper():
    space = paper_param_space()
    assert space.names == ["stripe_count", "stripe_size"]
    cfg = space.default_config()
    assert cfg == {"stripe_count": 1, "stripe_size": 1048576}  # Lustre defaults
