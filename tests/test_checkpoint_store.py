"""checkpoint/store.py under the FleetService state shapes.

The service checkpoints a host-resident fleet pytree whose leaves span the
full dtype mix of the streaming runtime: f32 learner/replay tensors, uint8
compact-trace action indices, int32 fixed-point restart encodings and FIFO
cursors, uint32 PRNG key words. These tests pin that the store round-trips
every one of them bit-exactly, and that a damaged checkpoint RAISES —
a partial file must never silently hand back a reinitialized session.
"""

import json
import os

import numpy as np
import pytest

from repro.checkpoint.store import (
    restore_checkpoint,
    restore_into,
    save_checkpoint,
)


def _fleet_state_tree(rng):
    """A miniature of FleetService.checkpoint()'s array tree."""
    return {
        "sessions": {
            "0": {
                "ddpg": {
                    "actor": [rng.standard_normal((7, 5)).astype(np.float32),
                              rng.standard_normal(5).astype(np.float32)],
                    "opt_count": np.int32(42),
                },
                "buffer": {
                    "s": rng.random((16, 3)).astype(np.float32),
                    "a": rng.random((16, 2)).astype(np.float32),
                },
                "trace_idx": rng.integers(0, 200, (16, 2), dtype=np.uint8),
                "restart_fp": rng.integers(
                    0, 2**20, (16,), dtype=np.int32),
                "learn_key": np.array([1234, 5678], np.uint32),
            },
            "1": {
                "ddpg": {
                    "actor": [rng.standard_normal((7, 5)).astype(np.float32),
                              rng.standard_normal(5).astype(np.float32)],
                    "opt_count": np.int32(7),
                },
                "buffer": {
                    "s": rng.random((16, 3)).astype(np.float32),
                    "a": rng.random((16, 2)).astype(np.float32),
                },
                "trace_idx": rng.integers(0, 200, (16, 2), dtype=np.uint8),
                "restart_fp": rng.integers(
                    0, 2**20, (16,), dtype=np.int32),
                "learn_key": np.array([4321, 8765], np.uint32),
            },
        },
    }


def _leaves(tree):
    import jax
    return jax.tree_util.tree_leaves(tree)


def test_mixed_dtype_fleet_tree_roundtrips_bitwise(tmp_path):
    rng = np.random.default_rng(0)
    tree = _fleet_state_tree(rng)
    extra = {"slots": [0, 1], "total_steps": 9,
             "noise_bitgen": {"state": {"state": 2**80, "inc": 3}}}
    save_checkpoint(str(tmp_path), 9, tree, extra=extra)

    step, flat, got_extra = restore_checkpoint(str(tmp_path))
    assert step == 9
    assert got_extra == extra  # big ints + nesting survive the JSON manifest
    restored = restore_into(tree, flat)
    for a, b in zip(_leaves(tree), _leaves(restored)):
        assert np.asarray(b).dtype == np.asarray(a).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_array_raises_not_reinitializes(tmp_path):
    rng = np.random.default_rng(1)
    path = save_checkpoint(str(tmp_path), 3, _fleet_state_tree(rng))
    npz = os.path.join(path, "arrays.npz")
    with np.load(npz) as z:  # simulate a torn write: payload drifts from
        flat = {k: z[k] for k in z.files}  # the CRCs the manifest recorded
    flat["sessions/0/buffer/s"] = flat["sessions/0/buffer/s"].copy()
    flat["sessions/0/buffer/s"][0, 0] += 1.0
    np.savez(npz, **flat)
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(str(tmp_path))


def test_missing_leaf_raises_keyerror(tmp_path):
    rng = np.random.default_rng(2)
    tree = _fleet_state_tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    _, flat, _ = restore_checkpoint(str(tmp_path))
    del flat["sessions/0/learn_key"]
    with pytest.raises(KeyError, match="learn_key"):
        restore_into(tree, flat)


def test_shape_drift_raises_valueerror(tmp_path):
    rng = np.random.default_rng(3)
    tree = _fleet_state_tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    _, flat, _ = restore_checkpoint(str(tmp_path))
    grown = _fleet_state_tree(rng)
    grown["sessions"]["0"]["buffer"]["s"] = np.zeros((32, 3), np.float32)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_into(grown, flat)


def test_crash_window_stale_tmp_dir_is_invisible_and_pruned(tmp_path):
    """A writer that died mid-write leaves step_<N>.tmp-<pid> behind: the
    torn directory is never listed as a restorable step, the previous
    checkpoint stays the restore target, and the next successful save
    sweeps the debris."""
    rng = np.random.default_rng(5)
    tree = _fleet_state_tree(rng)
    save_checkpoint(str(tmp_path), 1, tree)
    crashed = tmp_path / "step_000000002.tmp-99999"
    crashed.mkdir()
    (crashed / "arrays.npz").write_bytes(b"PK\x03\x04torn")
    from repro.checkpoint.store import latest_step, list_steps
    assert list_steps(str(tmp_path)) == [1]
    assert latest_step(str(tmp_path)) == 1
    step, _, _ = restore_checkpoint(str(tmp_path))
    assert step == 1
    save_checkpoint(str(tmp_path), 2, tree)
    assert not crashed.exists()
    assert list_steps(str(tmp_path)) == [1, 2]


def test_truncated_npz_raises_instead_of_reinitializing(tmp_path):
    """A torn arrays.npz (power cut before the payload hit the platter) is a
    hard load error — never a silent fresh session."""
    rng = np.random.default_rng(6)
    path = save_checkpoint(str(tmp_path), 4, _fleet_state_tree(rng))
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "rb") as f:
        payload = f.read()
    with open(npz, "wb") as f:
        f.write(payload[: len(payload) // 3])
    with pytest.raises(Exception) as exc_info:
        restore_checkpoint(str(tmp_path))
    from repro.checkpoint.store import _RESTORE_ERRORS
    assert isinstance(exc_info.value, _RESTORE_ERRORS)


def test_fallback_restore_walks_history_to_a_verifiable_step(tmp_path):
    """``fallback=True`` survives a corrupted newest checkpoint by walking
    the keep-k history newest-to-oldest; the recovered step is reported so
    callers know how far back the restore reached."""
    rng = np.random.default_rng(7)
    trees = {s: _fleet_state_tree(rng) for s in (1, 2, 3)}
    for s in (1, 2, 3):
        save_checkpoint(str(tmp_path), s, trees[s], extra={"step": s})
    # corrupt the newest payload after publish (media corruption)
    npz = os.path.join(str(tmp_path), "step_000000003", "arrays.npz")
    with np.load(npz) as z:
        flat = {k: z[k] for k in z.files}
    flat["sessions/1/learn_key"] = flat["sessions/1/learn_key"] ^ 0xFFFF
    np.savez(npz, **flat)
    # default stays strict: the newest checkpoint fails loudly
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(str(tmp_path))
    step, flat, extra = restore_checkpoint(str(tmp_path), fallback=True)
    assert step == 2 and extra == {"step": 2}
    restored = restore_into(trees[2], flat)
    for a, b in zip(_leaves(trees[2]), _leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # an explicit step never falls back — the caller asked for THAT one
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(str(tmp_path), step=3, fallback=True)


def test_fallback_with_every_step_corrupted_raises(tmp_path):
    rng = np.random.default_rng(8)
    for s in (1, 2):
        path = save_checkpoint(str(tmp_path), s, _fleet_state_tree(rng))
        os.remove(os.path.join(path, "arrays.npz"))
    with pytest.raises(IOError, match="no verifiable checkpoint"):
        restore_checkpoint(str(tmp_path), fallback=True)


def test_tampered_manifest_crc_raises(tmp_path):
    rng = np.random.default_rng(4)
    path = save_checkpoint(str(tmp_path), 5, _fleet_state_tree(rng))
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    key = next(iter(manifest["crc"]))
    manifest["crc"][key] ^= 0xDEADBEEF
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(str(tmp_path))
    # verify=False is the explicit escape hatch, not the default
    step, flat, _ = restore_checkpoint(str(tmp_path), verify=False)
    assert step == 5 and flat
