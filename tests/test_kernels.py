"""Per-kernel shape/dtype sweeps, asserting allclose against the pure-jnp
oracles in kernels/ref.py (Pallas kernels run in interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gmm import gmm
from repro.kernels.mamba2_scan import ssd_scan
from repro.kernels.rwkv6 import wkv6_scan

# Model-layer kernel sweeps (Pallas interpret mode, compile-heavy): slow lane.
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Kv,D", [
    (1, 128, 2, 2, 32),     # MHA
    (2, 256, 4, 2, 64),     # GQA g=2
    (1, 384, 8, 2, 16),     # GQA g=4, 3 blocks
    (1, 128, 4, 1, 128),    # MQA, full head_dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_forward(B, S, H, Kv, D, causal, dtype):
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, S, Kv, D)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, S, Kv, D)), dtype)
    o_ref = ref.attention_ref(q, k, v, causal)
    qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
    o = jnp.swapaxes(flash_attention(qt, kt, vt, causal, 128, 128, True),
                     1, 2)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), **_tol(dtype))


def test_flash_backward_matches_ref_grads():
    B, S, H, Kv, D = 1, 256, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, Kv, D)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, Kv, D)), jnp.float32)

    def loss_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, True) ** 2)

    def loss_ker(q, k, v):
        qt, kt, vt = (jnp.swapaxes(t, 1, 2) for t in (q, k, v))
        o = flash_attention(qt, kt, vt, True, 128, 128, True)
        return jnp.sum(jnp.swapaxes(o, 1, 2) ** 2)

    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    gk = jax.grad(loss_ker, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 16, 8, 32),
    (2, 256, 3, 32, 16, 64),
    (1, 64, 1, 64, 64, 64),   # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel(b, s, h, p, n, chunk, dtype):
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)) * 0.5, dtype)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, dtype)
    Cm = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, dtype)
    y_ref, s_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    xf = jnp.swapaxes(x, 1, 2).reshape(b * h, s, p)
    dtf = jnp.swapaxes(dt, 1, 2).reshape(b * h, s)
    Af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h)
    y, st = ssd_scan(xf, dtf, Af, Bm, Cm, heads=h, chunk=chunk,
                     interpret=True)
    y = jnp.swapaxes(y.reshape(b, h, s, p), 1, 2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(st.reshape(b, h, n, p)),
                               np.asarray(s_ref, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,c,chunk", [
    (1, 64, 2, 16, 32),
    (2, 128, 2, 32, 64),
    (1, 256, 4, 64, 64),
])
def test_wkv6_kernel(B, S, H, c, chunk):
    r = jnp.asarray(RNG.standard_normal((B, S, H, c)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, c)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, c)) * 0.5, jnp.float32)
    lw = -jnp.asarray(RNG.uniform(0.01, 2.0, (B, S, H, c)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, c)) * 0.3, jnp.float32)
    y_ref, s_ref = ref.wkv6_ref(r, k, v, lw, u)

    def fold(t):
        return jnp.swapaxes(t, 1, 2).reshape(B * H, S, c)

    uf = jnp.broadcast_to(u[None], (B, H, c)).reshape(B * H, c)
    y, st = wkv6_scan(fold(r), fold(k), fold(v), fold(lw), uf, chunk=chunk,
                      interpret=True)
    y = jnp.swapaxes(y.reshape(B, H, S, c), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.reshape(B, H, c, c)),
                               np.asarray(s_ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F", [
    (2, 128, 128, 128),
    (4, 128, 256, 128),
    (8, 256, 128, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm(E, C, D, F, dtype):
    x = jnp.asarray(RNG.standard_normal((E, C, D)), dtype)
    w = jnp.asarray(RNG.standard_normal((E, D, F)), dtype)
    o_ref = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                       w.astype(jnp.float32))
    o = gmm(x, w, interpret=True)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(o_ref),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


# ---------------------------------------------------------------------------
# XLA fallback paths vs oracles (these run in the dry-run)
# ---------------------------------------------------------------------------

def test_chunked_sdpa_vs_ref():
    from repro.models.attention import sdpa_chunked
    q = jnp.asarray(RNG.standard_normal((1, 1024, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 1024, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((1, 1024, 2, 32)), jnp.float32)
    o_ref = ref.attention_ref(q, k, v, True)
    o = sdpa_chunked(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_chunked_ssd_jnp_vs_ref():
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 1, 192, 2, 16, 8
    x = jnp.asarray(RNG.standard_normal((b, s, h, p)) * 0.5, jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, s, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, jnp.float32)
    Cm = jnp.asarray(RNG.standard_normal((b, s, n)) * 0.3, jnp.float32)
    y_ref, s_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    y, st = ssd_chunked(x, dt, A, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_chunked_wkv_jnp_vs_ref():
    from repro.models.rwkv import wkv_chunked
    B, S, H, c = 1, 96, 2, 16
    r = jnp.asarray(RNG.standard_normal((B, S, H, c)) * 0.5, jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, H, c)) * 0.5, jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, H, c)) * 0.5, jnp.float32)
    lw = -jnp.asarray(RNG.uniform(0.01, 2.0, (B, S, H, c)), jnp.float32)
    u = jnp.asarray(RNG.standard_normal((H, c)) * 0.3, jnp.float32)
    y_ref, s_ref = ref.wkv6_ref(r, k, v, lw, u)
    y, st = wkv_chunked(r, k, v, lw, u, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)
