"""Fused DDPG learner: Pallas kernel (interpret mode) vs the kernels/ref.py
oracle vs the XLA ``ddpg_learn_scan`` — plus the pre-gather and empty-buffer
regression suites.

Equivalence contract: decision-relevant fields — Adam step counts, the
learner ``step``, sampled minibatch indices — are EXACT across every path.
Float fields: kernel vs oracle (same packed formulation) stays within the
PR 3 <= 4 ulp bound; kernel vs the unpadded ``ddpg_learn_scan`` (different
GEMM formulations) holds relative error at float32 resolution — see
``_assert_learner_close`` for why a raw ulp bound is the wrong metric
across formulations. Both the paper's 2-D space shape and the 8-knob shape
are covered.
"""

import os
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DDPGConfig, MagpieAgent
from repro.core.ddpg import (
    _ddpg_step,
    ddpg_init,
    ddpg_learn_scan,
    fleet_init,
    fleet_learn_scan,
    gather_minibatches,
    sample_minibatch_indices,
)
from repro.kernels import ddpg_fused as fused
from repro.kernels import ref

# (state_dim, action_dim): the paper's 2-D space and the 8-knob space
DIMS = [(12, 2), (12, 8)]


def _storage(rng, cap, state_dim, action_dim):
    return (rng.random((cap, state_dim)).astype(np.float32),
            rng.random((cap, action_dim)).astype(np.float32),
            rng.standard_normal(cap).astype(np.float32),
            rng.random((cap, state_dim)).astype(np.float32))


def _max_ulp(tree_a, tree_b) -> int:
    """Largest float32 ulp distance across float leaves; int leaves must be
    exactly equal (the decision-relevant part of the contract)."""
    worst = 0
    for a, b in zip(jax.tree_util.tree_leaves(tree_a),
                    jax.tree_util.tree_leaves(tree_b)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.float32:
            ai = a.view(np.int32).astype(np.int64)
            bi = b.view(np.int32).astype(np.int64)
            worst = max(worst, int(np.abs(ai - bi).max()))
        else:
            np.testing.assert_array_equal(a, b)
    return worst


def _assert_learner_close(tree_a, tree_b):
    """Cross-formulation learner tolerance: int leaves (Adam counts, step)
    exact; float leaves allclose at float32 resolution (rtol 1e-5).

    The padded kernel and the unpadded scan compute each GEMM within ~1 ulp
    of each other, but Adam's early-step denominators (sqrt(nu) + eps with
    nu near zero) amplify that to tens of ulps on weights whose magnitude is
    ~1e-4 after a handful of updates — a few e-10 absolute. The strict <= 4
    ulp bound of the PR 3 engine contract applies to same-formulation
    comparisons (kernel vs oracle below); across formulations the honest
    bound is relative error at float32 resolution."""
    for a, b in zip(jax.tree_util.tree_leaves(tree_a),
                    jax.tree_util.tree_leaves(tree_b)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.float32:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)
        else:
            np.testing.assert_array_equal(a, b)


def _packed_inputs(cfg, size, seed=0, num_updates=8):
    """(packed params, packed pre-gathered batches, dims) for direct
    kernel/oracle calls — single session, no fleet axis."""
    state, _ = ddpg_init(jax.random.PRNGKey(seed), cfg)
    data = _storage(np.random.default_rng(seed + 1), 32, cfg.state_dim,
                    cfg.action_dim)
    dims = fused.packed_dims(cfg.state_dim, cfg.action_dim, cfg.hidden)
    a_adam, c_adam = state.actor_opt[0], state.critic_opt[0]
    packed = fused.pack_params(
        state.actor, state.critic, state.actor_targ, state.critic_targ,
        a_adam.mu, a_adam.nu, c_adam.mu, c_adam.nu,
        a_adam.count, c_adam.count, dims)
    idx = sample_minibatch_indices(jax.random.PRNGKey(seed + 2), num_updates,
                                   cfg.batch_size, jnp.asarray(size))
    batches = fused.pack_minibatches(gather_minibatches(data, idx), dims)
    return packed, batches, dims


# ---------------------------------------------------------------------------
# Satellite: hoisted minibatch gathers (bitwise vs the per-update path)
# ---------------------------------------------------------------------------

def test_gather_minibatches_bitwise_vs_per_update_indexing():
    rng = np.random.default_rng(0)
    data = _storage(rng, 32, 12, 2)
    idx = np.asarray(sample_minibatch_indices(jax.random.PRNGKey(1), 12, 16,
                                              jnp.asarray(20)))
    got = gather_minibatches(tuple(jnp.asarray(x) for x in data),
                             jnp.asarray(idx))
    for g, x in zip(got, data):
        want = np.stack([x[ix] for ix in idx])
        np.testing.assert_array_equal(np.asarray(g), want)


@pytest.mark.parametrize("state_dim,action_dim", DIMS)
def test_learn_scan_pregather_bitwise_vs_per_update_gather(state_dim,
                                                           action_dim,
                                                           monkeypatch):
    """The hoisted single-take learner == the old gather-per-update scan,
    bitwise: gathers are exact and the update arithmetic is untouched.
    This is the XLA path's contract — pin the default mode so the test
    means the same thing inside the REPRO_KERNELS=interpret CI lane."""
    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    cfg = DDPGConfig(state_dim=state_dim, action_dim=action_dim)
    state, (atx, ctx) = ddpg_init(jax.random.PRNGKey(0), cfg)
    data = _storage(np.random.default_rng(0), 32, state_dim, action_dim)
    key, size, updates = jax.random.PRNGKey(42), 20, 10

    new_state, new_ms = ddpg_learn_scan(state, data, size, key, cfg, atx,
                                        ctx, updates)

    s, a, r, s2 = (jnp.asarray(x) for x in data)

    @jax.jit
    def legacy(state):
        idx = sample_minibatch_indices(key, updates, cfg.batch_size,
                                       jnp.asarray(size))

        def body(st, ix):
            return _ddpg_step(st, (s[ix], a[ix], r[ix], s2[ix]),
                              cfg, atx, ctx)

        return jax.lax.scan(body, state, idx)

    old_state, old_ms = legacy(state)
    assert _max_ulp(new_state, old_state) == 0
    assert _max_ulp(new_ms, old_ms) == 0


# ---------------------------------------------------------------------------
# Tentpole: kernel vs oracle vs ddpg_learn_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("state_dim,action_dim", DIMS)
def test_kernel_interpret_matches_ref_oracle(state_dim, action_dim):
    cfg = DDPGConfig(state_dim=state_dim, action_dim=action_dim)
    packed, batches, dims = _packed_inputs(cfg, size=20)

    with_n = jax.tree_util.tree_map(lambda x: x[None], (packed, batches))
    k_packed, k_ms = fused.ddpg_fused_learn(
        *with_n, dims=dims, gamma=cfg.gamma, tau=cfg.tau,
        actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr, interpret=True)
    k_packed, k_ms = jax.tree_util.tree_map(lambda x: x[0], (k_packed, k_ms))

    r_packed, r_ms = ref.ddpg_fused_ref(
        packed, batches, state_dim=state_dim, action_dim=action_dim,
        pad=dims.pad, gamma=cfg.gamma, tau=cfg.tau,
        actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr)

    assert _max_ulp(k_packed, r_packed) <= 4
    assert _max_ulp(k_ms, r_ms) <= 4


@pytest.mark.parametrize("state_dim,action_dim", DIMS)
def test_kernel_xla_twin_matches_ref_oracle(state_dim, action_dim):
    """The blocked-GEMM XLA twin (the kernel's fallback formulation) agrees
    with the oracle too — the packed computation is backend-independent."""
    cfg = DDPGConfig(state_dim=state_dim, action_dim=action_dim)
    packed, batches, dims = _packed_inputs(cfg, size=20)

    with_n = jax.tree_util.tree_map(lambda x: x[None], (packed, batches))
    x_packed, x_ms = fused.ddpg_fused_xla(
        *with_n, dims=dims, gamma=cfg.gamma, tau=cfg.tau,
        actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr)
    x_packed, x_ms = jax.tree_util.tree_map(lambda x: x[0], (x_packed, x_ms))

    r_packed, r_ms = ref.ddpg_fused_ref(
        packed, batches, state_dim=state_dim, action_dim=action_dim,
        pad=dims.pad, gamma=cfg.gamma, tau=cfg.tau,
        actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr)

    assert _max_ulp(x_packed, r_packed) <= 4
    assert _max_ulp(x_ms, r_ms) <= 4


@pytest.mark.parametrize("state_dim,action_dim", DIMS)
def test_kernel_path_matches_learn_scan(state_dim, action_dim, monkeypatch):
    """REPRO_KERNELS=interpret routes ddpg_learn_scan through the Pallas
    kernel; result within the ulp contract of the XLA scan, counts exact."""
    cfg = DDPGConfig(state_dim=state_dim, action_dim=action_dim)
    state, (atx, ctx) = ddpg_init(jax.random.PRNGKey(0), cfg)
    data = _storage(np.random.default_rng(1), 32, state_dim, action_dim)
    key = jax.random.PRNGKey(7)

    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    x_state, x_ms = ddpg_learn_scan(state, data, 20, key, cfg, atx, ctx, 8)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    k_state, k_ms = ddpg_learn_scan(state, data, 20, key, cfg, atx, ctx, 8)

    assert int(k_state.step) == int(x_state.step) == 8
    assert int(k_state.actor_opt[0].count) == 8
    _assert_learner_close(k_state, x_state)
    _assert_learner_close(k_ms, x_ms)


def test_fleet_kernel_grid_matches_xla(monkeypatch):
    """The fleet entry runs the kernel gridded over sessions (via the vmap
    batching rule); every session stays within the ulp contract."""
    cfg = DDPGConfig(state_dim=12, action_dim=2)
    n = 3
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(n)])
    states, (atx, ctx) = fleet_init(keys, cfg)
    rng = np.random.default_rng(2)
    data = tuple(np.stack(xs) for xs in zip(
        *[_storage(rng, 16, 12, 2) for _ in range(n)]))
    sizes = jnp.full((n,), 10, jnp.int32)
    lkeys = jnp.stack([jax.random.PRNGKey(s + 3) for s in range(n)])

    monkeypatch.delenv("REPRO_KERNELS", raising=False)
    x_states, _ = fleet_learn_scan(states, data, sizes, lkeys, cfg, atx, ctx,
                                   6)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    k_states, _ = fleet_learn_scan(states, data, sizes, lkeys, cfg, atx, ctx,
                                   6)
    _assert_learner_close(k_states, x_states)


def test_padded_lanes_stay_zero():
    """Zero padding is a fixed point of the whole inner loop: weights, Adam
    moments and Polyak targets keep exact zeros in every padded row/column
    after many updates (the invariant that makes the packed layout sound)."""
    cfg = DDPGConfig(state_dim=12, action_dim=2)
    packed, batches, dims = _packed_inputs(cfg, size=20, num_updates=16)
    with_n = jax.tree_util.tree_map(lambda x: x[None], (packed, batches))
    (w, b, mw, mb, _), _ = fused.ddpg_fused_learn(
        *with_n, dims=dims, gamma=cfg.gamma, tau=cfg.tau,
        actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr, interpret=True)
    k, m, p = dims.state_dim, dims.action_dim, dims.pad
    # actor & actor_targ: input rows >= k, head columns >= m
    for net in (0, 2):
        assert not np.any(np.asarray(w[0, net, 0, k:, :]))
        assert not np.any(np.asarray(w[0, net, 2, :, m:]))
        assert not np.any(np.asarray(b[0, net, 2, m:]))
    # critic & critic_targ: input rows >= k+m, head columns >= 1
    for net in (1, 3):
        assert not np.any(np.asarray(w[0, net, 0, k + m:, :]))
        assert not np.any(np.asarray(w[0, net, 2, :, 1:]))
        assert not np.any(np.asarray(b[0, net, 2, 1:]))
    # Adam moments inherit the zeros (exactly-zero grads on padding)
    assert not np.any(np.asarray(mw[0, 0, :, 0, k:, :]))
    assert not np.any(np.asarray(mw[0, 1, :, 0, k + m:, :]))
    assert not np.any(np.asarray(mb[0, 0, :, 2, m:]))


def test_agent_learn_routes_through_kernel(monkeypatch):
    """End-to-end dispatch: MagpieAgent.learn under REPRO_KERNELS=interpret
    mutates the learner like the default path, within the ulp contract."""
    def run(mode):
        if mode:
            monkeypatch.setenv("REPRO_KERNELS", mode)
        else:
            monkeypatch.delenv("REPRO_KERNELS", raising=False)
        cfg = DDPGConfig(state_dim=3, action_dim=2, updates_per_step=6)
        agent = MagpieAgent(cfg, seed=0)
        rng = np.random.default_rng(0)
        for _ in range(12):
            agent.observe(rng.random(3).astype(np.float32),
                          rng.random(2).astype(np.float32),
                          float(rng.standard_normal() * 0.1),
                          rng.random(3).astype(np.float32))
        metrics = agent.learn()
        return agent.state, metrics

    x_state, x_metrics = run(None)
    k_state, k_metrics = run("interpret")
    _assert_learner_close(k_state, x_state)
    assert set(k_metrics) == set(x_metrics)
    for key in x_metrics:
        np.testing.assert_allclose(k_metrics[key], x_metrics[key],
                                   rtol=1e-5, atol=1e-6)


def test_episode_scan_engine_runs_on_kernel_learner(monkeypatch):
    """The fused episode engine compiles and runs with the Pallas learner in
    its scan body (scan + vmap over pallas_call), and a mode flip recompiles
    instead of reusing the other path's program (cache-key regression)."""
    from repro.core import Scalarizer, Tuner
    from repro.envs import LustreSimEnv

    def run(mode):
        if mode:
            monkeypatch.setenv("REPRO_KERNELS", mode)
        else:
            monkeypatch.delenv("REPRO_KERNELS", raising=False)
        env = LustreSimEnv("seq_write", seed=0).to_model_env()
        scal = Scalarizer(weights={"throughput": 1.0},
                          specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=4),
                            seed=0)
        return Tuner(env, scal, agent, eval_runs=1, engine="scan").run(3)

    base = run(None)
    got = run("interpret")
    # the kernel learner's ulp-level drift may nudge float fields, but the
    # run must produce the same shape of result on the same step budget
    assert len(got.history) == len(base.history) == 3
    assert set(got.best_config) == set(base.best_config)
    assert np.isfinite(got.best_objective)


# ---------------------------------------------------------------------------
# Satellite: the empty-buffer (silent zero-index) hazard
# ---------------------------------------------------------------------------

def test_learn_scan_raises_on_empty_buffer():
    cfg = DDPGConfig(state_dim=3, action_dim=2)
    state, (atx, ctx) = ddpg_init(jax.random.PRNGKey(0), cfg)
    data = _storage(np.random.default_rng(0), 8, 3, 2)
    with pytest.raises(ValueError, match="empty replay buffer"):
        ddpg_learn_scan(state, data, 0, jax.random.PRNGKey(1), cfg, atx,
                        ctx, 4)


def test_fleet_learn_scan_raises_on_any_empty_session():
    cfg = DDPGConfig(state_dim=3, action_dim=2)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
    states, (atx, ctx) = fleet_init(keys, cfg)
    rng = np.random.default_rng(0)
    data = tuple(np.stack(xs) for xs in zip(
        *[_storage(rng, 8, 3, 2) for _ in range(2)]))
    lkeys = jnp.stack([jax.random.PRNGKey(s + 3) for s in range(2)])
    with pytest.raises(ValueError, match="empty replay buffer"):
        fleet_learn_scan(states, data, jnp.asarray([4, 0]), lkeys, cfg,
                         atx, ctx, 4)


def test_agent_learn_on_empty_buffer_is_guarded_noop():
    agent = MagpieAgent(DDPGConfig(state_dim=3, action_dim=2), seed=0)
    before = jax.tree_util.tree_map(np.asarray, agent.state)
    assert agent.learn() == {}
    assert _max_ulp(agent.state, before) == 0


def test_sample_minibatch_indices_in_range_without_clamp():
    idx = np.asarray(sample_minibatch_indices(jax.random.PRNGKey(0), 50, 16,
                                              jnp.asarray(1)))
    assert idx.min() == idx.max() == 0  # size 1: only slot 0 is valid
    idx = np.asarray(sample_minibatch_indices(jax.random.PRNGKey(0), 50, 16,
                                              jnp.asarray(5)))
    assert idx.min() >= 0 and idx.max() < 5


# ---------------------------------------------------------------------------
# Satellite: BENCH_<n>.json numbering
# ---------------------------------------------------------------------------

def test_bench_json_numbering_appends_next_free_index(tmp_path):
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    try:
        from benchmarks.run import _write_bench_json
    finally:
        sys.path.pop(0)
    p0 = _write_bench_json({"benchmark": "episode_engine", "x": 1},
                           root=str(tmp_path))
    p1 = _write_bench_json({"benchmark": "episode_engine", "x": 2},
                           root=str(tmp_path))
    assert os.path.basename(p0) == "BENCH_0.json"
    assert os.path.basename(p1) == "BENCH_1.json"
    import json
    with open(p1) as f:
        assert json.load(f)["x"] == 2
