"""Sharded fleet session axis: ``shard_map`` over local devices.

The CI multi-device lane runs these under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a single-device
host the device-dependent tests skip. Load-bearing properties:

  * sharded fleet results are INVARIANT to the device count — per-session
    PRNG keys derive from session seeds, never from placement, and the scan
    body is placement-free, so 1-device and N-device runs agree bitwise;
  * session counts that do not divide the device count run via padding and
    return exactly the unpadded sessions' results;
  * a sharded fleet-of-N contains the same per-session trajectories as the
    unsharded fleet.
"""

import jax
import numpy as np
import pytest

from repro.core import DDPGConfig, FleetTuner
from repro.envs import LustreSimEnv

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices; CI multi-device lane forces 8 via XLA_FLAGS")


def _grid(devices, seeds, steps=5, workloads=("seq_write", "file_server"),
          chunk=None):
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=4)
    fleet = FleetTuner.from_grid(
        list(workloads), [{"throughput": 1.0}], list(seeds),
        engine="scan", ddpg_config=cfg, devices=devices, eval_runs=1,
        chunk=chunk)
    return fleet.run(steps)


def _assert_same_results(a, b):
    assert a.labels == b.labels
    for ra, rb in zip(a.results, b.results):
        assert ra.best_config == rb.best_config
        for ha, hb in zip(ra.history, rb.history):
            assert ha.config == hb.config
            assert ha.objective == hb.objective
            assert ha.reward == hb.reward
            assert ha.restart_seconds == hb.restart_seconds


@multi_device
def test_sharded_fleet_invariant_to_device_count():
    """8 sessions on 1 device == the same grid sharded over all devices."""
    r1 = _grid(jax.devices()[:1], seeds=[0, 1, 2, 3])
    rn = _grid(jax.devices(), seeds=[0, 1, 2, 3])
    _assert_same_results(r1, rn)


@multi_device
def test_sharded_fleet_pads_uneven_session_counts():
    """Sessions not divisible by the device count run via padding; the
    padded replicas never leak into results."""
    ndev = len(jax.devices())
    n_seeds = max(2, (ndev - 1))  # 1 workload x n_seeds, coprime-ish to ndev
    r_one = _grid(jax.devices()[:1], seeds=list(range(n_seeds)),
                  workloads=("seq_write",))
    r_all = _grid(jax.devices(), seeds=list(range(n_seeds)),
                  workloads=("seq_write",))
    assert len(r_all.results) == n_seeds
    _assert_same_results(r_one, r_all)


@multi_device
def test_from_grid_defaults_to_all_devices_for_scan():
    fleet = FleetTuner.from_grid(["seq_write"], [{"throughput": 1.0}], [0, 1],
                                 engine="scan")
    assert list(fleet.devices) == list(jax.devices())
    res = fleet.run(3)
    assert all(len(r.history) == 3 for r in res.results)


def test_scan_fleet_runs_on_any_device_count():
    """The scan fleet engine itself needs no multi-device host (devices=None
    or a single device falls back to plain vmap)."""
    res = _grid(None, seeds=[0, 1], steps=3, workloads=("seq_write",))
    assert len(res.results) == 2
    summary = res.summary("throughput")
    assert np.isfinite(summary["mean"])


@multi_device
def test_chunked_sharded_fleet_matches_unsharded():
    """chunk= composes with devices=: the chunk size is rounded up to a
    device multiple (core.episode.resolve_chunk), ragged chunks pad inside
    the last chunk only, and the streamed sharded run returns the same
    decision trajectories as the unsharded monolithic run."""
    from repro.core import last_fleet_run_stats
    seeds = [0, 1, 2, 3, 4]  # 5 sessions: ragged under any rounded chunk
    r_mono = _grid(jax.devices()[:1], seeds=seeds, workloads=("seq_write",))
    r_chunked = _grid(jax.devices(), seeds=seeds, workloads=("seq_write",),
                      chunk=3)
    stats = last_fleet_run_stats()
    ndev = len(jax.devices())
    assert stats["chunk"] % ndev == 0  # rounded up to a device multiple
    assert stats["padded_sessions"] < stats["chunk"]
    assert len(r_chunked.results) == len(seeds)
    # decision trajectory exact; floats ulp-bounded — the rounded chunk
    # compiles at a different vmap width than the monolithic run, and XLA
    # CPU's codegen is width-dependent (see tests/test_chunked_fleet.py)
    assert r_mono.labels == r_chunked.labels
    for ra, rb in zip(r_mono.results, r_chunked.results):
        assert ra.best_config == rb.best_config
        for ha, hb in zip(ra.history, rb.history):
            assert ha.config == hb.config
            assert ha.restart_seconds == hb.restart_seconds
            np.testing.assert_array_max_ulp(
                np.float32(ha.objective), np.float32(hb.objective), maxulp=32)
            np.testing.assert_array_max_ulp(
                np.float32(ha.reward), np.float32(hb.reward), maxulp=32)