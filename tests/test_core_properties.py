"""Property-based tests (hypothesis) on the paper-core invariants:
action mapping, replay buffer FIFO, scalarization/reward."""

import numpy as np
import pytest

# Declared in requirements.txt / pyproject's test extra; skip the whole
# property lane (instead of erroring collection) where it isn't installed.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    MetricSpec, ParamSpace, ParamSpec, ReplayBuffer, Scalarizer,
)

# ---------------------------------------------------------------------------
# Action mapping (paper §II-C-1)
# ---------------------------------------------------------------------------

SPACE = ParamSpace(specs=(
    ParamSpec("cont", "continuous", minimum=-3.0, maximum=7.0),
    ParamSpec("disc", "discrete", minimum=1, maximum=6),
    ParamSpec("choice", "choice", values=(64, 128, 256, 512)),
))


@given(st.lists(st.floats(0, 1), min_size=3, max_size=3))
@settings(max_examples=200, deadline=None)
def test_action_to_config_always_in_bounds(action):
    cfg = SPACE.to_config(action)
    assert -3.0 <= cfg["cont"] <= 7.0
    assert cfg["disc"] in (1, 2, 3, 4, 5, 6)
    assert cfg["choice"] in (64, 128, 256, 512)
    assert SPACE.validate(cfg)


@given(st.floats(0, 1))
@settings(max_examples=100, deadline=None)
def test_discrete_inverse_map_is_paper_formula(a):
    """lambda = floor(a*(max-min) + min + 0.5) for discrete params."""
    spec = ParamSpec("d", "discrete", minimum=1, maximum=6)
    expected = int(np.floor(a * (6 - 1) + 1 + 0.5))
    assert spec.from_unit(a) == min(6, max(1, expected))


@given(st.integers(1, 6), st.sampled_from((64, 128, 256, 512)))
@settings(max_examples=50, deadline=None)
def test_config_roundtrip(disc, choice):
    cfg = {"cont": 0.0, "disc": disc, "choice": choice}
    back = SPACE.to_config(SPACE.to_action(cfg))
    assert back["disc"] == disc
    assert back["choice"] == choice
    assert abs(back["cont"] - 0.0) < 1e-5


def test_out_of_range_action_clipped():
    cfg = SPACE.to_config([1.7, -0.3, 2.0])
    assert SPACE.validate(cfg)


def test_duplicate_names_rejected():
    with pytest.raises(ValueError):
        ParamSpace(specs=(ParamSpec("x", "discrete", 0, 1),
                          ParamSpec("x", "discrete", 0, 1)))


# ---------------------------------------------------------------------------
# Mixed-kind spaces (8-D generalization): log2_int / boolean / categorical
# ---------------------------------------------------------------------------

MIXED = ParamSpace(specs=(
    ParamSpec("lin", "continuous", minimum=-2.0, maximum=5.0),
    ParamSpec("disc", "discrete", minimum=1, maximum=6),
    ParamSpec("pow2", "log2_int", minimum=4, maximum=2048),
    ParamSpec("flag", "boolean", default=True),
    ParamSpec("cat", "categorical", values=("a", "b", "c")),
    ParamSpec("choice", "choice", values=(64, 128, 256, 512)),
))


@given(st.lists(st.floats(0, 1), min_size=6, max_size=6))
@settings(max_examples=200, deadline=None)
def test_mixed_action_to_config_always_valid(action):
    cfg = MIXED.to_config(action)
    assert MIXED.validate(cfg)
    assert isinstance(cfg["flag"], bool)
    assert cfg["pow2"] & (cfg["pow2"] - 1) == 0  # power of two
    assert cfg["cat"] in ("a", "b", "c")


@given(st.integers(1, 6), st.integers(2, 11), st.booleans(),
       st.sampled_from(("a", "b", "c")), st.sampled_from((64, 128, 256, 512)),
       st.floats(-2.0, 5.0))
@settings(max_examples=200, deadline=None)
def test_mixed_config_roundtrip(disc, pow2_exp, flag, cat, choice, lin):
    """unit -> config -> unit -> config is the identity on every finite kind
    (continuous round-trips to within float tolerance)."""
    cfg = {"lin": lin, "disc": disc, "pow2": 2 ** pow2_exp, "flag": flag,
           "cat": cat, "choice": choice}
    assert MIXED.validate(cfg)
    back = MIXED.to_config(MIXED.to_action(cfg))
    for k in ("disc", "pow2", "flag", "cat", "choice"):
        assert back[k] == cfg[k], k
    assert abs(back["lin"] - lin) < 1e-4


@given(st.lists(st.lists(st.floats(0, 1), min_size=6, max_size=6),
                min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_vectorized_roundtrip_matches_scalar(actions):
    """to_configs/to_actions (the fleet fast path) == the scalar maps."""
    acts = np.array(actions)
    batch = MIXED.to_configs(acts)
    assert batch == [MIXED.to_config(a) for a in acts]
    units = MIXED.to_actions(batch)
    np.testing.assert_array_equal(
        units, np.stack([MIXED.to_action(c) for c in batch]))
    # the round-trip is idempotent: every finite-kind value survives
    # unit-space re-encoding exactly; continuous within float32 tolerance
    for back, cfg in zip(MIXED.to_configs(units), batch):
        for key in ("disc", "pow2", "flag", "cat", "choice"):
            assert back[key] == cfg[key], key
        assert abs(back["lin"] - cfg["lin"]) < 1e-5


def test_cardinality_and_grid_capping():
    cards = {s.name: s.cardinality for s in MIXED.specs}
    assert cards == {"lin": None, "disc": 6, "pow2": 10, "flag": 2,
                     "cat": 3, "choice": 4}
    # grid axes never exceed cardinality: 4*6*10*2*3*4 with ppd=100
    assert MIXED.grid_size(100) == 100 * 6 * 10 * 2 * 3 * 4
    grid = MIXED.grid(2)
    assert MIXED.grid_size(2) == len(grid) == 2 * 2 * 2 * 2 * 2 * 2
    seen_flags = {c["flag"] for c in grid}
    assert seen_flags == {False, True}


def test_log2_int_requires_power_of_two_bounds():
    with pytest.raises(ValueError):
        ParamSpec("bad", "log2_int", minimum=3, maximum=64)
    spec = ParamSpec("ok", "log2_int", minimum=1, maximum=256)
    assert spec.cardinality == 9
    assert not spec.validate(100)  # not a power of two
    assert spec.validate(128)


# ---------------------------------------------------------------------------
# Replay buffer (paper §II-D: limited size, FIFO)
# ---------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(1, 100))
@settings(max_examples=50, deadline=None)
def test_fifo_eviction(capacity, n_adds):
    buf = ReplayBuffer(capacity, state_dim=2, action_dim=1)
    for i in range(n_adds):
        buf.add(np.full(2, i, np.float32), np.zeros(1), float(i),
                np.zeros(2))
    assert len(buf) == min(capacity, n_adds)
    s, a, r, s2 = buf.as_arrays()
    # the retained rewards are exactly the most recent min(cap, n) values
    expected = set(range(max(0, n_adds - capacity), n_adds))
    assert set(int(x) for x in r) == expected


def test_sample_requires_data():
    buf = ReplayBuffer(4, 2, 1)
    with pytest.raises(ValueError):
        buf.sample(np.random.default_rng(0), 2)


def test_state_dict_roundtrip():
    buf = ReplayBuffer(4, 2, 1)
    for i in range(6):
        buf.add(np.ones(2) * i, np.ones(1), i, np.ones(2))
    d = buf.state_dict()
    buf2 = ReplayBuffer(4, 2, 1)
    buf2.load_state_dict(d)
    for x, y in zip(buf.as_arrays(), buf2.as_arrays()):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Scalarization / reward (paper §II-A, §II-B-5)
# ---------------------------------------------------------------------------

SPECS = {"t": MetricSpec("t", 0.0, 100.0), "i": MetricSpec("i", 0.0, 10.0)}


@given(st.floats(0, 100), st.floats(0, 10))
@settings(max_examples=100, deadline=None)
def test_objective_weighted_sum(t, i):
    sc = Scalarizer(weights={"t": 1.0, "i": 2.0}, specs=SPECS)
    expected = 1.0 * t / 100.0 + 2.0 * i / 10.0
    assert abs(sc.objective({"t": t, "i": i}) - expected) < 1e-6


@given(st.floats(1, 100), st.floats(1, 100))
@settings(max_examples=100, deadline=None)
def test_reward_sign_matches_improvement(prev_t, new_t):
    sc = Scalarizer(weights={"t": 1.0}, specs=SPECS)
    r = sc.reward({"t": prev_t}, {"t": new_t})
    if new_t > prev_t:
        assert r > 0
    elif new_t < prev_t:
        assert r < 0
    # proportional form: r = (new - prev) / prev in normalized units
    assert abs(r - (new_t - prev_t) / prev_t) < 1e-5


def test_norm_clips_outside_bounds():
    assert SPECS["t"].norm(-5.0) == 0.0
    assert SPECS["t"].norm(500.0) == 1.0
