"""Property-based tests (hypothesis) on the paper-core invariants:
action mapping, replay buffer FIFO, scalarization/reward."""

import numpy as np
import pytest

# Declared in requirements.txt / pyproject's test extra; skip the whole
# property lane (instead of erroring collection) where it isn't installed.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    MetricSpec, ParamSpace, ParamSpec, ReplayBuffer, Scalarizer,
)

# ---------------------------------------------------------------------------
# Action mapping (paper §II-C-1)
# ---------------------------------------------------------------------------

SPACE = ParamSpace(specs=(
    ParamSpec("cont", "continuous", minimum=-3.0, maximum=7.0),
    ParamSpec("disc", "discrete", minimum=1, maximum=6),
    ParamSpec("choice", "choice", values=(64, 128, 256, 512)),
))


@given(st.lists(st.floats(0, 1), min_size=3, max_size=3))
@settings(max_examples=200, deadline=None)
def test_action_to_config_always_in_bounds(action):
    cfg = SPACE.to_config(action)
    assert -3.0 <= cfg["cont"] <= 7.0
    assert cfg["disc"] in (1, 2, 3, 4, 5, 6)
    assert cfg["choice"] in (64, 128, 256, 512)
    assert SPACE.validate(cfg)


@given(st.floats(0, 1))
@settings(max_examples=100, deadline=None)
def test_discrete_inverse_map_is_paper_formula(a):
    """lambda = floor(a*(max-min) + min + 0.5) for discrete params."""
    spec = ParamSpec("d", "discrete", minimum=1, maximum=6)
    expected = int(np.floor(a * (6 - 1) + 1 + 0.5))
    assert spec.from_unit(a) == min(6, max(1, expected))


@given(st.integers(1, 6), st.sampled_from((64, 128, 256, 512)))
@settings(max_examples=50, deadline=None)
def test_config_roundtrip(disc, choice):
    cfg = {"cont": 0.0, "disc": disc, "choice": choice}
    back = SPACE.to_config(SPACE.to_action(cfg))
    assert back["disc"] == disc
    assert back["choice"] == choice
    assert abs(back["cont"] - 0.0) < 1e-5


def test_out_of_range_action_clipped():
    cfg = SPACE.to_config([1.7, -0.3, 2.0])
    assert SPACE.validate(cfg)


def test_duplicate_names_rejected():
    with pytest.raises(ValueError):
        ParamSpace(specs=(ParamSpec("x", "discrete", 0, 1),
                          ParamSpec("x", "discrete", 0, 1)))


# ---------------------------------------------------------------------------
# Replay buffer (paper §II-D: limited size, FIFO)
# ---------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(1, 100))
@settings(max_examples=50, deadline=None)
def test_fifo_eviction(capacity, n_adds):
    buf = ReplayBuffer(capacity, state_dim=2, action_dim=1)
    for i in range(n_adds):
        buf.add(np.full(2, i, np.float32), np.zeros(1), float(i),
                np.zeros(2))
    assert len(buf) == min(capacity, n_adds)
    s, a, r, s2 = buf.as_arrays()
    # the retained rewards are exactly the most recent min(cap, n) values
    expected = set(range(max(0, n_adds - capacity), n_adds))
    assert set(int(x) for x in r) == expected


def test_sample_requires_data():
    buf = ReplayBuffer(4, 2, 1)
    with pytest.raises(ValueError):
        buf.sample(np.random.default_rng(0), 2)


def test_state_dict_roundtrip():
    buf = ReplayBuffer(4, 2, 1)
    for i in range(6):
        buf.add(np.ones(2) * i, np.ones(1), i, np.ones(2))
    d = buf.state_dict()
    buf2 = ReplayBuffer(4, 2, 1)
    buf2.load_state_dict(d)
    for x, y in zip(buf.as_arrays(), buf2.as_arrays()):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Scalarization / reward (paper §II-A, §II-B-5)
# ---------------------------------------------------------------------------

SPECS = {"t": MetricSpec("t", 0.0, 100.0), "i": MetricSpec("i", 0.0, 10.0)}


@given(st.floats(0, 100), st.floats(0, 10))
@settings(max_examples=100, deadline=None)
def test_objective_weighted_sum(t, i):
    sc = Scalarizer(weights={"t": 1.0, "i": 2.0}, specs=SPECS)
    expected = 1.0 * t / 100.0 + 2.0 * i / 10.0
    assert abs(sc.objective({"t": t, "i": i}) - expected) < 1e-6


@given(st.floats(1, 100), st.floats(1, 100))
@settings(max_examples=100, deadline=None)
def test_reward_sign_matches_improvement(prev_t, new_t):
    sc = Scalarizer(weights={"t": 1.0}, specs=SPECS)
    r = sc.reward({"t": prev_t}, {"t": new_t})
    if new_t > prev_t:
        assert r > 0
    elif new_t < prev_t:
        assert r < 0
    # proportional form: r = (new - prev) / prev in normalized units
    assert abs(r - (new_t - prev_t) / prev_t) < 1e-5


def test_norm_clips_outside_bounds():
    assert SPECS["t"].norm(-5.0) == 0.0
    assert SPECS["t"].norm(500.0) == 1.0
