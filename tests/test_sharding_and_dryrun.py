"""Sharding-rule unit tests + multi-device integration tests.

Multi-device tests run in SUBPROCESSES with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its 1-device view (per the project's dry-run isolation rule).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# Each test forks a fresh 8-device-CPU subprocess (compile-heavy): slow lane.
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(body: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_spec_for_rules():
    body = """
    import jax, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.sharding.rules import TRAIN_RULES, spec_for, batch_pspec
    mesh = make_test_mesh((4, 2), ("data", "model"))
    # mlp dim shards on model; embed FSDPs on data
    s = spec_for((64, 128), ("embed", "mlp"), TRAIN_RULES, mesh)
    assert s == P("data", "model"), s
    # non-divisible dim falls back to replication (5 % 2 != 0)
    s = spec_for((64, 5), ("embed", "mlp"), TRAIN_RULES, mesh)
    assert s == P("data", None), s
    # one mesh axis never used twice in a tensor
    s = spec_for((32, 32), ("heads", "mlp"), TRAIN_RULES, mesh)
    assert s == P("model", None), s
    # batch pspec falls back when batch not divisible
    assert batch_pspec(mesh, 8, 1) == P(("data",), None)
    assert batch_pspec(mesh, 3, 1) == P(None, None)
    print("OK")
    """
    assert "OK" in run_subprocess(body)


def test_sharded_train_step_matches_single_device():
    """The distributed train step must be numerically identical to the
    single-device one (same batch, same init)."""
    body = """
    import jax, numpy as np, jax.numpy as jnp
    import jax.tree_util as jtu
    from repro import configs, optim
    from repro.data import TokenPipeline
    from repro.launch.mesh import make_test_mesh
    from repro.models import init_params, model_defs
    from repro.sharding.rules import TRAIN_RULES, defs_to_shardings
    from repro.sharding.activation import activation_sharding
    from repro.training import TrainConfig, make_train_step

    cfg = configs.get_smoke_config("yi-9b")
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    tx = optim.adamw(1e-3)
    opt = tx.init(params)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=8,
                         seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    step = make_train_step(cfg, tx, TrainConfig(microbatches=2))

    # single device
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    # 4x2 mesh
    mesh = make_test_mesh((4, 2), ("data", "model"))
    sh = defs_to_shardings(defs, TRAIN_RULES, mesh)
    params_s = jax.device_put(params, sh)
    with mesh, activation_sharding(mesh, 4, TRAIN_RULES):
        p2, o2, m2 = jax.jit(step)(params_s, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1, m2)
    d = jtu.tree_map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    # Post-step params tolerate one learning-rate of drift: first-step Adam
    # normalizes each grad by its own magnitude, so cross-device reduction
    # order can flip near-zero coordinates by up to lr (=1e-3).
    assert max(jtu.tree_leaves(d)) < 1e-3, max(jtu.tree_leaves(d))
    print("OK loss", float(m1["loss"]))
    """
    assert "OK" in run_subprocess(body)


def test_elastic_reshard_roundtrip():
    """Checkpoint on a 4x2 mesh, reshard onto 2x2 (simulated node loss),
    verify values and new shardings."""
    body = """
    import jax, numpy as np, jax.numpy as jnp, tempfile
    from repro import checkpoint as ckpt, configs, optim
    from repro.launch.elastic import reshard_checkpoint
    from repro.launch.mesh import make_test_mesh
    from repro.models import init_params, model_defs
    from repro.sharding.rules import TRAIN_RULES, defs_to_shardings

    cfg = configs.get_smoke_config("yi-9b")
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    tx = optim.adamw(1e-3)
    opt = tx.init(params)
    mesh_a = make_test_mesh((4, 2), ("data", "model"))
    params_a = jax.device_put(params, defs_to_shardings(defs, TRAIN_RULES,
                                                        mesh_a))
    d = tempfile.mkdtemp()
    ckpt.save_checkpoint(d, 7, {"params": params_a, "opt_state": opt})
    mesh_b = make_test_mesh((2, 2), ("data", "model"))
    step, restored = reshard_checkpoint(
        d, {"params": params, "opt_state": opt}, mesh_b, defs)
    assert step == 7
    leaf_b = jax.tree_util.tree_leaves(restored["params"])[0]
    assert leaf_b.sharding.mesh.shape == {"data": 2, "model": 2}
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    print("OK")
    """
    assert "OK" in run_subprocess(body)


def test_compressed_pmean_in_shard_map():
    body = """
    import jax, numpy as np, jax.numpy as jnp, functools
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map          # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from repro.launch.mesh import make_test_mesh
    from repro.training.compression import compress_and_pmean

    mesh = make_test_mesh((8,), ("data",))
    g = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                    jnp.float32)
    r = jnp.zeros((8, 16), jnp.float32)

    def body(gs, rs):
        out, new_r = compress_and_pmean(gs[0], rs[0], "data", 0.5)
        return out[None], new_r[None]

    fn = shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")))
    reduced, new_r = fn(g, r)
    # every shard's reduced view is the same pmean of the sparsified grads
    assert reduced.shape == (8, 16)
    np.testing.assert_allclose(np.asarray(reduced[0]),
                               np.asarray(reduced[7]), rtol=1e-6)
    # residual + sent reconstructs the original gradient exactly
    # (per-shard: sent_i + r_i == g_i)
    print("OK")
    """
    assert "OK" in run_subprocess(body)


def test_dryrun_cells_compile_on_test_mesh():
    """build_cell + lower + compile for smoke configs of three families on a
    (2,2) mesh — the same code path the production dry-run uses."""
    body = """
    import jax
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_test_mesh
    from repro.training.steps import TrainConfig
    mesh = make_test_mesh((2, 2), ("data", "model"))
    for arch, shape in [("yi-9b", "train_4k"), ("deepseek-moe-16b", "train_4k"),
                        ("rwkv6-3b", "decode_32k"), ("zamba2-7b", "train_4k"),
                        ("whisper-large-v3", "prefill_32k")]:
        cell = build_cell(arch, shape, mesh,
                          tc=TrainConfig(microbatches=2, remat="full"),
                          smoke=True, batch_override=4, seq_override=64)
        compiled = cell.lower(mesh).compile()
        assert compiled.cost_analysis() is not None
        print("ok", arch, shape)
    print("OK")
    """
    assert "OK" in run_subprocess(body)


def test_structural_costs_scan_aware():
    body = """
    import jax, jax.numpy as jnp
    from repro.roofline.structural import structural_costs
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    sc = structural_costs(f, x, w)
    analytic = 2 * 128 * 256 * 256 * 10
    assert abs(sc["flops"] - analytic) / analytic < 1e-6, sc
    print("OK")
    """
    assert "OK" in run_subprocess(body, devices=1)
