"""Whole-episode megakernel (kernels/episode_fused.py, roofline/vmem.py) and
the async chunk-staging host runtime.

The pinned equivalence ladder (every bound measured before pinning):

  rung 1  megakernel (Pallas interpret) == its XLA twin, bitwise (maxulp=0):
          the twin IS the kernel body vmapped, so any gap would be a Pallas
          lowering bug;
  rung 2  megakernel through the full Tuner == the scan engine, both under
          ``REPRO_KERNELS=interpret`` (the comparable packed-learner path):
          decision trajectory EXACT and float fields bitwise (maxulp=0,
          measured 0 on the 2-D and the 8-D space for both modes);
  rung 3  megakernel == the pure-jnp oracle (``kernels.ref.
          episode_fused_ref``, jitted): decisions EXACT, episode outputs
          (env state, trace, buffer) <= 4 f32 ulps; the packed learner state
          compares at float32 resolution (``_assert_learner_close``) — the
          cross-formulation Adam-moment amplification documented in
          tests/test_ddpg_fused.py applies verbatim here.

Also pinned: mode=None keys — and IS, by cached-object identity — the exact
pre-megakernel program; composition refusals (guardrails / resilience /
cell sharing / obs masking / multi-device raise instead of silently
degrading); the roofline VMEM-fit check rejects oversized replay windows
with an actionable message; async chunk staging stays bitwise-pure
scheduling and reports its overlap efficiency.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DDPGConfig, FleetTuner, MagpieAgent
from repro.core.episode import _compiled_episode, last_fleet_run_stats
from repro.core.scalarization import metric_bounds
from repro.envs import LustreSimEnv, LustreSimV2
from repro.kernels.ddpg_fused import pack_params, packed_dims
from repro.kernels.episode_fused import (EpisodeKernelSpec, EpisodeOperands,
                                         episode_fused_learn,
                                         episode_fused_xla)
from repro.kernels.ops import episode_kernel_mode
from repro.kernels.ref import episode_fused_ref
from repro.roofline import (check_episode_vmem_fit, episode_vmem_plan,
                            suggest_max_capacity)

from tests.test_ddpg_fused import _assert_learner_close, _max_ulp
from tests.test_episode import _assert_bitwise_equal_runs, _tuner


# ---------------------------------------------------------------------------
# Operand builder: one session's episode inputs straight from a live agent
# ---------------------------------------------------------------------------

def _build(env_cls, seed=3, T=5, U=4, cap=8):
    env = env_cls("seq_write", seed=seed).to_model_env()
    cfg = DDPGConfig.for_env(env, updates_per_step=U)
    agent = MagpieAgent(cfg, seed=seed, warmup_steps=2, buffer_capacity=cap)
    dims = packed_dims(cfg.state_dim, cfg.action_dim, cfg.hidden)
    st = agent.state
    a_adam, c_adam = st.actor_opt[0], st.critic_opt[0]
    packed = pack_params(st.actor, st.critic, st.actor_targ, st.critic_targ,
                         a_adam.mu, a_adam.nu, c_adam.mu, c_adam.nu,
                         a_adam.count, c_adam.count, dims)
    k, m = cfg.state_dim, cfg.action_dim
    rng = np.random.default_rng(seed)
    use_warmup = np.zeros(T, bool)
    use_warmup[: min(2, T)] = True
    warmup = rng.uniform(size=(T, m)).astype(np.float32)
    noise = (rng.normal(size=(T, m)) * 0.1).astype(np.float32)
    lo, span = metric_bounds(env.metric_specs, env.state_metrics)
    w_vec = np.zeros(k, np.float32)
    w_vec[0] = 1.0
    param_leaves, param_def = jax.tree_util.tree_flatten(env.model.params)
    env_leaves, env_def = jax.tree_util.tree_flatten(env.model_state)
    op = EpisodeOperands(
        use_warmup=jnp.asarray(use_warmup), warmup=jnp.asarray(warmup),
        noise=jnp.asarray(noise), w_vec=jnp.asarray(w_vec),
        lo=jnp.asarray(lo), span=jnp.asarray(span),
        params=tuple(jnp.asarray(x) for x in param_leaves),
        env=tuple(jnp.asarray(x) for x in env_leaves),
        packed=tuple(packed),
        buffer=(jnp.zeros((cap, k)), jnp.zeros((cap, m)), jnp.zeros((cap,)),
                jnp.zeros((cap, k)), jnp.asarray(0, jnp.int32),
                jnp.asarray(0, jnp.int32)),
        learn_key=agent._learn_key,
        state_vec=jnp.full((k,), 0.4, jnp.float32),
        objective=jnp.asarray(0.4, jnp.float32))
    spec = EpisodeKernelSpec(step_fn=env.model.step_fn, space=env.param_space,
                             cfg=cfg, learn=True, num_updates=U, dims=dims,
                             param_treedef=param_def, env_treedef=env_def)
    return op, spec


def _one(tree):
    return jax.tree_util.tree_map(lambda x: np.asarray(x)[0], tree)


# ---------------------------------------------------------------------------
# Mode resolution + program identity
# ---------------------------------------------------------------------------

def test_episode_kernel_mode_parsing(monkeypatch):
    for off in ("", "off", "0", "none", "OFF"):
        monkeypatch.setenv("REPRO_MEGAKERNEL", off)
        assert episode_kernel_mode() is None
    monkeypatch.delenv("REPRO_MEGAKERNEL")
    assert episode_kernel_mode() is None
    for mode in ("xla", "pallas", "interpret"):
        monkeypatch.setenv("REPRO_MEGAKERNEL", mode)
        assert episode_kernel_mode() == mode
    monkeypatch.setenv("REPRO_MEGAKERNEL", "auto")
    expect = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert episode_kernel_mode() == expect
    monkeypatch.setenv("REPRO_MEGAKERNEL", "bogus")
    with pytest.raises(ValueError, match="REPRO_MEGAKERNEL"):
        episode_kernel_mode()


def _episode_args():
    env = LustreSimEnv("seq_write", seed=0).to_model_env()
    cfg = DDPGConfig.for_env(env, updates_per_step=2)
    agent = MagpieAgent(cfg, seed=0)
    return (env.model.step_fn, env.param_space, cfg, agent._actor_tx,
            agent._critic_tx, True, 2)


def test_mode_none_keys_the_exact_pre_megakernel_program(monkeypatch):
    """REPRO_MEGAKERNEL unset and =off key — and ARE, by cached-object
    identity — the same pre-megakernel program; an active mode compiles a
    different one."""
    args = _episode_args()
    monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)
    fn_unset = _compiled_episode(*args, fleet=True, devices=None)
    monkeypatch.setenv("REPRO_MEGAKERNEL", "off")
    fn_off = _compiled_episode(*args, fleet=True, devices=None)
    assert fn_unset is fn_off
    monkeypatch.setenv("REPRO_MEGAKERNEL", "xla")
    fn_mega = _compiled_episode(*args, fleet=True, devices=None)
    assert fn_mega is not fn_unset


# ---------------------------------------------------------------------------
# Rung 2: megakernel == scan engine through the Tuner (decisions EXACT,
# floats bitwise), 2-D and 8-D, interpret kernel and XLA twin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["xla", "interpret"])
@pytest.mark.parametrize("env_cls", [LustreSimEnv, LustreSimV2])
def test_megakernel_matches_scan_engine(env_cls, mode, monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)
    base = _tuner(env_cls, "scan").run(5)
    monkeypatch.setenv("REPRO_MEGAKERNEL", mode)
    mega = _tuner(env_cls, "scan").run(5)
    _assert_bitwise_equal_runs(base, mega, maxulp=0)


def test_megakernel_progressive_runs_match_scan(monkeypatch):
    """Resumable across run() calls exactly like the scan engine (learner
    state, FIFO, noise streams and env key chain all round-trip through the
    packed layout between runs)."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)
    base = _tuner(LustreSimEnv, "scan", seed=7)
    monkeypatch.setenv("REPRO_MEGAKERNEL", "xla")
    mega = _tuner(LustreSimEnv, "scan", seed=7)
    for steps in (3, 4):
        monkeypatch.delenv("REPRO_MEGAKERNEL", raising=False)
        rb = base.run(steps)
        monkeypatch.setenv("REPRO_MEGAKERNEL", "xla")
        rm = mega.run(steps)
        _assert_bitwise_equal_runs(rb, rm, maxulp=0)
    assert len(mega.history) == 7


# ---------------------------------------------------------------------------
# Rung 1 + 3: kernel vs XLA twin (bitwise) and vs the pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("env_cls", [LustreSimEnv, LustreSimV2])
def test_megakernel_bitwise_vs_xla_twin(env_cls):
    op, spec = _build(env_cls)
    opf = jax.tree_util.tree_map(lambda x: x[None], op)
    out_k = _one(episode_fused_learn(opf, spec=spec, interpret=True))
    out_x = _one(episode_fused_xla(opf, spec=spec))
    assert _max_ulp(out_k, out_x) == 0


@pytest.mark.parametrize("env_cls", [LustreSimEnv, LustreSimV2])
def test_megakernel_matches_oracle(env_cls):
    op, spec = _build(env_cls)
    opf = jax.tree_util.tree_map(lambda x: x[None], op)
    out_k = _one(episode_fused_learn(opf, spec=spec, interpret=True))
    ref = jax.jit(lambda o: episode_fused_ref(o, spec=spec))
    out_r = jax.tree_util.tree_map(np.asarray, ref(op))
    # decisions: action indices, restart encodings, key chain — EXACT
    np.testing.assert_array_equal(out_k.action_idx, out_r.action_idx)
    np.testing.assert_array_equal(out_k.restarts, out_r.restarts)
    np.testing.assert_array_equal(out_k.learn_key, out_r.learn_key)
    # episode outputs: the PR 3/4 engine-contract ulp bound (measured <= 1)
    for field in ("env", "buffer", "state_vec", "objective", "metrics",
                  "rewards", "objectives"):
        assert _max_ulp(getattr(out_k, field), getattr(out_r, field)) <= 4, \
            field
    # packed learner state: cross-formulation Adam tolerance (see module
    # docstring / tests.test_ddpg_fused._assert_learner_close)
    _assert_learner_close(out_k.packed, out_r.packed)


def test_store_before_learn_invariant():
    """Step t's transition lands in the FIFO BEFORE step t's learner phase:
    from an empty buffer, a single step's 96.. sampling universe is exactly
    {the just-stored transition}, and the Adam counters advance — pinned by
    exact agreement with the oracle, which stores first by construction."""
    op, spec = _build(LustreSimEnv, T=1, U=3, cap=4)
    opf = jax.tree_util.tree_map(lambda x: x[None], op)
    out_k = _one(episode_fused_learn(opf, spec=spec, interpret=True))
    out_r = jax.tree_util.tree_map(
        np.asarray, jax.jit(lambda o: episode_fused_ref(o, spec=spec))(op))
    assert int(out_k.buffer[5]) == 1          # size: the stored transition
    assert int(out_k.buffer[4]) == 1          # next_slot advanced
    counts = np.asarray(out_k.packed[4])
    np.testing.assert_array_equal(counts, [3, 3])  # U updates ran on it
    np.testing.assert_array_equal(out_k.action_idx, out_r.action_idx)
    _assert_learner_close(out_k.packed, out_r.packed)


def test_padded_lanes_stay_zero_fixed_point():
    """pack_params zeroes the padded lanes; the episode kernel's masked
    GEMMs and the act-mask keep them an exact zero fixed point across all T
    steps and every learner update."""
    op, spec = _build(LustreSimV2, T=4, U=4)
    dims = spec.dims
    opf = jax.tree_util.tree_map(lambda x: x[None], op)
    out_k = _one(episode_fused_learn(opf, spec=spec, interpret=True))
    weights, biases, mom_w, mom_b, _ = out_k.packed
    sizes = (dims.actor_sizes, dims.critic_sizes,
             dims.actor_sizes, dims.critic_sizes)
    w_real = np.zeros(np.asarray(weights).shape, bool)
    b_real = np.zeros(np.asarray(biases).shape, bool)
    for i, sz in enumerate(sizes):
        for layer, (fin, fout) in enumerate(zip(sz[:-1], sz[1:])):
            w_real[i, layer, :fin, :fout] = True
            b_real[i, layer, :fout] = True
    assert np.all(np.asarray(weights)[~w_real] == 0)
    assert np.all(np.asarray(biases)[~b_real] == 0)
    # Adam moments share the nets' real regions (mom[net_pair, mu/nu])
    for pair, (wi, _) in enumerate(((0, 1), (1, 0))):
        for j in range(2):
            assert np.all(np.asarray(mom_w)[pair, j][~w_real[wi]] == 0)
            assert np.all(np.asarray(mom_b)[pair, j][~b_real[wi]] == 0)


# ---------------------------------------------------------------------------
# Composition refusals (megakernel refuses instead of silently degrading)
# ---------------------------------------------------------------------------

def test_megakernel_composition_refusals(monkeypatch):
    from repro.core.guardrails import DeploymentPolicy
    from repro.core.resilience import ResiliencePolicy
    from repro.core.sharing import SharingConfig

    monkeypatch.setenv("REPRO_MEGAKERNEL", "xla")
    args = _episode_args()
    with pytest.raises(ValueError, match="REPRO_MEGAKERNEL=off"):
        _compiled_episode(*args, fleet=True, devices=None,
                          policy=DeploymentPolicy(min_gain=0.01))
    with pytest.raises(ValueError, match="REPRO_MEGAKERNEL=off"):
        _compiled_episode(*args, fleet=True, devices=None,
                          resilience=ResiliencePolicy())
    with pytest.raises(ValueError, match="REPRO_MEGAKERNEL=off"):
        _compiled_episode(*args, fleet=True, devices=None,
                          sharing=SharingConfig(shared_replay=True),
                          cell_size=2)
    with pytest.raises(ValueError, match="observation masking"):
        _compiled_episode(*args, fleet=True, devices=None,
                          obs_mask=(1.0, 0.0, 1.0))
    dev = jax.devices()[0]
    with pytest.raises(ValueError, match="single-device"):
        _compiled_episode(*args, fleet=True, devices=(dev, dev))


# ---------------------------------------------------------------------------
# Roofline VMEM-fit check
# ---------------------------------------------------------------------------

_FIT_KW = dict(steps=5, state_dim=8, action_dim=8, hidden=(64, 64),
               num_updates=96, batch_size=16, pad=128)


def test_vmem_fit_rejects_oversized_capacity():
    with pytest.raises(ValueError) as err:
        check_episode_vmem_fit(chunk=8, capacity=300_000, **_FIT_KW)
    msg = str(err.value)
    assert "replay_window" in msg
    assert "shrink buffer capacity" in msg
    assert "REPRO_MEGAKERNEL=off" in msg
    assert "chunk=8" in msg  # names the launch the caller asked for


def test_vmem_fit_accepts_and_suggests():
    plan = check_episode_vmem_fit(chunk=8, capacity=64, **_FIT_KW)
    assert plan["fits"]
    cap = suggest_max_capacity(**_FIT_KW)
    assert cap > 64
    assert episode_vmem_plan(capacity=cap, **_FIT_KW)["fits"]
    assert not episode_vmem_plan(capacity=cap + 1000, **_FIT_KW)["fits"]


def test_megakernel_rejects_oversized_episode_end_to_end():
    op, spec = _build(LustreSimEnv, cap=300_000)
    opf = jax.tree_util.tree_map(lambda x: x[None], op)
    with pytest.raises(ValueError, match="does not fit in VMEM"):
        episode_fused_learn(opf, spec=spec, interpret=True)


# ---------------------------------------------------------------------------
# Async chunk staging: stats recorded, scheduling stays bitwise-pure
# ---------------------------------------------------------------------------

def _staging_fleet(overlap):
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=4)
    f = FleetTuner.from_grid(
        ["seq_write"], [{"throughput": 1.0}], [0, 1, 2, 3],
        engine="scan", ddpg_config=cfg, eval_runs=1, warmup_steps=3,
        chunk=2)
    f.overlap = overlap
    return f


def test_async_staging_stats_and_bitwise_purity():
    r_off = _staging_fleet(False).run(4)
    st_off = last_fleet_run_stats()["staging"]
    assert st_off["async"] is False
    r_on = _staging_fleet(True).run(4)
    st_on = last_fleet_run_stats()["staging"]
    assert st_on["async"] is True
    assert st_on["stage_seconds"] > 0.0
    assert 0.0 <= st_on["overlap_efficiency"] <= 1.0
    assert st_on["stage_wait_seconds"] >= 0.0
    # async staging + async drain prefetch are pure scheduling: bitwise
    for a, b in zip(r_on.results, r_off.results):
        _assert_bitwise_equal_runs(a, b, maxulp=0)


def test_memory_plan_counts_inflight_staging_chunk():
    """overlap_device_bytes bounds the ASYNC schedule: computing chunk k +
    staged-in-flight k+1 + draining k-1 = three chunks, not two."""
    from repro.core.fleet import memory_plan
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"))
    plan = memory_plan(cfg, LustreSimEnv("seq_write").param_space,
                       sessions=64, steps=5, chunk=16)
    assert plan["overlap_device_bytes"] == 3 * plan["chunk_device_bytes"]


# ---------------------------------------------------------------------------
# benchmarks/run.py CLI (satellite: --list + unknown --only)
# ---------------------------------------------------------------------------

def _run_bench_cli(*argv):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *argv],
        capture_output=True, text=True, cwd=root, env=env, timeout=300)


def test_bench_run_list_prints_targets():
    r = _run_bench_cli("--list")
    assert r.returncode == 0, r.stderr
    for name in ("megakernel", "scaling", "fleet"):
        assert name in r.stdout


def test_bench_run_unknown_only_exits_nonzero():
    r = _run_bench_cli("--only", "not-a-bench", "--no-bench-json")
    assert r.returncode == 2
    assert "not-a-bench" in r.stderr
    assert "megakernel" in r.stderr  # the error lists valid targets
