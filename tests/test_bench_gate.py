"""Benchmark-regression gate (benchmarks/regression_gate.py) and the
BENCH_<n>.json output-dir plumbing (benchmarks/run.py).

The gate's contract: a synthetic 30%-slower point MUST trip it (exit 1 /
ok=False), while anything inside the measured noise band — including a
modest improvement — MUST pass. The writer's contract: ``--output-dir``
numbers BENCH files against the target directory, never against (or into)
the committed repo-root trajectory.
"""

import json

import pytest

from benchmarks.common import ESTABLISHED_NOISE_BAND_REL
from benchmarks.regression_gate import evaluate_gate
from benchmarks.run import _write_bench_json


def _current(median, noise_band=ESTABLISHED_NOISE_BAND_REL):
    return {"median": median, "noise_band": noise_band}


def test_synthetic_regression_trips_the_gate():
    prev = 60.0
    verdict = evaluate_gate(_current(prev * 0.70), prev, "BENCH_2.json")
    assert verdict["ok"] is False
    assert verdict["comparison"]["label"] == "regression"
    assert verdict["comparison"]["ratio"] == pytest.approx(0.70)


def test_within_noise_band_passes():
    prev = 60.0
    # both edges of the +-14% established band are noise, not regressions
    for ratio in (1.0 - ESTABLISHED_NOISE_BAND_REL + 1e-6, 1.0,
                  1.0 + ESTABLISHED_NOISE_BAND_REL - 1e-6):
        verdict = evaluate_gate(_current(prev * ratio), prev, "BENCH_2.json")
        assert verdict["ok"] is True
        assert verdict["comparison"]["label"] == "within_noise"


def test_improvement_passes_not_fails():
    verdict = evaluate_gate(_current(80.0), 60.0, "BENCH_2.json")
    assert verdict["ok"] is True
    assert verdict["comparison"]["label"] == "improvement"


def test_gate_uses_the_measured_noise_band():
    # a 20% dip with a 25% measured band is noise; with the 14% floor it
    # would have been a regression — the gate must respect the wider band
    verdict = evaluate_gate(_current(48.0, noise_band=0.25), 60.0, "B.json")
    assert verdict["ok"] is True
    narrow = evaluate_gate(_current(48.0, noise_band=0.14), 60.0, "B.json")
    assert narrow["ok"] is False


def test_gate_cli_vacuous_pass_without_history(tmp_path, monkeypatch):
    import benchmarks.fleet_throughput as ft
    from benchmarks import regression_gate
    monkeypatch.setattr(ft, "_previous_bench", lambda: None)
    assert regression_gate.main([]) == 0


def test_gate_cli_fails_on_regression_json(tmp_path, monkeypatch):
    import benchmarks.fleet_throughput as ft
    from benchmarks import regression_gate
    monkeypatch.setattr(
        ft, "_previous_bench",
        lambda: {"fleet_session_steps_per_sec": 60.0, "_file": "BENCH_2.json"})
    slow = tmp_path / "BENCH_0.json"
    slow.write_text(json.dumps({
        "quick": False, "fleet_session_steps_per_sec": 42.0,
        "noise_band": 0.14, "scaling": []}))
    assert regression_gate.main(["--bench-json", str(slow)]) == 1

    ok = tmp_path / "BENCH_1.json"
    ok.write_text(json.dumps({
        "quick": False, "fleet_session_steps_per_sec": 58.0,
        "noise_band": 0.14, "scaling": []}))
    assert regression_gate.main(["--bench-json", str(ok)]) == 0

    quick = tmp_path / "BENCH_2.json"
    quick.write_text(json.dumps({
        "quick": True, "fleet_session_steps_per_sec": 9.0}))
    assert regression_gate.main(["--bench-json", str(quick)]) == 2


def test_gate_cli_exit2_on_unusable_bench_json(tmp_path, monkeypatch,
                                               capsys):
    """Every unusable --bench-json shape exits 2 with a stderr diagnostic —
    never 1 (exit 2 means 'could not gate', not 'regressed') and never a
    silent 0."""
    import benchmarks.fleet_throughput as ft
    from benchmarks import regression_gate
    monkeypatch.setattr(
        ft, "_previous_bench",
        lambda: {"fleet_session_steps_per_sec": 60.0, "_file": "BENCH_2.json"})

    cases = {
        "missing.json": None,                       # unreadable: never written
        "malformed.json": "{not json",              # JSONDecodeError
        "empty.json": "",                           # empty file is not JSON
        "list.json": json.dumps([1, 2, 3]),         # not an object
        "no_field.json": json.dumps(                # missing the metric
            {"quick": False, "noise_band": 0.14}),
    }
    for name, content in cases.items():
        path = tmp_path / name
        if content is not None:
            path.write_text(content)
        assert regression_gate.main(["--bench-json", str(path)]) == 2, name
        captured = capsys.readouterr()
        assert "regression-gate:" in captured.err, name


def test_gate_cli_band_fallback_on_empty_scaling(tmp_path, monkeypatch):
    """A full-mode point with no top-level band and an EMPTY scaling list
    falls back to the default band instead of raising (regression: bare
    max() over an empty generator)."""
    import benchmarks.fleet_throughput as ft
    from benchmarks import regression_gate
    monkeypatch.setattr(
        ft, "_previous_bench",
        lambda: {"fleet_session_steps_per_sec": 60.0, "_file": "BENCH_2.json"})
    p = tmp_path / "BENCH_0.json"
    p.write_text(json.dumps({
        "quick": False, "fleet_session_steps_per_sec": 58.0, "scaling": []}))
    assert regression_gate.main(["--bench-json", str(p)]) == 0
    # and a scaling-derived band is still honored when present
    p.write_text(json.dumps({
        "quick": False, "fleet_session_steps_per_sec": 48.0,
        "scaling": [{"noise_band": 0.25}]}))
    assert regression_gate.main(["--bench-json", str(p)]) == 0


# ---------------------------------------------------------------------------
# shared-experience acceptance is honored by the gate
# ---------------------------------------------------------------------------

def _se_point(accept_pass, sps=60.0):
    return {"quick": False, "fleet_session_steps_per_sec": sps,
            "noise_band": 0.14, "scaling": [],
            "shared_experience": {"acceptance": {
                "pass": accept_pass, "steps_ratio": 0.9 if not accept_pass
                else 0.59, "steps_ratio_max": 0.7,
                "bytes_ratio": 2.0, "bytes_ratio_min": 2.0}}}


def test_gate_fails_failed_shared_experience_acceptance(tmp_path,
                                                        monkeypatch,
                                                        capsys):
    """A BENCH point whose shared-experience acceptance failed exits 1 even
    when its throughput is squarely within the noise band — the gate
    enforces BOTH trajectories."""
    import benchmarks.fleet_throughput as ft
    from benchmarks import regression_gate
    monkeypatch.setattr(
        ft, "_previous_bench",
        lambda: {"fleet_session_steps_per_sec": 60.0, "_file": "BENCH_2.json"})
    bad = tmp_path / "BENCH_0.json"
    bad.write_text(json.dumps(_se_point(accept_pass=False)))
    assert regression_gate.main(["--bench-json", str(bad)]) == 1
    assert "shared-experience" in capsys.readouterr().err

    good = tmp_path / "BENCH_1.json"
    good.write_text(json.dumps(_se_point(accept_pass=True)))
    assert regression_gate.main(["--bench-json", str(good)]) == 0
    # a point with no shared_experience entry gates on throughput alone
    plain = tmp_path / "BENCH_2.json"
    plain.write_text(json.dumps({
        "quick": False, "fleet_session_steps_per_sec": 58.0,
        "noise_band": 0.14, "scaling": []}))
    assert regression_gate.main(["--bench-json", str(plain)]) == 0


def test_committed_bench4_point_passes_the_gate():
    """The BENCH_4.json this PR commits must itself clear the gate it
    extends (acceptance pass recorded, throughput within band)."""
    import os
    from benchmarks import regression_gate
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_4.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_4.json not present")
    with open(path) as f:
        point = json.load(f)
    acc = point["shared_experience"]["acceptance"]
    assert acc["pass"] is True
    assert acc["steps_ratio"] <= acc["steps_ratio_max"]
    assert acc["bytes_ratio"] >= acc["bytes_ratio_min"]
    assert regression_gate.main(["--bench-json", path]) == 0


# ---------------------------------------------------------------------------
# BENCH_<n>.json --output-dir numbering (benchmarks/run.py)
# ---------------------------------------------------------------------------

def test_output_dir_numbering_is_local_to_the_dir(tmp_path):
    out = tmp_path / "bench-out"
    # numbering starts at 0 in a fresh dir (repo root already has BENCH_0..)
    p0 = _write_bench_json({"benchmark": "x", "v": 1}, root=str(out))
    assert p0 == str(out / "BENCH_0.json")
    # a POPULATED output dir appends after its own highest index
    p1 = _write_bench_json({"benchmark": "x", "v": 2}, root=str(out))
    assert p1 == str(out / "BENCH_1.json")
    with open(p1) as f:
        assert json.load(f)["v"] == 2
    # the committed trajectory was never touched
    assert sorted(out.iterdir()) == [out / "BENCH_0.json",
                                     out / "BENCH_1.json"]


def test_output_dir_skips_existing_indices(tmp_path):
    (tmp_path / "BENCH_0.json").write_text("{}")
    (tmp_path / "BENCH_1.json").write_text("{}")
    p = _write_bench_json({"benchmark": "x"}, root=str(tmp_path))
    assert p == str(tmp_path / "BENCH_2.json")
