"""Docs lane: the documentation cannot rot.

Every fenced ```python block in README.md and docs/*.md is executed (so the
paper-mapping and architecture docs stay runnable against the real API), and
every relative markdown link must resolve to a file in the repo. Bash fences
are not executed — they document shell entry points covered by CI jobs.
"""

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def _snippets():
    out = []
    for path in DOC_FILES:
        for i, m in enumerate(_FENCE.finditer(path.read_text())):
            out.append(pytest.param(
                path, m.group(1),
                id=f"{path.relative_to(ROOT)}:{i}"))
    return out


def test_docs_exist_and_have_snippets():
    assert (ROOT / "docs" / "paper_mapping.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()
    assert len(_snippets()) >= 2  # README + architecture carry runnable code


@pytest.mark.parametrize("path,code", _snippets())
def test_doc_snippet_runs(path, code):
    """Each fenced python block is a self-contained program (tiny budgets)."""
    exec(compile(code, f"{path.name}[snippet]", "exec"), {"__name__": "__docs__"})


@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(path):
    text = path.read_text()
    for m in _LINK.finditer(text):
        target = m.group(1)
        if re.match(r"^[a-z]+://|^mailto:", target):
            continue  # external
        resolved = (path.parent / target).resolve()
        # CI badge links (../../actions/...) point outside the checkout by
        # design; everything else must exist in-repo.
        if ROOT not in resolved.parents and resolved != ROOT:
            continue
        assert resolved.exists(), f"{path.name}: broken link {target}"


def test_paper_mapping_names_real_modules_and_tests():
    """Every `module.py` path and test file the mapping cites must exist."""
    text = (ROOT / "docs" / "paper_mapping.md").read_text()
    for mod in set(re.findall(r"`((?:core|envs|benchmarks)/[\w/]+\.py)`", text)):
        assert (ROOT / "src" / "repro" / mod).exists() or \
            (ROOT / mod).exists(), f"mapping cites missing module {mod}"
    for test_ref in set(re.findall(r"`(tests/[\w]+\.py)(?:::[\w:]+)?`", text)):
        assert (ROOT / test_ref).exists(), f"mapping cites missing {test_ref}"
    # cited test functions exist in their files
    for file, func in set(re.findall(r"`(tests/[\w]+\.py)::(\w+)`", text)):
        assert func in (ROOT / file).read_text(), \
            f"{file} does not define {func}"
