"""Cross-session experience sharing (core/sharing.py + the cell episode
engine + the grouped replay buffer + the sharing service).

Load-bearing properties:
  * sharing OFF is off by EXECUTABLE IDENTITY — a fleet built with a fully-
    off ``SharingConfig`` runs the very same cached compiled program as a
    fleet that never heard of sharing, and the results are bitwise equal;
  * every sharing splice is an exact identity at the degenerate point: a
    shared-replay cell of ONE session and an averaging cell that never
    fires reproduce the independent fleet's decision trajectory on the 2-D
    and the 8-D space;
  * the merged cell FIFO interleaves member transitions in session order —
    the grouped buffer after a shared warmup equals the independent
    buffers' rows woven together, bit for bit;
  * chunking stays pure scheduling under sharing (cell-aligned chunks ==
    monolithic);
  * the DIAL observation-scope mode masks ONLY the learner's view: a
    scoped fleet-of-1 equals a scoped single ``Tuner``, and scope
    resolution rejects unknown scopes;
  * ``BatchedReplayBuffer(groups=...)`` validates cell topology and
    merges/samples per group;
  * ``memory_plan`` models merged cell buffers and still matches the live
    allocations (including bf16 storage under the host store);
  * the ``FleetService`` binds cells at boundaries, matches the static
    sharing fleet exactly, and checkpoint/restore of a sharing service —
    merged windows included — is bitwise-continue.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DDPGConfig,
    FleetService,
    FleetTuner,
    MagpieAgent,
    Scalarizer,
    SharingConfig,
    Tuner,
    last_fleet_run_stats,
    memory_plan,
    normalize_sharing,
)
from repro.core.replay_buffer import BatchedReplayBuffer
from repro.envs import LustreSimEnv, LustreSimV2, ModelEnv, SyntheticSurfaceModel
from repro.envs.metrics import scope_mask

from tests.test_episode import _assert_bitwise_equal_runs
from tests.test_service import _assert_exact_histories

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

W = {"throughput": 1.0}


def _fleet(env_cls=LustreSimEnv, seeds=(0, 1), workloads=("seq_write",),
           sharing=None, chunk=None, updates=4, warmup=3, capacity=16):
    cfg = DDPGConfig.for_env(env_cls(workloads[0]), updates_per_step=updates)
    return FleetTuner.from_grid(
        list(workloads), [W], list(seeds), env_cls=env_cls, engine="scan",
        ddpg_config=cfg, eval_runs=1, warmup_steps=warmup,
        buffer_capacity=capacity, chunk=chunk, sharing=sharing)


# ---------------------------------------------------------------------------
# SharingConfig normalization
# ---------------------------------------------------------------------------

def test_normalize_sharing_canonicalizes_off_to_none():
    assert normalize_sharing(None) is None
    assert normalize_sharing(SharingConfig()) is None
    assert normalize_sharing(SharingConfig(avg_every=math.inf)) is None
    assert normalize_sharing(SharingConfig(avg_every=0)) is None
    # opt-state averaging without an averaging cadence is no mode at all
    assert normalize_sharing(SharingConfig(avg_opt_state=True)) is None
    with pytest.raises(TypeError):
        normalize_sharing({"shared_replay": True})


def test_normalize_sharing_sorts_scopes_for_hash_identity():
    a = normalize_sharing(SharingConfig(observation_scopes=("OST", "OSC")))
    b = normalize_sharing(SharingConfig(observation_scopes=("OSC", "OST")))
    assert a == b and a.observation_scopes == ("OSC", "OST")
    on = normalize_sharing(SharingConfig(shared_replay=True, avg_every=4.0))
    assert on.shared_replay and on.avg_every == 4 and on.averaging


# ---------------------------------------------------------------------------
# Sharing off == off by executable identity (the acceptance pin)
# ---------------------------------------------------------------------------

def test_sharing_off_is_the_same_executable_and_bitwise():
    base = _fleet().run(5)
    program = last_fleet_run_stats()["program"]
    off = _fleet(sharing=SharingConfig(avg_every=math.inf)).run(5)
    stats = last_fleet_run_stats()
    assert stats["program"] is program  # SAME cached executable, not a twin
    assert stats["sharing"] is None and stats["cell_size"] == 1
    for ra, rb in zip(base.results, off.results):
        _assert_bitwise_equal_runs(ra, rb, maxulp=0)
        _assert_exact_histories(ra.history, rb.history)


# ---------------------------------------------------------------------------
# Degenerate cells == independent fleet (2-D and 8-D)
# ---------------------------------------------------------------------------

def _check_degenerate_parity(env_cls, sharing, seeds, workloads, maxulp):
    ind = _fleet(env_cls, seeds=seeds, workloads=workloads).run(6)
    shr = _fleet(env_cls, seeds=seeds, workloads=workloads,
                 sharing=sharing).run(6)
    assert last_fleet_run_stats()["sharing"] == normalize_sharing(sharing)
    for ra, rb in zip(ind.results, shr.results):
        _assert_bitwise_equal_runs(ra, rb, maxulp=maxulp)


@pytest.mark.parametrize("env_cls", [LustreSimEnv, LustreSimV2])
def test_shared_replay_cell_of_one_matches_independent(env_cls):
    """A one-session cell's merged window IS its private window: the
    cumsum/scatter splices collapse to the independent FIFO write and the
    merged-window sampling to per-session sampling. The cell program is a
    different executable (grouped operands), so cross-program codegen gets
    the usual few-ulp float latitude; decisions must be exact."""
    _check_degenerate_parity(
        env_cls, SharingConfig(shared_replay=True), (0,),
        ("seq_write", "random_rw"), maxulp=4)


@pytest.mark.parametrize("env_cls", [LustreSimEnv, LustreSimV2])
def test_averaging_that_never_fires_matches_independent(env_cls):
    """avg_every longer than the run: the cell mean is computed but never
    applied (`avg_now` stays False), so trajectories match the independent
    fleet; avg_every=inf normalizes to sharing=None entirely."""
    _check_degenerate_parity(
        env_cls, SharingConfig(avg_every=10_000, avg_opt_state=True),
        (0, 1), ("seq_write",), maxulp=4)


# ---------------------------------------------------------------------------
# Merged FIFO: session-order interleave of member transitions, bit for bit
# ---------------------------------------------------------------------------

def test_merged_window_interleaves_member_transitions():
    steps, k = 3, 2  # all-warmup steps: both arms run identical actions
    ind = _fleet(seeds=(0, 1), warmup=4)
    shr = _fleet(seeds=(0, 1), warmup=4,
                 sharing=SharingConfig(shared_replay=True))
    ind.run(steps), shr.run(steps)

    (ms, ma, mr, ms2), nxt, sizes = shr.agent.buffer.grouped_storage()
    (bs, ba, br, bs2), isizes = ind.agent.buffer.storage()
    assert ms.shape[0] == 1 and bs.shape[0] == 2
    assert int(sizes[0]) == steps * k and int(nxt[0]) == steps * k
    for t in range(steps):
        for j in range(k):  # env step t, member j -> merged slot t*k + j
            np.testing.assert_array_equal(ms[0, t * k + j], bs[j, t])
            np.testing.assert_array_equal(ma[0, t * k + j], ba[j, t])
            np.testing.assert_array_equal(mr[0, t * k + j], br[j, t])
            np.testing.assert_array_equal(ms2[0, t * k + j], bs2[j, t])


# ---------------------------------------------------------------------------
# Chunking stays pure scheduling under sharing
# ---------------------------------------------------------------------------

def test_chunked_matches_monolithic_under_sharing():
    sharing = SharingConfig(shared_replay=True, avg_every=2)
    mono = _fleet(seeds=(0, 1), workloads=("seq_write", "random_rw"),
                  sharing=sharing).run(6)
    chunked = _fleet(seeds=(0, 1), workloads=("seq_write", "random_rw"),
                     sharing=sharing, chunk=2).run(6)
    stats = last_fleet_run_stats()
    assert stats["chunk"] == 2 and stats["num_chunks"] == 2
    for rm, rc in zip(mono.results, chunked.results):
        _assert_bitwise_equal_runs(rm, rc, maxulp=32)  # cross-width codegen


def test_chunk_is_rounded_up_to_whole_cells():
    sharing = SharingConfig(shared_replay=True)
    fleet = _fleet(seeds=(0, 1, 2), workloads=("seq_write", "random_rw"),
                   sharing=sharing, chunk=2)
    fleet.run(2)
    assert last_fleet_run_stats()["chunk"] == 3  # cells of 3 never split


def test_sharing_needs_whole_cells_and_the_scan_engine():
    env = LustreSimEnv("seq_write")
    cfg = DDPGConfig.for_env(env, updates_per_step=2)
    with pytest.raises(ValueError, match="scan"):
        FleetTuner.from_grid(["seq_write"], [W], [0, 1], env_cls=LustreSimEnv,
                             engine="host", ddpg_config=cfg,
                             sharing=SharingConfig(shared_replay=True))


# ---------------------------------------------------------------------------
# DIAL observation scopes: the learner's view, nothing else
# ---------------------------------------------------------------------------

def test_scope_mask_resolves_compound_scopes_and_rejects_unknown():
    env = LustreSimV2("seq_write")
    for scopes in (("OSC",), ("MDS",)):
        mask = scope_mask(env.metric_specs, env.state_metrics, scopes)
        names = [n for n, v in zip(env.state_metrics, mask) if v]
        assert 0 < len(names) < len(env.state_metrics)
        for n in names:  # '&'-joined scopes are visible to every part
            assert set(scopes) & set(env.metric_specs[n].scope.split("&"))
    with pytest.raises(ValueError, match="unknown metric scopes"):
        scope_mask(env.metric_specs, env.state_metrics, ["QUORUM"])


def test_scoped_fleet_of_one_matches_scoped_tuner():
    seed, steps = 5, 8
    sharing = SharingConfig(observation_scopes=("OSC",))

    env = LustreSimV2("seq_write", seed=seed).to_model_env()
    scal = Scalarizer(weights=W, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=4),
                        seed=seed, warmup_steps=3, buffer_capacity=16)
    single = Tuner(env, scal, agent, engine="scan", eval_runs=1,
                   observation_scopes=("OSC",)).run(steps)

    cfg = DDPGConfig.for_env(LustreSimV2("seq_write"), updates_per_step=4)
    fleet = FleetTuner.from_grid(
        ["seq_write"], [W], [seed], env_cls=LustreSimV2, engine="scan",
        ddpg_config=cfg, eval_runs=1, warmup_steps=3, buffer_capacity=16,
        sharing=sharing)
    got = fleet.run(steps).results[0]
    _assert_bitwise_equal_runs(single, got, maxulp=4)


def test_scoped_tuner_differs_from_full_state_tuner():
    def run(scopes):
        env = LustreSimV2("seq_write", seed=2).to_model_env()
        scal = Scalarizer(weights=W, specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=4),
                            seed=2, warmup_steps=2, buffer_capacity=16)
        return Tuner(env, scal, agent, engine="scan", eval_runs=1,
                     observation_scopes=scopes).run(10)

    full, scoped = run(None), run(("OSC",))
    assert any(h.config != g.config
               for h, g in zip(full.history, scoped.history))


def test_observation_scopes_validation():
    env = LustreSimV2("seq_write", seed=0).to_model_env()
    scal = Scalarizer(weights=W, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env))
    with pytest.raises(ValueError, match="scan"):
        Tuner(env, scal, agent, engine="host",
              observation_scopes=("OSC",))


# ---------------------------------------------------------------------------
# Grouped replay buffer: topology validation + merge semantics
# ---------------------------------------------------------------------------

def test_grouped_buffer_validates_cell_topology():
    with pytest.raises(ValueError, match="one group per session"):
        BatchedReplayBuffer(3, 4, 2, 1, groups=[0, 0])
    with pytest.raises(ValueError, match="consecutive"):
        BatchedReplayBuffer(2, 4, 2, 1, groups=[0, 2])
    with pytest.raises(ValueError, match="contiguous"):
        BatchedReplayBuffer(4, 4, 2, 1, groups=[0, 1, 0, 1])


def test_grouped_buffer_merges_adds_and_expands_views():
    buf = BatchedReplayBuffer(4, 8, 2, 1, groups=[0, 0, 1, 1],
                              storage_backend="host")
    for t in range(3):
        v = np.arange(4, dtype=np.float32) + 10 * t
        buf.add(np.stack([v, v]).T, v[:, None], v, np.stack([v, v]).T)
    (gs, _, gr, _), nxt, sizes = buf.grouped_storage()
    assert gs.shape == (2, 8, 2) and list(nxt) == [6, 6]
    assert list(sizes) == [6, 6] and len(buf) == 6
    # session order within the group, env-step major: [t0s0, t0s1, t1s0...]
    np.testing.assert_array_equal(gr[0, :6], [0, 1, 10, 11, 20, 21])
    np.testing.assert_array_equal(gr[1, :6], [2, 3, 12, 13, 22, 23])
    # the per-session expansion: every member sees its group's window
    (es, _, er, _), esizes = buf.storage()
    assert es.shape == (4, 8, 2) and list(esizes) == [6, 6, 6, 6]
    np.testing.assert_array_equal(er[0], er[1])
    np.testing.assert_array_equal(er[2], er[3])
    assert not np.array_equal(er[0], er[2])


def test_grouped_buffer_fifo_wraps_per_group():
    buf = BatchedReplayBuffer(2, 4, 1, 1, groups=[0, 0],
                              storage_backend="host")
    for t in range(3):  # 6 adds into 4 slots: first 2 evicted
        v = np.array([2 * t, 2 * t + 1], np.float32)
        buf.add(v[:, None], v[:, None], v, v[:, None])
    (_, _, gr, _), nxt, sizes = buf.grouped_storage()
    assert list(sizes) == [4] and list(nxt) == [2]
    np.testing.assert_array_equal(gr[0], [4, 5, 2, 3])  # wrapped FIFO


def test_grouped_buffer_set_storage_roundtrip():
    buf = BatchedReplayBuffer(4, 4, 2, 1, groups=[0, 0, 1, 1],
                              storage_backend="host")
    v = np.ones((4, 2), np.float32)
    buf.add(v, v[:, :1], v[:, 0], v)
    (s, a, r, s2), nxt, sizes = buf.grouped_storage()
    twin = BatchedReplayBuffer(4, 4, 2, 1, groups=[0, 0, 1, 1],
                               storage_backend="host")
    twin.set_storage(s, a, r, s2, nxt, sizes)
    (ts, _, tr, _), tn, tsz = twin.grouped_storage()
    np.testing.assert_array_equal(ts, s)
    np.testing.assert_array_equal(tr, r)
    assert list(tn) == list(nxt) and list(tsz) == list(sizes)


# ---------------------------------------------------------------------------
# memory_plan models merged cell buffers (and matches live under bf16)
# ---------------------------------------------------------------------------

def test_memory_plan_divides_replay_bytes_by_cell_size():
    env = LustreSimV2("seq_write")
    cfg = DDPGConfig.for_env(env)
    kw = dict(sessions=8, steps=8, capacity=64)
    ind = memory_plan(cfg, env.param_space, **kw)
    mrg = memory_plan(cfg, env.param_space, cell_size=4, **kw)
    assert (mrg["per_session"]["replay_bytes"]
            == ind["per_session"]["replay_bytes"] // 4)
    assert mrg["cell_size"] == 4
    with pytest.raises(ValueError, match="whole cells"):
        memory_plan(cfg, env.param_space, sessions=6, steps=8, cell_size=4)


def test_fleet_memory_plan_matches_live_under_sharing_and_bf16():
    cfg = DDPGConfig.for_env(LustreSimV2("seq_write"), updates_per_step=2)
    fleet = FleetTuner.from_grid(
        ["seq_write"], [W], [0, 1], env_cls=LustreSimV2, engine="scan",
        ddpg_config=cfg, eval_runs=1, warmup_steps=2, buffer_capacity=32,
        replay_dtype=jnp.bfloat16, sharing=SharingConfig(shared_replay=True))
    plan = fleet.memory_plan(steps=6)
    assert plan["cell_size"] == 2
    assert plan["replay_dtype"] == "bfloat16"
    assert plan["matches_live"] is True


# ---------------------------------------------------------------------------
# FleetService: cell binding, static parity, checkpointed sharing
# ---------------------------------------------------------------------------

def _sharing_service(tmpdir=None, sharing=None, cell_size=2):
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=4)
    return FleetService(
        chunk=2, env_cls=LustreSimEnv, ddpg_config=cfg, warmup_steps=3,
        eval_runs=1, buffer_capacity=16, sharing=sharing,
        cell_size=cell_size,
        checkpoint_dir=tmpdir)


def test_sharing_service_matches_static_sharing_fleet():
    sharing = SharingConfig(shared_replay=True, avg_every=2)
    seeds, steps = [0, 1], 6
    static = _fleet(seeds=seeds, sharing=sharing).run(steps)

    svc = _sharing_service(sharing=sharing)
    sids = [svc.request_join("seq_write", W, s + 1000 * i)
            for i, s in enumerate(seeds)]
    svc.advance(steps)
    for sid in sids:
        svc.request_leave(sid)
    svc.advance(0)
    for sid, res in zip(sids, static.results):
        got = svc.result(sid)
        _assert_bitwise_equal_runs(res, got, maxulp=0)
        _assert_exact_histories(res.history, got.history)


def test_sharing_service_checkpoint_resume_is_bitwise(tmp_path):
    sharing = SharingConfig(shared_replay=True, avg_every=2)
    svc = _sharing_service(str(tmp_path / "svc"), sharing=sharing)
    sids = [svc.request_join("seq_write", W, s) for s in (0, 1)]
    svc.advance(4)
    svc.checkpoint()

    svc.advance(3)
    for sid in sids:
        svc.request_leave(sid)
    svc.advance(0)

    res = FleetService.restore(str(tmp_path / "svc"))
    assert res.sharing == normalize_sharing(sharing)
    assert res.cell_size == 2
    res.advance(3)
    for sid in sids:
        res.request_leave(sid)
    res.advance(0)
    for sid in sids:
        a, b = svc.result(sid), res.result(sid)
        _assert_bitwise_equal_runs(a, b, maxulp=0)
        _assert_exact_histories(a.history, b.history)


def test_cell_dies_with_its_last_member():
    sharing = SharingConfig(shared_replay=True)
    svc = _sharing_service(sharing=sharing)
    a = svc.request_join("seq_write", W, 0)
    svc.advance(2)
    assert len(svc._cells) == 1
    svc.request_leave(a)
    svc.advance(0)
    assert svc._cells == {}  # merged experience leaves with its tenants
    b = svc.request_join("seq_write", W, 7)
    svc.advance(2)
    assert len(svc._cells) == 1 and b in svc.active


def test_service_chunk_must_align_with_cells():
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=2)
    with pytest.raises(ValueError, match="multiple of cell_size"):
        FleetService(chunk=3, env_cls=LustreSimEnv, ddpg_config=cfg,
                     sharing=SharingConfig(shared_replay=True), cell_size=2)


# ---------------------------------------------------------------------------
# Random-space parity (hypothesis when available, fixed seeds always)
# ---------------------------------------------------------------------------

def _check_random_space_sharing_parity(dim, steps, space_seed, seed):
    from tests.test_episode import _random_space
    rng = np.random.default_rng(space_seed)
    space = _random_space(rng, dim)

    def build(sharing):
        def factory(workload, s):
            return ModelEnv(SyntheticSurfaceModel(
                space, n_metrics=3, surface_seed=space_seed), seed=s)
        cfg = DDPGConfig.for_env(factory("w", 0), updates_per_step=2)
        return FleetTuner.from_grid(
            ["w"], [{"m0": 0.7, "m2": 0.3}], [seed], env_factory=factory,
            engine="scan", ddpg_config=cfg, eval_runs=1, warmup_steps=2,
            buffer_capacity=8, sharing=sharing)

    ind = build(None).run(steps).results[0]
    shr = build(SharingConfig(shared_replay=True)).run(steps).results[0]
    _assert_bitwise_equal_runs(ind, shr, maxulp=4)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(dim=st.integers(2, 6), steps=st.integers(3, 8),
           space_seed=st.integers(0, 2 ** 16), seed=st.integers(0, 2 ** 16))
    def test_random_space_cell_of_one_parity_hypothesis(
            dim, steps, space_seed, seed):
        _check_random_space_sharing_parity(dim, steps, space_seed, seed)
else:
    @pytest.mark.parametrize("dim,steps,space_seed,seed", [
        (2, 6, 11, 3), (5, 4, 29, 17), (8, 5, 101, 42)])
    def test_random_space_cell_of_one_parity_fixed(
            dim, steps, space_seed, seed):
        _check_random_space_sharing_parity(dim, steps, space_seed, seed)


# ---------------------------------------------------------------------------
# Benchmark helpers (benchmarks/shared_experience.py)
# ---------------------------------------------------------------------------

def test_steps_to_gain_first_sustained_hit():
    from benchmarks.shared_experience import WINDOW, _steps_to
    curve = np.array([0.1, 0.2, 0.55, 0.4, 0.6])
    assert _steps_to(curve, 0.5, miss=99) == 2 + WINDOW
    assert _steps_to(curve, 0.7, miss=99) == 99


def test_ratio_stats_labels_against_noise_band():
    from benchmarks.common import ESTABLISHED_NOISE_BAND_REL
    from benchmarks.shared_experience import _ratio_stats
    assert _ratio_stats([0.5, 0.6, 0.7])["label"] == "improvement"
    assert _ratio_stats([1.0, 1.01, 0.99])["label"] == "within_noise"
    assert _ratio_stats([1.5, 1.6, 1.7])["label"] == "regression"
    st = _ratio_stats([1.0, 1.0, 1.0])
    assert st["noise_band"] >= ESTABLISHED_NOISE_BAND_REL
