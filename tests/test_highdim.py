"""The 8-knob ``LustreSimV2`` stack: one ``ParamSpace`` drives the env, the
DDPG agent, the fleet, and all three baselines (ISSUE 2's tentpole).

Load-bearing properties:
  * the V2 surface reduces EXACTLY to the 2-D surface when the client knobs
    sit at their Lustre defaults (so 2-D calibration stays authoritative);
  * client knobs both move throughput (response surface) and are VISIBLE in
    the Table-I metric state (the paper's thesis);
  * a fleet of one on the 8-D space is bitwise-identical to the single Tuner;
  * every tuner/baseline runs end-to-end from the same space definition;
  * restart costs are attributed per scope (client knob vs DFS restart).
"""

import numpy as np
import pytest

from repro.core import (
    BestConfigTuner,
    DDPGConfig,
    FleetTuner,
    GridSearchTuner,
    MagpieAgent,
    RandomSearchTuner,
    Scalarizer,
    Tuner,
)
from repro.envs import (
    LustreSimEnv,
    LustreSimV2,
    batch_mean_performance,
    magpie8_param_space,
)

THROUGHPUT = {"throughput": 1.0}


def _scal(env):
    return Scalarizer(weights=dict(THROUGHPUT), specs=env.metric_specs)


# ---------------------------------------------------------------------------
# Response surface
# ---------------------------------------------------------------------------

def test_v2_space_is_8d_mixed():
    space = magpie8_param_space()
    assert space.dim == 8
    kinds = {s.name: s.kind for s in space.specs}
    assert kinds["stripe_size"] == "log2_int"
    assert kinds["checksums"] == "boolean"
    assert kinds["service_threads"] == "categorical"
    cfg = space.default_config()  # Lustre defaults
    assert cfg["max_rpcs_in_flight"] == 8 and cfg["max_dirty_mb"] == 32
    assert cfg["checksums"] is True
    assert space.validate(cfg)
    # the "~5.5 M distinct configurations" claim in README/docs/benchmarks
    total = int(np.prod([s.cardinality for s in space.specs]))
    assert total == 5_488_560


def test_v2_defaults_reduce_to_2d_surface():
    """With client knobs at defaults, only the service-thread factor differs
    from the paper's 2-D surface — same surface, larger box around it."""
    v2 = LustreSimV2("seq_write", seed=0)
    base = LustreSimEnv("seq_write", seed=0, extended=True)
    cfg8 = v2.param_space.default_config()
    cfg3 = {"stripe_count": 1, "stripe_size": 1 << 20, "service_threads": 64}
    p8 = v2.mean_performance(cfg8)
    p3 = base.mean_performance(cfg3)
    assert np.isclose(p8["throughput"], p3["throughput"], rtol=1e-12)
    assert np.isclose(p8["iops"], p3["iops"], rtol=1e-12)


def test_v2_batch_surface_matches_scalar():
    envs, configs = [], []
    rng = np.random.default_rng(0)
    for i, wl in enumerate(["file_server", "video_server", "seq_write",
                            "seq_read", "random_rw"]):
        env = LustreSimV2(wl, seed=i)
        envs.append(env)
        configs.append(env.param_space.to_config(
            rng.uniform(size=env.param_space.dim)))
    for env, config, got in zip(envs, configs,
                                batch_mean_performance(envs, configs)):
        ref = env.mean_performance(config)
        for k in ref:
            assert np.isclose(float(ref[k]), got[k], rtol=1e-12, atol=0.0), k


def test_client_knobs_move_throughput():
    env = LustreSimV2("seq_write", seed=0)
    base = env.param_space.default_config()
    t0 = env.mean_performance(base)["throughput"]
    # starving the RPC pipeline on a wide layout hurts
    starved = {**base, "stripe_count": 6, "max_rpcs_in_flight": 1}
    fed = {**base, "stripe_count": 6, "max_rpcs_in_flight": 64}
    assert (env.mean_performance(starved)["throughput"]
            < 0.8 * env.mean_performance(fed)["throughput"])
    # a tiny dirty cache throttles a pure-write workload
    assert env.mean_performance({**base, "max_dirty_mb": 4})["throughput"] < t0
    # disabling checksums buys CPU back
    assert env.mean_performance({**base, "checksums": False})["throughput"] > t0
    # read-ahead is wasted on pure writes: no effect on seq_write
    assert np.isclose(
        env.mean_performance({**base, "read_ahead_mb": 1024})["throughput"],
        t0, rtol=1e-9)
    # ...but collapsing it hurts a sequential reader
    env_r = LustreSimV2("seq_read", seed=0)
    base_r = env_r.param_space.default_config()
    assert (env_r.mean_performance({**base_r, "read_ahead_mb": 1})["throughput"]
            < env_r.mean_performance(base_r)["throughput"])


def test_client_knobs_visible_in_metric_state():
    """The paper's thesis: a knob's limit shows up in the metric it governs."""
    env = LustreSimV2("seq_write", seed=0)
    base = env.param_space.default_config()
    m_small = env.apply({**base, "max_dirty_mb": 4})
    assert m_small["cur_dirty_bytes"] <= 4 * 1024 * 1024
    env2 = LustreSimV2("seq_write", seed=0)
    m_rpc = env2.apply({**base, "stripe_count": 6, "max_rpcs_in_flight": 1})
    assert m_rpc["write_rpcs_in_flight"] <= 6.0
    # checksums on burns CPU: less idle than the checksum-free run
    env_on = LustreSimV2("seq_write", seed=0)
    env_off = LustreSimV2("seq_write", seed=0)
    on = env_on.apply({**base, "stripe_count": 6, "checksums": True})
    off = env_off.apply({**base, "stripe_count": 6, "checksums": False})
    assert on["cpu_usage_idle"] < off["cpu_usage_idle"]


def test_v2_true_optimum_beats_default():
    env = LustreSimV2("video_server", seed=0)
    best, score = env.true_optimum(THROUGHPUT, samples=256, sweeps=1)
    assert env.param_space.validate(best)
    default_t = env.mean_performance(
        env.param_space.default_config())["throughput"]
    assert env.mean_performance(best)["throughput"] > 1.3 * default_t


# ---------------------------------------------------------------------------
# Restart-cost accounting
# ---------------------------------------------------------------------------

def test_restart_scopes_and_episode_accounting():
    env = LustreSimV2("seq_read", seed=0)
    base = env.param_space.default_config()
    assert env.restart_cost(dict(base), dict(base)) == 0.0
    client = env.restart_cost({**base, "max_rpcs_in_flight": 64}, base)
    assert 12.0 <= client <= 20.0  # client knob: workload restart only
    dfs = env.restart_cost({**base, "checksums": False}, base)
    assert 42.0 <= dfs <= 50.0     # remount: +30 s DFS restart
    summary = env.restart_summary()
    assert summary["workload"]["count"] == 1
    assert summary["dfs"]["count"] == 1
    assert np.isclose(summary["workload"]["seconds"]
                      + summary["dfs"]["seconds"], client + dfs)


# ---------------------------------------------------------------------------
# End-to-end: one ParamSpace definition drives every tuner
# ---------------------------------------------------------------------------

def test_fleet_of_one_matches_single_tuner_8d():
    """Bitwise: same seed -> identical configs, objectives, rewards, restarts."""
    seed, wl, steps = 5, "seq_write", 8
    env = LustreSimV2(wl, seed=seed)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=seed)
    single = Tuner(env, _scal(env), agent).run(steps)

    fleet = FleetTuner.from_grid([wl], [THROUGHPUT], [seed],
                                 env_cls=LustreSimV2)
    got = fleet.run(steps).results[0]

    assert got.best_config == single.best_config
    assert got.best_objective == single.best_objective
    assert got.default_metrics == single.default_metrics
    for h_s, h_f in zip(single.history, got.history):
        assert h_f.config == h_s.config
        assert h_f.objective == h_s.objective
        assert h_f.reward == h_s.reward
        assert h_f.restart_seconds == h_s.restart_seconds


def test_all_tuners_run_on_8d_space():
    steps = 6
    results = {}
    env = LustreSimV2("seq_write", seed=0)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=0)
    results["magpie"] = Tuner(env, _scal(env), agent, eval_runs=1).run(steps)
    env_b = LustreSimV2("seq_write", seed=0)
    results["bestconfig"] = BestConfigTuner(
        env_b, _scal(env_b), round_size=6, eval_runs=1, seed=0).run(steps)
    env_r = LustreSimV2("seq_write", seed=0)
    results["random"] = RandomSearchTuner(
        env_r, _scal(env_r), eval_runs=1, seed=0).run(steps)
    env_g = LustreSimV2("seq_write", seed=0)
    results["grid"] = GridSearchTuner(
        env_g, _scal(env_g), points_per_dim=2, eval_runs=1).run()
    for name, res in results.items():
        assert res.best_config.keys() == set(env.param_space.names), name
        assert env.param_space.validate(res.best_config), name
        assert np.isfinite(res.best_objective), name


def test_from_grid_rejects_conflicting_env_args():
    with pytest.raises(ValueError):
        FleetTuner.from_grid(["seq_write"], [THROUGHPUT], [0],
                             env_cls=LustreSimV2,
                             env_factory=lambda w, s: LustreSimV2(w, seed=s))
    with pytest.raises(ValueError):
        FleetTuner.from_grid(["seq_write"], [THROUGHPUT], [0],
                             env_cls=LustreSimV2, extended=True)


def test_grid_search_rejects_intractable_8d_grid():
    env = LustreSimV2("seq_write", seed=0)
    with pytest.raises(ValueError):
        GridSearchTuner(env, _scal(env), points_per_dim=8)
    assert env.param_space.grid_size(8) > 200_000


def test_ddpg_config_sized_from_space():
    env2 = LustreSimEnv("seq_write", seed=0)
    env8 = LustreSimV2("seq_write", seed=0)
    cfg2, cfg8 = DDPGConfig.for_env(env2), DDPGConfig.for_env(env8)
    assert (cfg2.state_dim, cfg2.action_dim) == (12, 2)
    assert (cfg8.state_dim, cfg8.action_dim) == (12, 8)
    assert cfg8.hidden == cfg2.hidden  # trunk stays flat across spaces
    # Tuner builds its own agent from the space when none is given
    tuner = Tuner(env8, _scal(env8), eval_runs=1)
    assert tuner.agent.cfg.action_dim == 8
