"""Self-healing fleet runtime (core/resilience.py + supervised streaming).

Load-bearing properties:
  * resilience-off is bitwise-NEUTRAL: ``resilience=None`` keys (and IS, by
    executable identity) the exact pre-resilience episode program — single
    scan tuner, chunked fleet and service reproduce the default-constructed
    run maxulp=0;
  * a chaos-injected NaN divergence is caught in-graph: the poisoned sample
    never enters the replay FIFO, the learner resets to the last-good
    snapshot within ``snapshot_every`` steps, and past ``max_resets`` the
    session degrades cleanly to a frozen incumbent (sticky, never resets);
  * the ``health_decision`` state machine holds its invariants under
    arbitrary fault sequences (hypothesis + fixed-seed fallback lanes,
    mirroring tests/test_episode): resets never exceed ``max_resets``,
    degraded is sticky, a degraded step never resets;
  * host supervision is bitwise invisible on success: a transient staging
    exception is retried to a result bitwise-equal to a fault-free run, a
    stalled chunk only trips the watchdog counter, and a permanently dead
    chunk quarantines its sessions through the leave path while every
    survivor stays bitwise vs an uninjected fleet;
  * trace-derived health counters equal the in-graph totals, and a
    resilient service checkpoint resumes bit-identically.
"""

import numpy as np
import pytest

from repro.core import (
    ChunkSupervisor,
    DDPGConfig,
    DeploymentPolicy,
    FleetService,
    FleetTuner,
    MagpieAgent,
    ResiliencePolicy,
    Scalarizer,
    SharingConfig,
    Tuner,
    health_decision,
    normalize_resilience,
    normalize_supervisor,
)
from repro.core.resilience import (
    EVENT_DEGRADED,
    EVENT_NONFINITE,
    EVENT_RESET,
    empty_health_counters,
    health_counters,
    merge_health_counters,
)
from repro.envs import (
    ChaosConfig,
    FaultInjectedModel,
    LustreSimEnv,
    LustreSimV2,
    ModelEnv,
    nan_poison,
)

from tests.test_episode import _assert_bitwise_equal_runs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs it (requirements.txt); skip locally without
    HAVE_HYPOTHESIS = False


def _tuner(env_cls=LustreSimEnv, resilience=None, seed=3, updates=4,
           warmup=3, workload="seq_write", env=None, **kw):
    env = env or env_cls(workload, seed=seed).to_model_env()
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=updates),
                        seed=seed, warmup_steps=warmup)
    return Tuner(env, scal, agent, engine="scan", eval_runs=1,
                 resilience=resilience, **kw)


def _fleet(resilience=None, supervisor=None, chaos=None, chunk=2,
           seeds=(0, 1, 2), updates=4, warmup=3, env_factory=None,
           sharing=None):
    env = (env_factory("seq_write", 0) if env_factory
           else LustreSimEnv("seq_write"))
    cfg = DDPGConfig.for_env(env, updates_per_step=updates)
    return FleetTuner.from_grid(
        ["seq_write"], [{"throughput": 1.0}], list(seeds),
        env_cls=None if env_factory else LustreSimEnv,
        env_factory=env_factory, engine="scan", ddpg_config=cfg, eval_runs=1,
        warmup_steps=warmup, chunk=chunk, resilience=resilience,
        supervisor=supervisor, chaos=chaos, sharing=sharing)


def _faulted_tuner(fault_specs, resilience, seed=0, env_cls=LustreSimV2):
    base = env_cls("seq_write", seed=seed).as_model()
    env = ModelEnv(FaultInjectedModel(base, fault_specs), seed=seed)
    return _tuner(resilience=resilience, seed=seed, env=env)


def _faulted_fleet_factory(fault_specs):
    """Every session wraps its model in ONE shared fault schedule, so the
    fleet keeps a single step_fn identity (one compiled program)."""
    specs = tuple(fault_specs)

    def env_factory(workload, seed):
        base = LustreSimV2(workload, seed=seed).as_model()
        return ModelEnv(FaultInjectedModel(base, specs), seed=seed)

    return env_factory


# ---------------------------------------------------------------------------
# Off path: resilience=None is the pre-resilience engine, bit for bit
# ---------------------------------------------------------------------------

def test_resilience_none_shares_the_plain_program_object():
    """``resilience=None`` is not merely equivalent — it keys the SAME
    cached episode executable as not mentioning resilience at all, for both
    the single and the fleet build, so the off path cannot drift from the
    plain engine by construction."""
    from repro.core.episode import _compiled_episode
    env = LustreSimEnv("seq_write", seed=0).to_model_env()
    cfg = DDPGConfig.for_env(env)
    from repro.core.ddpg import fleet_init
    import jax
    import jax.numpy as jnp
    _, (atx, ctx) = fleet_init(jnp.stack([jax.random.PRNGKey(0)]), cfg)
    for fleet in (False, True):
        default = _compiled_episode(env.model.step_fn, env.param_space, cfg,
                                    atx, ctx, True, cfg.updates_per_step,
                                    fleet=fleet, devices=None)
        explicit = _compiled_episode(env.model.step_fn, env.param_space, cfg,
                                     atx, ctx, True, cfg.updates_per_step,
                                     fleet=fleet, devices=None,
                                     resilience=None)
        assert default is explicit


def test_nonfinite_check_false_normalizes_to_the_off_program():
    """A fully-off policy collapses to the SAME canonical None the cache
    keys on — there is exactly one off value."""
    assert normalize_resilience(None) is None
    off = ResiliencePolicy(nonfinite_check=False, max_resets=9)
    assert normalize_resilience(off) is None
    assert normalize_supervisor(None) is None
    with pytest.raises(ValueError, match="max_resets"):
        normalize_resilience(ResiliencePolicy(max_resets=-1))
    with pytest.raises(ValueError, match="snapshot_every"):
        normalize_resilience(ResiliencePolicy(snapshot_every=0))
    with pytest.raises(ValueError, match="degrade_after"):
        normalize_resilience(ResiliencePolicy(degrade_after=0))
    with pytest.raises(ValueError, match="on_failure"):
        normalize_supervisor(ChunkSupervisor(on_failure="crash"))


def test_resilience_off_is_bitwise_neutral_single_tuner():
    ref = _tuner(seed=5).run(8)
    off = _tuner(seed=5, resilience=None).run(8)
    _assert_bitwise_equal_runs(ref, off, maxulp=0)
    assert off.health_stats is None


def test_resilience_off_is_bitwise_neutral_chunked_fleet():
    ref, off = _fleet(), _fleet(resilience=None)
    for steps in (4, 3):  # progressive runs stay aligned too
        for a, b in zip(ref.run(steps).results, off.run(steps).results):
            _assert_bitwise_equal_runs(a, b, maxulp=0)
            assert b.health_stats is None


def test_resilience_off_is_bitwise_neutral_service(tmp_path):
    def make(**kw):
        svc = FleetService(chunk=2, warmup_steps=3,
                           checkpoint_dir=str(tmp_path), **kw)
        svc.request_join("seq_write", {"throughput": 1.0}, 0)
        svc.request_join("seq_write", {"throughput": 1.0}, 1)
        return svc

    ref, off = make(), make(resilience=None, supervisor=None)
    for steps in (4, 2):
        ref.advance(steps), off.advance(steps)
        for sid in (0, 1):
            a, b = ref._sessions[sid], off._sessions[sid]
            assert [r.config for r in a.history] == \
                [r.config for r in b.history]
            assert [r.objective for r in a.history] == \
                [r.objective for r in b.history]
            assert [r.reward for r in a.history] == \
                [r.reward for r in b.history]
    assert "supervisor" not in ref.last_stats
    assert "quarantined" not in ref.last_stats


def test_resilient_run_without_faults_matches_plain_single_tuner():
    """On a healthy run the resilient body is numerically the plain body:
    same FIFO writes, same learn inputs, zero health events."""
    ref = _tuner(seed=5).run(8)
    t = _tuner(seed=5, resilience=ResiliencePolicy())
    res = t.run(8)
    _assert_bitwise_equal_runs(ref, res, maxulp=0)
    assert not np.any(t.health_events)
    s = res.health_stats
    assert s["resets_total"] == 0 and s["nonfinite_total"] == 0
    assert not s["degraded"]
    assert s["policy"]["max_resets"] == ResiliencePolicy().max_resets


def test_resilient_fleet_without_faults_matches_plain_fleet():
    ref, res = _fleet(), _fleet(resilience=ResiliencePolicy())
    for a, b in zip(ref.run(6).results, res.run(6).results):
        _assert_bitwise_equal_runs(a, b, maxulp=0)
        assert b.health_stats["resets_total"] == 0
    assert not np.any(res.health_events)


# ---------------------------------------------------------------------------
# In-graph recovery: NaN divergence -> snapshot reset or clean degrade
# ---------------------------------------------------------------------------

def test_nan_divergence_recovers_within_the_snapshot_window():
    start, dur = 4, 2
    t = _faulted_tuner([nan_poison("throughput", start=start, duration=dur)],
                       ResiliencePolicy(max_resets=4, snapshot_every=1))
    res = t.run(12)
    ev = t.health_events
    # the poison is observed (raw in the trace) and answered by a reset on
    # each corrupted step — the learner never keeps a poisoned sample
    for k in range(start, start + dur):
        assert ev[k] & EVENT_NONFINITE
        assert ev[k] & EVENT_RESET
        assert np.isnan(res.history[k].metrics["throughput"])
    # recovery within snapshot_every steps of the fault clearing: the next
    # step is healthy and every post-fault objective is finite again
    after = ev[start + dur:]
    assert not np.any(after & EVENT_NONFINITE)
    assert not np.any(after & EVENT_DEGRADED)
    post = [h.objective for h in res.history[start + dur:]]
    assert np.all(np.isfinite(post))
    s = res.health_stats
    assert s["resets_total"] == dur and s["nonfinite_total"] == dur
    assert not s["degraded"]


def test_exhausted_reset_budget_degrades_cleanly_and_stays_frozen():
    start = 3
    t = _faulted_tuner([nan_poison("throughput", start=start, duration=50)],
                       ResiliencePolicy(max_resets=0, snapshot_every=1))
    res = t.run(10)
    ev = t.health_events
    assert not np.any(ev[:start])
    # max_resets=0: the FIRST divergence degrades; no reset is ever spent
    # and the flag is sticky for the rest of the run
    assert not np.any(ev & EVENT_RESET)
    assert np.all(ev[start:] & EVENT_DEGRADED)
    s = res.health_stats
    assert s["degraded"] and s["resets_total"] == 0
    assert s["degraded_steps"] == 10 - start
    assert s["nonfinite_total"] == 10 - start


def test_degrade_after_caps_total_nonfinite_detections():
    """``degrade_after`` degrades a flapping session even with resets left:
    two separated poison bursts spend resets, the third crosses the total
    non-finite cap."""
    pol = ResiliencePolicy(max_resets=100, snapshot_every=1, degrade_after=3)
    t = _faulted_tuner([nan_poison("throughput", start=2, duration=1),
                        nan_poison("throughput", start=5, duration=1),
                        nan_poison("throughput", start=8, duration=1)], pol)
    res = t.run(12)
    ev = t.health_events
    assert ev[2] & EVENT_RESET and ev[5] & EVENT_RESET
    assert ev[8] & EVENT_DEGRADED and not (ev[8] & EVENT_RESET)
    assert np.all(ev[8:] & EVENT_DEGRADED)
    assert res.health_stats["resets_total"] == 2


def test_trace_counters_equal_in_graph_totals():
    t = _faulted_tuner([nan_poison("throughput", start=4, duration=2)],
                       ResiliencePolicy(max_resets=4))
    res = t.run(10)
    s = res.health_stats
    got = health_counters(t.health_events)
    assert got["steps"] == 10
    assert got["resets"] == s["resets_total"]
    assert got["nonfinite"] == s["nonfinite_total"]
    assert s["degraded_steps"] == got["degraded_steps"] == 0


def test_merge_health_counters_and_empty_counters():
    a = health_counters(np.array(
        [0, EVENT_NONFINITE | EVENT_RESET, EVENT_NONFINITE | EVENT_DEGRADED,
         EVENT_DEGRADED], np.uint8))
    assert a["steps"] == 4 and a["nonfinite"] == 2
    assert a["resets"] == 1 and a["degraded_steps"] == 2
    merged = merge_health_counters(a, empty_health_counters())
    assert merged == a
    assert empty_health_counters()["resets"] == 0


# ---------------------------------------------------------------------------
# health_decision invariants (hypothesis + fixed-seed fallback)
# ---------------------------------------------------------------------------

def _check_health_invariants(bads, max_resets, degrade_after):
    """Fold an arbitrary fault sequence through the state machine: resets
    never exceed ``max_resets``, degraded is sticky, a degraded step never
    resets, non-finite detections count every bad step exactly once."""
    pol = ResiliencePolicy(max_resets=max_resets,
                           degrade_after=degrade_after)
    resets, nf = np.int32(0), np.int32(0)
    degraded = np.bool_(False)
    for b in bads:
        b = np.bool_(b)
        do_reset, new_deg, new_resets, new_nf = health_decision(
            b, resets, nf, degraded, pol)
        assert int(new_resets) <= max_resets
        assert bool(new_deg) or not bool(degraded)   # sticky
        assert not (bool(do_reset) and bool(new_deg))  # degraded: no reset
        assert int(new_nf) == int(nf) + int(bool(b))
        assert int(new_resets) - int(resets) == int(bool(do_reset))
        resets, nf, degraded = new_resets, new_nf, new_deg


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(bads=st.lists(st.booleans(), max_size=40),
           max_resets=st.integers(0, 6),
           degrade_after=st.none() | st.integers(1, 10))
    def test_health_decision_invariants(bads, max_resets, degrade_after):
        _check_health_invariants(bads, max_resets, degrade_after)
else:
    @pytest.mark.parametrize("bads,max_resets,degrade_after", [
        ([True] * 10, 3, None),
        ([False, True, False, True, True, False], 1, None),
        ([True, False] * 8, 2, 3),
        ([False] * 5, 0, 1),
        ([True] * 4, 0, None)])
    def test_health_decision_invariants(bads, max_resets, degrade_after):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _check_health_invariants(bads, max_resets, degrade_after)


# ---------------------------------------------------------------------------
# Host supervisor: retries are bitwise invisible, stalls only trip counters
# ---------------------------------------------------------------------------

def test_supervised_stream_without_faults_is_bitwise_invisible():
    """Supervision is pure scheduling: the serial supervised stream matches
    the unsupervised double-buffered one maxulp=0."""
    ref = _fleet()
    sup = _fleet(supervisor=ChunkSupervisor(max_retries=2,
                                            backoff_seconds=0.0))
    for a, b in zip(ref.run(6).results, sup.run(6).results):
        _assert_bitwise_equal_runs(a, b, maxulp=0)


def test_transient_chunk_failure_is_retried_to_a_bitwise_equal_result():
    from repro.core.episode import last_fleet_run_stats
    chaos = ChaosConfig(fail_chunks=((0, 1),))  # chunk 0: 1 failure, then ok
    ref = _fleet()
    faulted = _fleet(supervisor=ChunkSupervisor(max_retries=2,
                                                backoff_seconds=0.0),
                     chaos=chaos.host())
    rr, rf = ref.run(6), faulted.run(6)
    stats = last_fleet_run_stats()["supervisor"]
    assert stats["retries"] == 1 and stats["failed_chunks"] == []
    for a, b in zip(rr.results, rf.results):
        _assert_bitwise_equal_runs(a, b, maxulp=0)


def test_stalled_chunk_trips_the_watchdog_without_touching_results():
    from repro.core.episode import last_fleet_run_stats
    chaos = ChaosConfig(stall_chunks=((0, 0.05),))
    ref = _fleet()
    stalled = _fleet(supervisor=ChunkSupervisor(backoff_seconds=0.0,
                                                watchdog_seconds=0.02),
                     chaos=chaos.host())
    rr, rs = ref.run(5), stalled.run(5)
    stats = last_fleet_run_stats()["supervisor"]
    assert stats["watchdog_trips"] >= 1
    assert stats["failed_chunks"] == []
    assert len(stats["chunk_seconds"]) == 2  # 3 sessions / chunk=2
    for a, b in zip(rr.results, rs.results):
        _assert_bitwise_equal_runs(a, b, maxulp=0)


def test_exhausted_retries_raise_chunk_failure_by_default():
    from repro.core.resilience import ChunkFailure
    chaos = ChaosConfig(fail_chunks=((0, 99),))  # never clears
    faulted = _fleet(supervisor=ChunkSupervisor(max_retries=1,
                                                backoff_seconds=0.0),
                     chaos=chaos.host())
    with pytest.raises(ChunkFailure, match="chunk 0"):
        faulted.run(4)


def test_chaos_without_a_supervisor_is_refused():
    from repro.core.episode import stream_chunks
    with pytest.raises(ValueError, match="ChunkSupervisor"):
        stream_chunks(lambda args: args, lambda ci: ci,
                      lambda ci, out: None, 2, chaos=object())


# ---------------------------------------------------------------------------
# Service quarantine: a dead chunk leaves, survivors stay bitwise
# ---------------------------------------------------------------------------

def _service(tmp_path, n=4, **kw):
    svc = FleetService(chunk=2, warmup_steps=3,
                       checkpoint_dir=str(tmp_path), **kw)
    sids = [svc.request_join("seq_write", {"throughput": 1.0}, seed)
            for seed in range(n)]
    return svc, sids


def test_dead_chunk_quarantines_sessions_and_survivors_stay_bitwise(
        tmp_path):
    ref, _ = _service(tmp_path / "ref")
    chaos = ChaosConfig(fail_chunks=((1, 99),))  # chunk 1 never stages
    # on_failure="raise" is forced to "skip" inside advance: a persistent
    # service quarantines, it never crashes
    svc, sids = _service(
        tmp_path / "chaotic",
        supervisor=ChunkSupervisor(max_retries=1, backoff_seconds=0.0,
                                   on_failure="raise"),
        chaos=chaos.host())
    ref.advance(4)
    svc.advance(4)
    assert svc.last_stats["supervisor"]["failed_chunks"] == [1]
    assert svc.last_stats["quarantined"] == sids[2:]
    # survivors (chunk 0) are bitwise the uninjected fleet's sessions
    for sid in sids[:2]:
        a, b = ref._sessions[sid], svc._sessions[sid]
        assert [r.config for r in a.history] == \
            [r.config for r in b.history]
        assert [r.objective for r in a.history] == \
            [r.objective for r in b.history]
    # the quarantined sessions leave at the next boundary with their
    # pre-episode state (the failed chunk never drained: no history)
    svc.advance(0)
    for sid in sids[2:]:
        assert sid not in svc._sessions
        assert svc.result(sid).history == []
    for sid in sids[:2]:
        assert sid in svc._sessions


def test_resilient_service_checkpoint_restore_resumes_bit_identically(
        tmp_path):
    import jax
    pol = ResiliencePolicy(max_resets=2, snapshot_every=2)
    sup = ChunkSupervisor(max_retries=1, backoff_seconds=0.0)
    svc = FleetService(chunk=2, warmup_steps=3, resilience=pol,
                       supervisor=sup, checkpoint_dir=str(tmp_path))
    a = svc.request_join("seq_write", {"throughput": 1.0}, 0)
    b = svc.request_join("random_rw", {"iops": 1.0}, 1)
    svc.advance(5)
    svc.checkpoint()
    svc.advance(4)
    want = {sid: svc.health_stats(sid) for sid in (a, b)}
    want_hist = {sid: [r.config for r in svc._sessions[sid].history]
                 for sid in (a, b)}
    want_snap = {sid: jax.tree_util.tree_leaves(
        svc._sessions[sid].health.snapshot) for sid in (a, b)}

    svc2 = FleetService.restore(str(tmp_path))
    assert svc2.resilience == normalize_resilience(pol)
    assert svc2.supervisor == sup
    svc2.advance(4)
    for sid in (a, b):
        assert svc2.health_stats(sid) == want[sid]
        assert [r.config for r in svc2._sessions[sid].history] == \
            want_hist[sid]
        got = jax.tree_util.tree_leaves(svc2._sessions[sid].health.snapshot)
        for x, y in zip(want_snap[sid], got):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # departure surfaces the health record on the TuningResult
    svc2.request_leave(a)
    svc2.advance(0)
    res = svc2.result(a)
    assert res.health_stats["policy"]["max_resets"] == 2
    assert res.health_stats["steps"] == 9  # 5 checkpointed + 4 resumed


# ---------------------------------------------------------------------------
# Composition: sharing masks corrupted contributions; guardrails refuse
# ---------------------------------------------------------------------------

def test_shared_cell_masks_poisoned_contributions():
    """With shared replay, a poisoned step's transitions are DROPPED from
    the cell's merged window (the contribution mask), so the window is
    exactly the fault-free window minus the poisoned writes — and every
    member recovers."""
    sharing = SharingConfig(shared_replay=True)
    pol = ResiliencePolicy(max_resets=4, snapshot_every=1)
    clean = _fleet(sharing=sharing, resilience=pol)
    poisoned = _fleet(
        sharing=sharing, resilience=pol,
        env_factory=_faulted_fleet_factory(
            [nan_poison("throughput", start=3, duration=1)]))
    clean.run(8)
    poisoned.run(8)
    ev = poisoned.health_events
    assert np.all(ev[:, 3] & EVENT_NONFINITE)  # every member saw the poison
    assert not np.any(ev[:, 4:] & EVENT_DEGRADED)  # ...and all recovered
    _, _, clean_size = clean.agent.buffer.grouped_storage()
    _, _, got_size = poisoned.agent.buffer.grouped_storage()
    # one poisoned step x 3 members never reached the merged window
    assert np.all(clean_size - got_size == 3)


def test_resilience_refuses_guardrail_composition():
    env = LustreSimEnv("seq_write", seed=0).to_model_env()
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    with pytest.raises(ValueError, match="does not compose"):
        Tuner(env, scal, engine="scan", policy=DeploymentPolicy(),
              resilience=ResiliencePolicy())
    with pytest.raises(ValueError, match="does not compose"):
        FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [0], engine="scan",
            env_cls=LustreSimEnv, policy=DeploymentPolicy(),
            resilience=ResiliencePolicy())
    with pytest.raises(ValueError, match="does not compose"):
        FleetService(chunk=2, policy=DeploymentPolicy(),
                     resilience=ResiliencePolicy())


def test_resilience_requires_the_scan_engine():
    env = LustreSimEnv("seq_write", seed=0)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    with pytest.raises(ValueError, match="scan"):
        Tuner(env, scal, engine="host", resilience=ResiliencePolicy())
    with pytest.raises(ValueError, match="scan"):
        FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [0], engine="host",
            env_cls=LustreSimEnv, resilience=ResiliencePolicy())
    with pytest.raises(ValueError, match="scan"):
        FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [0], engine="host",
            env_cls=LustreSimEnv, supervisor=ChunkSupervisor())
