"""Shadow/canary deployment guardrails (core/guardrails.py + envs/faults.py).

Load-bearing properties:
  * guardrails-off is bitwise-NEUTRAL: ``policy=None`` keys (and builds) the
    exact pre-guardrail episode program — same cached executable object —
    and every engine (single scan tuner, chunked fleet, service,
    fleet-of-1) reproduces the default-constructed run maxulp=0;
  * the promotion gate holds: ``min_gain`` high enough means ZERO
    promotions and a frozen live config; an exhausted restart budget only
    ever rejects (budget accounting never exceeds the cap without a
    rollback re-apply, never goes negative);
  * fault injection (``envs.faults``) proves rollback: a throughput
    collapse at step k triggers a rollback within the policy window and
    the live system returns to the pre-promotion incumbent;
  * policy decision functions are monotone in their thresholds
    (hypothesis + fixed-seed fallback lanes, mirroring tests/test_episode);
  * the trace-derived counters agree with the in-graph guard totals, and a
    guarded service checkpoint resumes bit-identically.
"""

import numpy as np
import pytest

from repro.core import (
    DDPGConfig,
    DeploymentPolicy,
    FleetTuner,
    MagpieAgent,
    Scalarizer,
    Tuner,
    gate_decision,
    rollback_decision,
)
from repro.core.guardrails import (
    EVENT_PROMOTED,
    EVENT_REJECTED_GAIN,
    EVENT_ROLLBACK,
    empty_counters,
    guardrail_counters,
    merge_counters,
)
from repro.envs import (
    FaultInjectedModel,
    FaultSpec,
    LustreSimEnv,
    LustreSimV2,
    ModelEnv,
    metric_dropout,
    throughput_collapse,
)

from tests.test_episode import _assert_bitwise_equal_runs

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # CI installs it (requirements.txt); skip locally without
    HAVE_HYPOTHESIS = False


def _tuner(env_cls=LustreSimEnv, policy=None, seed=3, updates=4, warmup=3,
           workload="seq_write", env=None, **kw):
    env = env or env_cls(workload, seed=seed).to_model_env()
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=updates),
                        seed=seed, warmup_steps=warmup)
    return Tuner(env, scal, agent, engine="scan", eval_runs=1, policy=policy,
                 **kw)


def _fleet(policy=None, chunk=2, seeds=(0, 1, 2), updates=4, warmup=3):
    env = LustreSimEnv("seq_write")
    cfg = DDPGConfig.for_env(env, updates_per_step=updates)
    return FleetTuner.from_grid(
        ["seq_write"], [{"throughput": 1.0}], list(seeds),
        env_cls=LustreSimEnv, engine="scan", ddpg_config=cfg, eval_runs=1,
        warmup_steps=warmup, chunk=chunk, policy=policy)


# ---------------------------------------------------------------------------
# Off path: policy=None is the pre-guardrail engine, bit for bit
# ---------------------------------------------------------------------------

def test_policy_none_shares_the_unguarded_program_object():
    """``policy=None`` is not merely equivalent — it keys the SAME cached
    episode executable as not mentioning guardrails at all, so the off path
    cannot drift from the unguarded engine by construction."""
    from repro.core.episode import _compiled_episode
    env = LustreSimEnv("seq_write", seed=0).to_model_env()
    cfg = DDPGConfig.for_env(env)
    from repro.core.ddpg import fleet_init
    import jax
    import jax.numpy as jnp
    _, (atx, ctx) = fleet_init(jnp.stack([jax.random.PRNGKey(0)]), cfg)
    default = _compiled_episode(env.model.step_fn, env.param_space, cfg,
                                atx, ctx, True, cfg.updates_per_step,
                                fleet=False, devices=None)
    explicit = _compiled_episode(env.model.step_fn, env.param_space, cfg,
                                 atx, ctx, True, cfg.updates_per_step,
                                 fleet=False, devices=None, policy=None)
    assert default is explicit


def test_guardrails_off_is_bitwise_neutral_single_tuner():
    ref = _tuner(seed=5).run(8)
    off = _tuner(seed=5, policy=None).run(8)
    _assert_bitwise_equal_runs(ref, off, maxulp=0)
    assert off.guardrail_stats is None


def test_guardrails_off_is_bitwise_neutral_chunked_fleet():
    ref, off = _fleet(), _fleet(policy=None)
    for steps in (4, 3):  # progressive runs stay aligned too
        for a, b in zip(ref.run(steps).results, off.run(steps).results):
            _assert_bitwise_equal_runs(a, b, maxulp=0)
            assert b.guardrail_stats is None


def test_guardrails_off_is_bitwise_neutral_service(tmp_path):
    from repro.core import FleetService

    def make(**kw):
        svc = FleetService(chunk=2, warmup_steps=3,
                           checkpoint_dir=str(tmp_path), **kw)
        svc.request_join("seq_write", {"throughput": 1.0}, 0)
        svc.request_join("seq_write", {"throughput": 1.0}, 1)
        return svc

    # default-constructed vs policy=None explicit: identical across advances
    ref, off = make(), make(policy=None)
    for steps in (4, 2):
        ref.advance(steps), off.advance(steps)
        for sid in (0, 1):
            a, b = ref._sessions[sid], off._sessions[sid]
            assert [r.config for r in a.history] == \
                [r.config for r in b.history]
            assert [r.objective for r in a.history] == \
                [r.objective for r in b.history]
            assert [r.reward for r in a.history] == \
                [r.reward for r in b.history]
    assert "guardrails" not in ref.last_stats


def test_guardrails_off_fleet_of_one_matches_single_tuner():
    """The PR's threading changed every engine; the fleet-of-1 == Tuner
    contract must survive it (decisions exact, floats cross-vmap-width)."""
    single = _tuner(seed=3, updates=4, warmup=3).run(6)
    # from_grid's cell 0 seed is 3 + 1000*0 = 3: same streams as the single
    got = _fleet(policy=None, chunk=None, seeds=(3,)).run(6).results[0]
    _assert_bitwise_equal_runs(single, got, maxulp=32)


# ---------------------------------------------------------------------------
# Gate behavior (fixed seeds)
# ---------------------------------------------------------------------------

def test_min_gain_gate_blocks_all_promotions_and_freezes_config():
    pol = DeploymentPolicy(min_gain=1e9)
    t = _tuner(policy=pol)
    res = t.run(10)
    s = res.guardrail_stats
    assert s["promotions"] == 0 and s["promotions_total"] == 0
    assert s["rejected_min_gain"] == 10
    assert s["restart_budget_spent"] == 0.0
    # the live system never moved off the default configuration
    assert all(h.config == res.default_config for h in res.history)
    assert all(h.restart_seconds == 0.0 for h in res.history)
    # ... but the shadow trail shows the tuner kept exploring
    assert len(set(np.round(t.shadow_objectives, 6))) > 1


def test_permissive_policy_promotes():
    s = _tuner(policy=DeploymentPolicy(min_gain=-10.0)).run(10).guardrail_stats
    assert s["promotions"] > 0
    assert s["rejected_min_gain"] == 0


def test_restart_budget_caps_committed_downtime():
    """Promotions stop once the budget cannot absorb another restart; spent
    downtime never exceeds the cap (rollback disabled so no re-apply
    charges) and never goes negative."""
    cap = 40.0
    pol = DeploymentPolicy(min_gain=-10.0, max_restart_seconds=cap,
                           rollback_window=0)
    t = _tuner(policy=pol)
    res = t.run(12)
    s = res.guardrail_stats
    assert 0.0 <= s["restart_budget_spent"] <= cap
    assert s["budget_remaining"] >= 0.0
    assert s["rejected_budget"] > 0  # the cap actually bit
    # exhausted budget -> frozen config afterwards: after the last
    # promotion, committed restarts are all zero
    ev = t.guard_events
    promoted = np.nonzero(ev & EVENT_PROMOTED)[0]
    if promoted.size:
        after = [h.restart_seconds for h in res.history[promoted[-1] + 1:]]
        assert all(r == 0.0 for r in after)


def test_zero_budget_promotes_nothing_with_restart_cost():
    pol = DeploymentPolicy(min_gain=-10.0, max_restart_seconds=0.0,
                           rollback_window=0)
    res = _tuner(policy=pol).run(10)
    s = res.guardrail_stats
    assert s["restart_budget_spent"] == 0.0
    assert all(h.restart_seconds == 0.0 for h in res.history)


# ---------------------------------------------------------------------------
# Fault injection: degradation -> rollback within the window
# ---------------------------------------------------------------------------

def _faulted_tuner(fault_specs, policy, seed=0, env_cls=LustreSimV2):
    base = env_cls("seq_write", seed=seed).as_model()
    env = ModelEnv(FaultInjectedModel(base, fault_specs), seed=seed)
    return _tuner(policy=policy, seed=seed, env=env)


def test_injected_collapse_triggers_rollback_within_window():
    window = 10
    fault_at = 6
    pol = DeploymentPolicy(min_gain=-0.5, rollback_window=window,
                           rollback_threshold=0.3)
    t = _faulted_tuner(
        [throughput_collapse(start=fault_at, duration=10, to_fraction=0.1)],
        pol)
    t.run(20)
    ev = t.guard_events
    rollbacks = np.nonzero(ev & EVENT_ROLLBACK)[0]
    assert rollbacks.size > 0
    # the degradation is answered by a rollback inside the policy window
    # (earlier rollbacks from ordinary tuning variance are allowed)
    in_window = rollbacks[(rollbacks >= fault_at)
                          & (rollbacks < fault_at + window)]
    assert in_window.size > 0


def test_rollback_restores_the_pre_promotion_incumbent():
    """After a rollback at step r (with no same- or next-step promotion),
    the step r+1 committed config IS the incumbent displaced by the last
    promotion — the live system actually went back."""
    pol = DeploymentPolicy(min_gain=-0.5, rollback_window=10,
                           rollback_threshold=0.3)
    t = _faulted_tuner(
        [throughput_collapse(start=6, duration=10, to_fraction=0.1)], pol)
    res = t.run(20)
    ev = t.guard_events
    checked = 0
    for r in np.nonzero(ev & EVENT_ROLLBACK)[0]:
        if r + 1 >= len(ev) or (ev[r + 1] & EVENT_PROMOTED):
            continue  # next step promoted: committed is the new proposal
        promos = [p for p in np.nonzero(ev & EVENT_PROMOTED)[0] if p <= r]
        if not promos:
            continue
        p = promos[-1]
        incumbent = (res.history[p - 1].config if p > 0
                     else res.default_config)
        assert res.history[r + 1].config == incumbent
        checked += 1
    assert checked > 0  # the scenario actually exercised the property


def test_metric_dropout_is_observed_by_the_state():
    """Dropout zeroes the metric in the trace while active (the guarded and
    unguarded engines both see the corrupted observation)."""
    base = LustreSimV2("seq_write", seed=1).as_model()
    env = ModelEnv(FaultInjectedModel(
        base, [metric_dropout("iops", start=2, duration=3)]), seed=1)
    t = _tuner(seed=1, env=env)
    res = t.run(8)
    iops = [h.metrics["iops"] for h in res.history]
    assert all(v == 0.0 for v in iops[2:5])
    assert all(v != 0.0 for v in iops[:2] + iops[5:])


def test_fault_wrapper_validates_inputs():
    base = LustreSimV2("seq_write", seed=0).as_model()
    with pytest.raises(ValueError, match="unknown metric"):
        FaultInjectedModel(base, [FaultSpec("latency", 0, 1)])
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultInjectedModel(base, [FaultSpec("iops", 0, 1, mode="negate")])
    with pytest.raises(ValueError, match="duration"):
        FaultInjectedModel(base, [FaultSpec("iops", 0, 0)])


def test_fault_schedule_shares_one_step_fn_across_sessions():
    """Sessions sharing a schedule share ONE step_fn identity, so a faulted
    fleet still hits one compiled episode program."""
    rows = [throughput_collapse(start=3, duration=2)]
    a = FaultInjectedModel(LustreSimV2("seq_write", seed=0).as_model(), rows)
    b = FaultInjectedModel(LustreSimV2("seq_write", seed=9).as_model(), rows)
    assert a.step_fn is b.step_fn


# ---------------------------------------------------------------------------
# Policy invariants (hypothesis + fixed-seed fallback)
# ---------------------------------------------------------------------------

def _check_gate_monotone(gain, restart, spent, min_gain, budget, d_gain,
                         d_budget):
    """Loosening either threshold never turns a promotion into a
    rejection."""
    tight = DeploymentPolicy(min_gain=min_gain, max_restart_seconds=budget)
    loose = DeploymentPolicy(min_gain=min_gain - d_gain,
                             max_restart_seconds=budget + d_budget)
    p_tight, _, _ = gate_decision(np.float32(gain), np.float32(restart),
                                  np.float32(spent), tight)
    p_loose, _, _ = gate_decision(np.float32(gain), np.float32(restart),
                                  np.float32(spent), loose)
    assert bool(p_loose) or not bool(p_tight)


def _check_rollback_monotone(live, anchor, watch, thr, d_thr):
    """Raising the threshold never turns a no-rollback into a rollback; a
    disarmed watch never rolls back."""
    low = DeploymentPolicy(rollback_threshold=thr)
    high = DeploymentPolicy(rollback_threshold=thr + d_thr)
    r_low = rollback_decision(np.float32(live), np.float32(anchor),
                              np.int32(watch), low)
    r_high = rollback_decision(np.float32(live), np.float32(anchor),
                               np.int32(watch), high)
    assert bool(r_low) or not bool(r_high)
    disarmed = rollback_decision(np.float32(live), np.float32(anchor),
                                 np.int32(0), low)
    assert not bool(disarmed)


_FINITE = dict(allow_nan=False, allow_infinity=False, width=32)

if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(gain=st.floats(-5, 5, **_FINITE),
           restart=st.floats(0, 100, **_FINITE),
           spent=st.floats(0, 500, **_FINITE),
           min_gain=st.floats(-2, 2, **_FINITE),
           budget=st.floats(0, 500, **_FINITE),
           d_gain=st.floats(0, 3, **_FINITE),
           d_budget=st.floats(0, 300, **_FINITE))
    def test_gate_is_monotone_in_thresholds(gain, restart, spent, min_gain,
                                            budget, d_gain, d_budget):
        _check_gate_monotone(gain, restart, spent, min_gain, budget,
                             d_gain, d_budget)

    @settings(max_examples=50, deadline=None)
    @given(live=st.floats(0, 10, **_FINITE),
           anchor=st.floats(1e-3, 10, **_FINITE),
           watch=st.integers(0, 20),
           thr=st.floats(0, 1, **_FINITE),
           d_thr=st.floats(0, 1, **_FINITE))
    def test_rollback_is_monotone_in_threshold(live, anchor, watch, thr,
                                               d_thr):
        _check_rollback_monotone(live, anchor, watch, thr, d_thr)
else:
    @pytest.mark.parametrize(
        "gain,restart,spent,min_gain,budget,d_gain,d_budget", [
            (0.1, 15.0, 0.0, 0.05, 100.0, 0.2, 50.0),
            (-0.2, 15.0, 90.0, 0.0, 100.0, 0.5, 10.0),
            (0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0),
            (2.0, 50.0, 60.0, 0.1, 100.0, 0.0, 0.0)])
    def test_gate_is_monotone_in_thresholds(gain, restart, spent, min_gain,
                                            budget, d_gain, d_budget):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _check_gate_monotone(gain, restart, spent, min_gain, budget,
                             d_gain, d_budget)

    @pytest.mark.parametrize("live,anchor,watch,thr,d_thr", [
        (0.5, 1.0, 3, 0.05, 0.5), (0.99, 1.0, 1, 0.05, 0.0),
        (1.5, 1.0, 5, 0.1, 0.2), (0.0, 1.0, 0, 0.0, 1.0)])
    def test_rollback_is_monotone_in_threshold(live, anchor, watch, thr,
                                               d_thr):
        """Fixed-seed fallback when hypothesis is unavailable."""
        _check_rollback_monotone(live, anchor, watch, thr, d_thr)


def test_promoted_steps_really_cleared_the_min_gain_bar():
    """Recompute each promoted step's shadow gain from the trace (f32, the
    in-graph formula) — every promotion cleared ``min_gain``; every
    gain-rejection missed it."""
    pol = DeploymentPolicy(min_gain=0.02, rollback_window=4)
    t = _tuner(policy=pol, seed=11)
    res = t.run(14)
    objectives = np.asarray([h.objective for h in res.history], np.float32)
    shadow = np.asarray(t.shadow_objectives, np.float32)
    ev = t.guard_events
    for i in range(1, len(ev)):  # step 0's baseline predates the trace
        prev = objectives[i - 1]
        gain = np.float32(shadow[i] - prev) / np.maximum(
            prev, np.float32(1e-6))
        if ev[i] & EVENT_PROMOTED:
            assert gain >= np.float32(pol.min_gain) - np.float32(1e-6)
        if ev[i] & EVENT_REJECTED_GAIN:
            assert gain < np.float32(pol.min_gain) + np.float32(1e-6)


def test_best_objective_never_below_promotion_anchors():
    """Rollback bookkeeping never erases best tracking: the history maximum
    dominates every promotion's pre-promotion anchor objective."""
    pol = DeploymentPolicy(min_gain=-0.5, rollback_window=8,
                           rollback_threshold=0.2)
    t = _faulted_tuner(
        [throughput_collapse(start=5, duration=8, to_fraction=0.2)], pol)
    res = t.run(16)
    hist_best = max(h.objective for h in res.history)
    for p in np.nonzero(t.guard_events & EVENT_PROMOTED)[0]:
        if p == 0:
            continue
        assert hist_best >= res.history[p - 1].objective


# ---------------------------------------------------------------------------
# Counter plumbing + guarded fleet/service integration
# ---------------------------------------------------------------------------

def test_counters_agree_with_in_graph_guard_totals():
    pol = DeploymentPolicy(min_gain=-10.0, rollback_window=5)
    t = _tuner(policy=pol)
    s = t.run(9).guardrail_stats
    assert s["promotions"] == s["promotions_total"]
    assert s["rollbacks"] == s["rollbacks_total"]
    # trace restarts are decoded fixed-point f32 summed in f64; the guard
    # total is the in-graph f32 running sum — identical up to f32 rounding
    assert s["restart_budget_spent"] == pytest.approx(
        s["restart_seconds"], rel=1e-5)
    assert s["proposals"] == 9


def test_merge_counters_and_empty_counters():
    a = guardrail_counters(np.array([1, 2, 9], np.uint8),
                           np.array([10.0, 0.0, 5.0]))
    assert a["proposals"] == 3 and a["promotions"] == 2
    assert a["rejected_min_gain"] == 1 and a["rollbacks"] == 1
    assert a["restart_seconds"] == 15.0
    merged = merge_counters(a, empty_counters())
    assert merged == a
    assert empty_counters()["restart_seconds"] == 0.0


def test_guarded_fleet_chunk_invariance():
    """Chunking stays pure scheduling under guardrails: guarded chunked ==
    guarded monolithic (decisions + guard events exact, floats
    cross-width)."""
    pol = DeploymentPolicy(min_gain=-10.0, rollback_window=4)
    mono, chunked = _fleet(policy=pol, chunk=None), _fleet(policy=pol,
                                                           chunk=2)
    rm, rc = mono.run(6), chunked.run(6)
    assert np.array_equal(mono.guard_events, chunked.guard_events)
    for a, b in zip(rm.results, rc.results):
        _assert_bitwise_equal_runs(a, b, maxulp=32)
        assert a.guardrail_stats["promotions"] == \
            b.guardrail_stats["promotions"]
        assert a.guardrail_stats["rollbacks"] == \
            b.guardrail_stats["rollbacks"]


def test_guarded_service_checkpoint_resumes_bit_identically(tmp_path):
    from repro.core import FleetService

    pol = DeploymentPolicy(min_gain=-10.0, rollback_window=4,
                           max_restart_seconds=200.0)
    svc = FleetService(chunk=2, warmup_steps=3, policy=pol,
                       checkpoint_dir=str(tmp_path))
    a = svc.request_join("seq_write", {"throughput": 1.0}, 0)
    b = svc.request_join("random_rw", {"iops": 1.0}, 1)
    svc.advance(5)
    assert set(svc.last_stats["guardrails"]) == set(empty_counters())
    svc.checkpoint()
    svc.advance(4)
    want = {sid: svc.guardrail_stats(sid) for sid in (a, b)}
    want_cfg = {sid: dict(svc._sessions[sid].cur_config) for sid in (a, b)}

    svc2 = FleetService.restore(str(tmp_path))
    assert svc2.policy == pol
    svc2.advance(4)
    for sid in (a, b):
        assert svc2.guardrail_stats(sid) == want[sid]
        assert svc2._sessions[sid].cur_config == want_cfg[sid]
    # departure surfaces the record on the TuningResult
    svc2.request_leave(a)
    svc2.advance(0)
    res = svc2.result(a)
    assert res.guardrail_stats["promotions_total"] == \
        want[a]["promotions_total"]
    assert res.guardrail_stats["policy"]["rollback_window"] == 4


def test_guardrails_require_the_scan_engine():
    env = LustreSimEnv("seq_write", seed=0)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    with pytest.raises(ValueError, match="scan"):
        Tuner(env, scal, engine="host", policy=DeploymentPolicy())
    with pytest.raises(ValueError, match="scan"):
        _fleet_host = FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [0], engine="host",
            env_cls=LustreSimEnv, policy=DeploymentPolicy())
