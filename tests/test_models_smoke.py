"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU asserting output shapes + no NaNs, plus prefill/decode
consistency against the parallel forward — for every assigned arch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.models import (
    decode_step, forward, init_params, make_cache, model_defs, prefill,
)
from repro.training import TrainConfig, make_train_step

# ~80 s of per-arch compile-heavy smoke tests: slow lane (CI runs -m slow
# separately; the fast lane stays under a minute).
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.mrope_sections:
        kwargs["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (B, 3, S)).astype(jnp.int32)
    if cfg.is_encdec:
        kwargs["input_embeds"] = jax.random.normal(
            KEY, (B, cfg.encoder_seq, cfg.d_model))
    return tokens, kwargs


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_shapes_and_finiteness(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(model_defs(cfg), KEY)
    tokens, kwargs = _inputs(cfg)
    logits, aux = forward(cfg, params, tokens, **kwargs)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_one_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    defs = model_defs(cfg)
    params = init_params(defs, KEY)
    tx = optim.adamw(1e-3)
    opt = tx.init(params)
    step = jax.jit(make_train_step(cfg, tx, TrainConfig(microbatches=2)))
    tokens, kwargs = _inputs(cfg, B=4, S=16)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)}
    if "positions" in kwargs:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(16)[None, None, :], (4, 3, 16)).astype(jnp.int32)
    if "input_embeds" in kwargs:
        batch["input_embeds"] = jax.random.normal(
            KEY, (4, cfg.encoder_seq, cfg.d_model))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    diff = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    assert max(jax.tree_util.tree_leaves(diff)) > 0


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    """decode after prefill == the parallel forward on the extended seq."""
    cfg = configs.get_smoke_config(arch)
    params = init_params(model_defs(cfg), KEY)
    B, S = 2, 12
    tokens, kwargs = _inputs(cfg, B, S)
    cache = make_cache(cfg, B, 32)
    lg, cache = prefill(cfg, params, tokens, cache, **{
        k: v for k, v in kwargs.items()
        if k in ("positions", "input_embeds")})
    tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    lg2, _ = decode_step(cfg, params, tok, cache, jnp.asarray(S, jnp.int32))
    ext = jnp.concatenate([tokens, tok], axis=1)
    fw_kwargs = dict(kwargs)
    if cfg.mrope_sections:
        fw_kwargs["positions"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None, :], (B, 3, S + 1)).astype(jnp.int32)
    lg_full, _ = forward(cfg, params, ext, **fw_kwargs)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(lg_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch,expected_b", [
    ("qwen2-vl-72b", 72.5), ("zamba2-7b", 6.6), ("whisper-large-v3", 1.5),
    ("arctic-480b", 476.0), ("deepseek-moe-16b", 16.9),
    ("minicpm3-4b", 4.1), ("phi4-mini-3.8b", 3.7), ("yi-9b", 8.8),
    ("codeqwen1.5-7b", 8.2), ("rwkv6-3b", 3.1),
])
def test_full_config_param_counts(arch, expected_b):
    """FULL configs instantiated only as defs (no allocation): the parameter
    count must match the advertised model scale (DESIGN.md §4 notes the
    documented approximations)."""
    from repro.models.base import param_count
    n = param_count(model_defs(configs.get_config(arch))) / 1e9
    assert abs(n - expected_b) / expected_b < 0.12, (arch, n)


def test_moe_capacity_drop_and_combine():
    """Tokens over capacity are dropped (zero contribution), and combine
    weights renormalize over top-k."""
    from repro.models.moe import moe_apply
    cfg = configs.get_smoke_config("deepseek-moe-16b")
    # tiny capacity forces drops
    object.__setattr__(cfg.moe, "capacity_factor", 0.1)
    params = init_params(model_defs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"]["moe"])
    out, aux = moe_apply(cfg, lp, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0
