"""Fleet-tuning subsystem tests: fused scan learner, batched replay buffer,
vmapped multi-session agent/tuner.

The load-bearing properties:
  * ``ddpg_learn_scan`` == N sequential ``ddpg_update`` calls on the same
    minibatches (the fusion changes dispatch count, not math);
  * ``BatchedReplayBuffer`` has per-session FIFO semantics identical to N
    independent ``ReplayBuffer``s written in lockstep;
  * a fleet of one reproduces the single ``Tuner``/``MagpieAgent`` session
    exactly (sessions are independent; the fleet axis is pure throughput).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BatchedReplayBuffer,
    DDPGConfig,
    FleetAgent,
    FleetTuner,
    MagpieAgent,
    ReplayBuffer,
    Scalarizer,
    Tuner,
    ddpg_init,
    ddpg_learn_scan,
    ddpg_update,
    fleet_init,
    fleet_learn_scan,
    sample_minibatch_indices,
)
from repro.envs import LustreSimEnv
from repro.envs.lustre_sim import batch_mean_performance


def _filled_storage(rng, cap, size, state_dim=3, action_dim=2):
    s = np.zeros((cap, state_dim), np.float32)
    a = np.zeros((cap, action_dim), np.float32)
    r = np.zeros((cap,), np.float32)
    s2 = np.zeros((cap, state_dim), np.float32)
    s[:size] = rng.random((size, state_dim))
    a[:size] = rng.random((size, action_dim))
    r[:size] = rng.standard_normal(size)
    s2[:size] = rng.random((size, state_dim))
    return (s, a, r, s2)


# ---------------------------------------------------------------------------
# Fused scan learner
# ---------------------------------------------------------------------------

@pytest.mark.skipif(
    os.environ.get("REPRO_KERNELS") in ("pallas", "interpret"),
    reason="bitwise contract is the XLA scan path's; the Pallas kernel path "
           "holds the ulp contract instead (tests/test_ddpg_fused.py)")
def test_learn_scan_matches_sequential_updates():
    """One fused scan == the same minibatches through ddpg_update, bitwise."""
    cfg = DDPGConfig(state_dim=3, action_dim=2, updates_per_step=12)
    state, (atx, ctx) = ddpg_init(jax.random.PRNGKey(0), cfg)
    data = _filled_storage(np.random.default_rng(0), cap=32, size=20)
    key = jax.random.PRNGKey(42)

    fused_state, ms = ddpg_learn_scan(state, data, 20, key, cfg, atx, ctx, 12)

    idx = np.asarray(sample_minibatch_indices(key, 12, cfg.batch_size,
                                              jnp.asarray(20)))
    s, a, r, s2 = data
    seq_state = state
    for ix in idx:
        seq_state, m = ddpg_update(seq_state, (s[ix], a[ix], r[ix], s2[ix]),
                                   cfg, atx, ctx)

    diffs = jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x - y))), fused_state, seq_state)
    assert max(jax.tree_util.tree_leaves(diffs)) == 0.0
    # stacked metrics: one row per update, last row == last sequential metrics
    assert ms["critic_loss"].shape == (12,)
    assert float(ms["critic_loss"][-1]) == float(m["critic_loss"])


def test_learn_scan_restricts_sampling_to_valid_rows():
    key = jax.random.PRNGKey(7)
    idx = np.asarray(sample_minibatch_indices(key, 50, 16, jnp.asarray(5)))
    assert idx.min() >= 0 and idx.max() < 5


def test_agent_fused_learn_is_default_and_converges():
    """The agent's fused path reduces critic loss like the legacy loop did."""
    cfg = DDPGConfig(state_dim=3, action_dim=2)
    agent = MagpieAgent(cfg, seed=0)
    rng = np.random.default_rng(0)
    for _ in range(20):
        s = rng.random(3).astype(np.float32)
        a = rng.random(2).astype(np.float32)
        agent.observe(s, a, float(a[0] - 0.5 * a[1]), rng.random(3))
    first = agent.learn(updates=8)["critic_loss"]
    for _ in range(20):
        last = agent.learn(updates=8)["critic_loss"]
    assert last < first


# ---------------------------------------------------------------------------
# Batched replay buffer
# ---------------------------------------------------------------------------

def test_batched_buffer_fifo_parity_with_replay_buffer():
    """Per-session contents identical to N independent ReplayBuffers."""
    n, cap = 3, 4
    batched = BatchedReplayBuffer(n, cap, state_dim=2, action_dim=1)
    singles = [ReplayBuffer(cap, 2, 1) for _ in range(n)]
    rng = np.random.default_rng(0)
    for t in range(7):  # overfills capacity -> FIFO eviction exercised
        s = rng.random((n, 2)).astype(np.float32)
        a = rng.random((n, 1)).astype(np.float32)
        r = rng.random(n).astype(np.float32)
        s2 = rng.random((n, 2)).astype(np.float32)
        batched.add(s, a, r, s2)
        for i, buf in enumerate(singles):
            buf.add(s[i], a[i], float(r[i]), s2[i])
    assert len(batched) == min(7, cap) == len(singles[0])
    bs, ba, br, bs2 = batched.as_arrays()
    for i, buf in enumerate(singles):
        ss, sa, sr, ss2 = buf.as_arrays()
        np.testing.assert_array_equal(bs[i], ss)
        np.testing.assert_array_equal(ba[i], sa)
        np.testing.assert_array_equal(br[i], sr)
        np.testing.assert_array_equal(bs2[i], ss2)
    # storage() views agree too (used by the fused learner)
    (fs, _, fr, _), sizes = batched.storage()
    (gs, _, gr, _), size0 = singles[0].storage()
    assert int(sizes[0]) == size0
    np.testing.assert_array_equal(np.asarray(fs[0]), gs)


def test_batched_buffer_sample_shapes_and_roundtrip():
    buf = BatchedReplayBuffer(2, 8, state_dim=3, action_dim=2)
    rng = np.random.default_rng(1)
    for _ in range(5):
        buf.add(rng.random((2, 3)), rng.random((2, 2)), rng.random(2),
                rng.random((2, 3)))
    keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
    s, a, r, s2 = buf.sample(keys, batch_size=4)
    assert s.shape == (2, 4, 3) and a.shape == (2, 4, 2) and r.shape == (2, 4)
    buf2 = BatchedReplayBuffer(2, 8, state_dim=3, action_dim=2)
    buf2.load_state_dict(buf.state_dict())
    for x, y in zip(buf.as_arrays(), buf2.as_arrays()):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Vmapped fleet learner
# ---------------------------------------------------------------------------

def test_fleet_learner_sessions_are_independent_and_match_single():
    """Each fleet session evolves exactly as the same-seed single learner."""
    cfg = DDPGConfig(state_dim=3, action_dim=2)
    seeds = [0, 7]
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    fstates, (atx, ctx) = fleet_init(keys, cfg)

    rng = np.random.default_rng(3)
    data = [_filled_storage(rng, cap=16, size=10) for _ in seeds]
    batched = tuple(np.stack([d[j] for d in data]) for j in range(4))
    learn_keys = jnp.stack([jax.random.PRNGKey(s + 3) for s in seeds])

    fstates, _ = fleet_learn_scan(fstates, batched, jnp.asarray([10, 10]),
                                  learn_keys, cfg, atx, ctx, 6)

    for i, seed in enumerate(seeds):
        single, (atx1, ctx1) = ddpg_init(jax.random.PRNGKey(seed), cfg)
        single, _ = ddpg_learn_scan(single, data[i], 10,
                                    jax.random.PRNGKey(seed + 3),
                                    cfg, atx1, ctx1, 6)
        diffs = jax.tree_util.tree_map(
            lambda x, y, i=i: float(jnp.max(jnp.abs(x[i] - y))),
            fstates, single)
        # Batched (N>=2) matmuls may fuse/reduce in a different order than
        # the unbatched ones — float32 noise only, the trajectories match.
        # (A fleet of exactly one is bitwise-identical; see the parity test.)
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-5


# ---------------------------------------------------------------------------
# Vectorized environment surface
# ---------------------------------------------------------------------------

def test_batch_mean_performance_matches_scalar():
    envs, configs = [], []
    for i, w in enumerate(["file_server", "video_server", "seq_write",
                           "seq_read", "random_rw"]):
        envs.append(LustreSimEnv(w, seed=i))
        configs.append({"stripe_count": 1 + i % 6,
                        "stripe_size": int(64 * 1024 * 2 ** (2 * i % 11))})
    batch = batch_mean_performance(envs, configs)
    for env, config, got in zip(envs, configs, batch):
        ref = env.mean_performance(config)
        for k in ref:
            assert np.isclose(float(ref[k]), got[k], rtol=1e-12, atol=0.0), k


def test_batch_mean_performance_validates_configs():
    env = LustreSimEnv("seq_write", seed=0)
    import pytest
    with pytest.raises(ValueError):
        batch_mean_performance([env], [{"stripe_count": 99,
                                        "stripe_size": 1 << 20}])


# ---------------------------------------------------------------------------
# FleetTuner
# ---------------------------------------------------------------------------

def test_fleet_of_one_matches_single_tuner():
    """Same seed -> identical trajectory, best config and objective."""
    seed, workload, steps = 5, "seq_write", 12
    env = LustreSimEnv(workload, seed=seed)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig(state_dim=env.state_dim,
                                   action_dim=env.action_dim), seed=seed)
    single = Tuner(env, scal, agent).run(steps)

    fleet = FleetTuner.from_grid([workload], [{"throughput": 1.0}], [seed])
    fres = fleet.run(steps)
    assert len(fres.results) == 1
    got = fres.results[0]

    assert got.best_config == single.best_config
    assert got.default_config == single.default_config
    assert np.isclose(got.best_objective, single.best_objective, rtol=1e-9)
    for h_single, h_fleet in zip(single.history, got.history):
        assert h_fleet.config == h_single.config
        assert np.isclose(h_fleet.objective, h_single.objective, rtol=1e-9)
        assert np.isclose(h_fleet.restart_seconds, h_single.restart_seconds)
    for k, v in single.default_metrics.items():
        assert np.isclose(got.default_metrics[k], v, rtol=1e-9)


def test_fleet_grid_runs_concurrently_with_aggregates():
    """A seeds x workloads grid (>= 8 sessions) in one process, with the
    paper-style aggregate gain report."""
    fleet = FleetTuner.from_grid(
        ["seq_write", "file_server"], [{"throughput": 1.0}],
        [0, 1, 2, 3], eval_runs=1)
    assert fleet.agent.num_sessions == 8
    res = fleet.run(8)
    assert len(res.results) == 8 and len(res.labels) == 8
    assert all(len(r.history) == 8 for r in res.results)
    summary = res.summary("throughput")
    assert summary["sessions"] == 8
    assert summary["min"] <= summary["p50"] <= summary["max"]
    assert np.isfinite(summary["mean"])
    # labels encode the grid cell and resolve back to their session
    assert "seq_write|throughput|seed0" in res.labels
    r0 = res.by_label("seq_write|throughput|seed0")
    assert r0 is res.results[res.labels.index("seq_write|throughput|seed0")]


def test_fleet_progressive_runs_accumulate_history():
    fleet = FleetTuner.from_grid(["seq_write"], [{"throughput": 1.0}],
                                 [0, 1], eval_runs=1)
    r1 = fleet.run(4)
    r2 = fleet.run(4)
    assert all(len(r.history) == 8 for r in r2.results)
    # The best objective SEEN during tuning never regresses across calls.
    # (TuningResult.best_objective itself is a fresh noisy re-evaluation of
    # the best config, so it may fluctuate — same as the single Tuner.)
    for a, b in zip(r1.results, r2.results):
        best4 = max(h.objective for h in a.history)
        best8 = max(h.objective for h in b.history)
        assert best8 >= best4 - 1e-9


def test_fleet_agent_act_respects_warmup_and_bounds():
    cfg = DDPGConfig(state_dim=2, action_dim=2)
    agent = FleetAgent(cfg, seeds=[0, 1, 2], warmup_steps=3)
    states = np.full((3, 2), 0.5, np.float32)
    for _ in range(6):
        a = agent.act(states)
        assert a.shape == (3, 2)
        assert (a >= 0.0).all() and (a <= 1.0).all()
    # sessions with different seeds explore differently
    a0 = FleetAgent(cfg, seeds=[0, 1], warmup_steps=1).act(states[:2])
    assert not np.allclose(a0[0], a0[1])
