"""End-to-end behaviour tests for the paper's system: the full Magpie stack
(collector -> state -> DDPG -> action mapping -> restart accounting) against
the calibrated Lustre environment, plus the beyond-paper sharding
environment driven by the SAME agent code."""

import numpy as np
import pytest

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimEnv


def test_end_to_end_single_objective():
    env = LustreSimEnv("video_server", seed=0)
    sc = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(
        DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim),
        seed=0)
    res = Tuner(env, sc, agent).run(30)
    # noticeable gain (paper: +65% band on this workload)
    assert res.gain("throughput") > 0.15
    # history bookkeeping: 30 steps, restarts accounted, rewards finite
    assert len(res.history) == 30
    assert all(np.isfinite(h.reward) for h in res.history)
    assert res.simulated_restart_seconds >= 12.0


def test_end_to_end_multi_objective():
    env = LustreSimEnv("random_rw", seed=0)
    sc = Scalarizer(weights={"throughput": 1.0, "iops": 1.0},
                    specs=env.metric_specs)
    agent = MagpieAgent(
        DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim),
        seed=0)
    res = Tuner(env, sc, agent).run(30)
    # both objectives improve (scalarization balances them)
    assert res.gain("iops") > 0.2
    assert res.gain("throughput") > 0.0


@pytest.mark.slow  # ~30 s: repeatedly recompiles train cells while tuning
def test_sharding_env_with_magpie_agent():
    """The paper's technique as a first-class framework feature: tune this
    framework's own static compile parameters with the SAME agent."""
    import jax
    from repro.envs.sharding_env import ShardingEnv
    from repro.launch.mesh import make_test_mesh
    mesh = make_test_mesh((1, 1), ("data", "model"))
    env = ShardingEnv("yi-9b", "train_4k", mesh=mesh, smoke=True,
                      microbatch_choices=(1, 2, 4))
    sc = Scalarizer(weights={"steps_per_s": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(
        DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim),
        seed=0, warmup_steps=4)
    tuner = Tuner(env, sc, agent, eval_runs=1)
    res = tuner.run(6)
    assert res.best_metrics["steps_per_s"] > 0
    assert res.best_config["microbatches"] in (1, 2, 4)
    assert res.best_config["remat"] in ("none", "dots", "full")
    # recompiles were accounted as restart cost
    assert res.simulated_restart_seconds > 0
