"""Persistent FleetService (core/service.py): the churn CI lane.

Load-bearing properties pinned here:
  * a service whose sessions all join before the first ``advance`` and leave
    after the last reproduces the static ``FleetTuner`` single-run results
    EXACTLY (maxulp=0) — the serving loop adds scheduling, not arithmetic;
  * churn is bit-neutral: sessions joining and leaving at EVERY advance
    boundary leave the survivors' decision trajectories bitwise identical
    to a churn-free service on the same cadence (vmap row independence:
    a session's trajectory derives from its own seed streams, never from
    its row placement or chunk-mates);
  * kill-and-resume: a service restored from a ``checkpoint/store.py``
    snapshot continues bit-identically — same histories, same results;
  * checkpoints refuse to drop pending membership requests, and leases
    (chunk slots) are recycled across leave/join.
"""

import numpy as np
import pytest

from repro.core import DDPGConfig, FleetService, FleetTuner
from repro.envs import LustreSimEnv

from tests.test_episode import _assert_bitwise_equal_runs

W = {"throughput": 1.0}


def _cfg():
    return DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=4)


def _service(chunk=2, **kw):
    kw.setdefault("ddpg_config", _cfg())
    kw.setdefault("warmup_steps", 3)
    kw.setdefault("eval_runs", 1)
    return FleetService(chunk=chunk, **kw)


def _assert_exact_histories(a, b):
    """Bitwise history equality (timing fields excluded — they are wall
    clock, everything else must be exact)."""
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.step == rb.step and ra.config == rb.config
        assert ra.metrics == rb.metrics
        assert ra.objective == rb.objective and ra.reward == rb.reward
        assert ra.restart_seconds == rb.restart_seconds


# ---------------------------------------------------------------------------
# Service == static FleetTuner (full-lifetime sessions, one advance)
# ---------------------------------------------------------------------------

def test_service_matches_static_fleet_exactly():
    seeds, steps = [0, 1, 2, 3], 6
    fleet = FleetTuner.from_grid(
        ["seq_write"], [W], seeds, engine="scan", ddpg_config=_cfg(),
        eval_runs=1, warmup_steps=3, chunk=2)
    static = fleet.run(steps)

    svc = _service(chunk=2)
    # from_grid offsets cell seeds by 1000 per cell; mirror that here so
    # both populations consume identical RNG streams
    sids = [svc.request_join("seq_write", W, s + 1000 * i)
            for i, s in enumerate(seeds)]
    advanced = svc.advance(steps)
    assert advanced == sids
    stats = svc.last_stats
    assert stats["sessions"] == 4 and stats["chunk"] == 2
    assert stats["num_chunks"] == 2
    for sid in sids:
        svc.request_leave(sid)
    assert svc.advance(0) == []  # membership-only boundary
    assert svc.active == {}
    for sid, res in zip(sids, static.results):
        got = svc.result(sid)
        _assert_bitwise_equal_runs(res, got, maxulp=0)
        _assert_exact_histories(res.history, got.history)
        assert got.simulated_restart_seconds == res.simulated_restart_seconds


# ---------------------------------------------------------------------------
# Churn: join/leave every boundary is bit-neutral for survivors
# ---------------------------------------------------------------------------

def test_churn_every_boundary_is_bitwise_neutral():
    rounds, steps = 3, 2

    quiet = _service(chunk=2)
    survivors_q = [quiet.request_join("seq_write", W, s) for s in (0, 1)]
    for _ in range(rounds):
        quiet.advance(steps)
    for sid in survivors_q:
        quiet.request_leave(sid)
    quiet.advance(0)

    churn = _service(chunk=2)
    survivors_c = [churn.request_join("seq_write", W, s) for s in (0, 1)]
    transient = None
    for r in range(rounds):
        # a fresh tenant joins every round; the previous one departs —
        # membership changes at EVERY boundary while the survivors run
        if transient is not None:
            churn.request_leave(transient)
        transient = churn.request_join("seq_write", W, 50 + r)
        churn.advance(steps)
        assert transient in churn.active
    churn.request_leave(transient)
    for sid in survivors_c:
        churn.request_leave(sid)
    churn.advance(0)

    for sq, sc in zip(survivors_q, survivors_c):
        a, b = quiet.result(sq), churn.result(sc)
        _assert_bitwise_equal_runs(a, b, maxulp=0)
        _assert_exact_histories(a.history, b.history)
    # the transients really ran (steps per round while leased)
    assert len(churn.result(transient).history) == steps


def test_fixed_lease_width_reuses_one_executable():
    """The service always runs chunks at exactly ``chunk`` rows, so growing
    the population adds chunks, never compiled shapes: the second advance
    reuses the first one's program AND its compiled-shape bucket (relative
    check — other tests may share the underlying program cache)."""
    svc = _service(chunk=2)
    svc.request_join("seq_write", W, 0)
    svc.advance(2)
    first = dict(svc.last_stats)
    svc.request_join("seq_write", W, 1)
    svc.request_join("seq_write", W, 2)
    svc.advance(2)
    second = svc.last_stats
    assert (first["num_chunks"], second["num_chunks"]) == (1, 2)
    assert second["program"] is first["program"]
    assert second["executable_cache_size"] == first["executable_cache_size"]


def test_leases_are_recycled():
    svc = _service(chunk=2)
    a = svc.request_join("seq_write", W, 0)
    b = svc.request_join("seq_write", W, 1)
    svc.advance(1)
    assert svc.lease_table() == [a, b]
    svc.request_leave(a)
    c = svc.request_join("seq_write", W, 2)
    svc.advance(1)
    assert svc.lease_table() == [c, b]  # freed slot reused, not appended
    assert svc.result(a).best_config  # departed session finalized


# ---------------------------------------------------------------------------
# Kill-and-resume: bitwise continuation from a checkpoint
# ---------------------------------------------------------------------------

def test_kill_and_resume_is_bitwise(tmp_path):
    ckpt = str(tmp_path / "svc")
    svc = _service(chunk=2, checkpoint_dir=ckpt)
    sids = [svc.request_join("seq_write", W, s) for s in (0, 1, 2)]
    svc.advance(4)
    path = svc.checkpoint()
    assert str(tmp_path) in path

    # original keeps going...
    svc.advance(3)
    for sid in sids:
        svc.request_leave(sid)
    svc.advance(0)

    # ...the restored twin continues from the snapshot
    res = FleetService.restore(ckpt)
    assert res.total_steps == 4 and res.lease_table() == sids
    assert set(res.active) == set(sids)
    res.advance(3)
    for sid in sids:
        res.request_leave(sid)
    res.advance(0)

    for sid in sids:
        a, b = svc.result(sid), res.result(sid)
        _assert_bitwise_equal_runs(a, b, maxulp=0)
        _assert_exact_histories(a.history, b.history)
        assert a.simulated_restart_seconds == b.simulated_restart_seconds
        assert a.default_metrics == b.default_metrics


def test_checkpoint_refuses_pending_requests(tmp_path):
    svc = _service(chunk=2, checkpoint_dir=str(tmp_path / "svc"))
    svc.request_join("seq_write", W, 0)
    with pytest.raises(RuntimeError, match="pending"):
        svc.checkpoint()
    svc.advance(1)
    svc.checkpoint()  # applied at the boundary -> checkpointable


def test_restore_detects_environment_drift(tmp_path):
    ckpt = str(tmp_path / "svc")
    svc = _service(chunk=2, checkpoint_dir=ckpt)
    svc.request_join("seq_write", W, 0)
    svc.advance(2)
    svc.checkpoint()

    def drifted(workload, seed):
        # a different workload calibration = different model params (the
        # seed alone wouldn't drift them: it only seeds the state RNG)
        return LustreSimEnv("random_rw", seed=seed).to_model_env()

    with pytest.raises(ValueError, match="drifted"):
        FleetService.restore(ckpt, env_factory=drifted)


def test_unknown_session_raises():
    svc = _service(chunk=2)
    with pytest.raises(KeyError):
        svc.request_leave(99)
    with pytest.raises(KeyError):
        svc.result(99)
