"""Serve a small model with batched requests: prefill + decode loop over the
public API, one architecture per family (GQA, MLA, SSM).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import init_params, model_defs
from repro.training.steps import make_decode_step, make_prefill_step


def serve(arch: str, batch=4, prompt_len=32, gen=24) -> None:
    cfg = configs.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(model_defs(cfg), key)
    max_seq = prompt_len + gen
    prompts = jax.random.randint(key, (batch, prompt_len), 1, cfg.vocab_size)
    enc = (jax.random.normal(key, (batch, cfg.encoder_seq, cfg.d_model))
           if cfg.is_encdec else None)

    prefill_fn = jax.jit(make_prefill_step(cfg, batch, max_seq))
    decode_fn = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    logits, cache = prefill_fn(params, prompts, None, enc)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    toks = [tok]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, cache = decode_fn(params, tok, cache,
                                  jnp.asarray(prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    ms = (time.perf_counter() - t0) / (gen - 1) * 1e3
    print(f"{arch:20s} batch={batch} prompt={prompt_len} "
          f"gen={gen}: {ms:6.2f} ms/token (CPU, smoke config)")


def main() -> None:
    for arch in ("yi-9b", "minicpm3-4b", "rwkv6-3b"):
        serve(arch)


if __name__ == "__main__":
    main()
