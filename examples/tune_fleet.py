"""Fleet tuning: the paper's whole evaluation grid as one fused JAX program.

Runs a seeds x workloads x objectives grid of independent Magpie tuning
sessions concurrently — vmapped DDPG learners, device-resident replay, and a
vectorized Lustre response surface — then prints per-session results plus the
aggregate gain statistics the paper reports in Fig. 4/5 (91.8% average
throughput gain across workloads).

    PYTHONPATH=src python examples/tune_fleet.py
"""

from repro.core import FleetTuner


def main() -> None:
    fleet = FleetTuner.from_grid(
        workloads=["seq_write", "video_server", "file_server"],
        objectives=[{"throughput": 1.0}],
        seeds=[0, 1, 2],
    )
    print(f"running {fleet.agent.num_sessions} tuning sessions concurrently...")
    result = fleet.run(steps=30)  # paper's budget, every session

    for label, res in zip(result.labels, result.results):
        print(f"{label:40s} {res.default_metrics['throughput']:7.1f} "
              f"-> {res.best_metrics['throughput']:7.1f} MB/s "
              f"({res.gain('throughput')*100:+.1f}%)  best={res.best_config}")

    stats = result.summary("throughput")
    print(f"\naggregate throughput gain over {stats['sessions']} sessions: "
          f"mean {stats['mean']*100:+.1f}%  "
          f"p25/p50/p75 {stats['p25']*100:+.1f}/{stats['p50']*100:+.1f}/"
          f"{stats['p75']*100:+.1f}%  "
          f"range [{stats['min']*100:+.1f}%, {stats['max']*100:+.1f}%]")
    print(f"fleet wall time: {result.wall_seconds:.1f}s "
          f"for {stats['sessions']} x 30-step sessions")


if __name__ == "__main__":
    main()
