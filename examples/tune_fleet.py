"""Fleet tuning: the paper's whole evaluation grid as one fused JAX program.

Runs a seeds x workloads x objectives grid of independent Magpie tuning
sessions concurrently, then prints per-session results plus the aggregate
gain statistics the paper reports in Fig. 4/5 (91.8% average throughput gain
across workloads).

    PYTHONPATH=src python examples/tune_fleet.py
    PYTHONPATH=src python examples/tune_fleet.py --sessions 64 --chunk 16

``--sessions N`` spreads N sessions (seeds) over the workloads and runs them
through the streaming chunked scan engine: chunks of ``--chunk`` sessions
stream through ONE compiled episode program, so peak device memory is
O(chunk) no matter how large the fleet — the printed ``memory_plan()``
summary shows the capacity math before anything runs. ``--compile-cache``
persists the compiled episode across processes (back-to-back runs skip
XLA compilation entirely).
"""

import argparse

from repro.core import FleetTuner


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--sessions", type=int, default=9,
                        help="total tuning sessions (spread over 3 workloads)")
    parser.add_argument("--chunk", type=int, default=None,
                        help="sessions per streamed chunk (scan engine); "
                        "default: one chunk of the whole fleet")
    parser.add_argument("--steps", type=int, default=30,
                        help="tuning steps per session (paper budget: 30)")
    parser.add_argument("--compile-cache", nargs="?", const="", default=None,
                        metavar="DIR",
                        help="enable JAX's persistent compilation cache "
                        "(optional DIR; default ~/.cache/repro-jax-cache)")
    args = parser.parse_args()

    if args.compile_cache is not None:
        from repro.core import enable_persistent_compilation_cache
        path = enable_persistent_compilation_cache(args.compile_cache or None)
        print(f"persistent compilation cache: {path}")

    workloads = ["seq_write", "video_server", "file_server"]
    # the grid is a full workloads x seeds cross product, so the session
    # count is rounded to the nearest multiple of len(workloads) — say so
    # instead of silently running a different fleet than requested
    seeds = list(range(max(1, round(args.sessions / len(workloads)))))
    n_sessions = len(workloads) * len(seeds)
    if n_sessions != args.sessions:
        print(f"note: running {n_sessions} sessions "
              f"({len(workloads)} workloads x {len(seeds)} seeds; "
              f"{args.sessions} requested)")
    engine = "scan" if (args.chunk is not None or n_sessions > 9) else "host"
    fleet = FleetTuner.from_grid(
        workloads=workloads,
        objectives=[{"throughput": 1.0}],
        seeds=seeds,
        engine=engine,
        chunk=args.chunk if engine == "scan" else None,
        eval_runs=1 if n_sessions > 9 else 3,
    )

    if engine == "scan":
        plan = fleet.memory_plan(steps=args.steps)
        per = plan["per_session"]
        print(f"memory plan ({plan['sessions']} sessions, chunk "
              f"{plan['chunk']}, {plan['steps']} steps):")
        print(f"  per session: learner {per['learner_bytes']:,} B, replay "
              f"{per['replay_bytes']:,} B ({plan['replay_dtype']}), trace "
              f"{per['trace_bytes_per_step']} B/step")
        print(f"  device (one chunk resident): "
              f"{plan['chunk_device_bytes']:,} B")
        print(f"  host (whole fleet): {plan['fleet_host_bytes']:,} B "
              f"(validated vs live buffers: {plan['matches_live']})")

    print(f"running {fleet.agent.num_sessions} tuning sessions "
          f"({engine} engine)...")
    result = fleet.run(steps=args.steps)

    shown = min(len(result.results), 12)
    for label, res in zip(result.labels[:shown], result.results[:shown]):
        print(f"{label:40s} {res.default_metrics['throughput']:7.1f} "
              f"-> {res.best_metrics['throughput']:7.1f} MB/s "
              f"({res.gain('throughput')*100:+.1f}%)  best={res.best_config}")
    if shown < len(result.results):
        print(f"... ({len(result.results) - shown} more sessions)")

    stats = result.summary("throughput")
    print(f"\naggregate throughput gain over {stats['sessions']} sessions: "
          f"mean {stats['mean']*100:+.1f}%  "
          f"p25/p50/p75 {stats['p25']*100:+.1f}/{stats['p50']*100:+.1f}/"
          f"{stats['p75']*100:+.1f}%  "
          f"range [{stats['min']*100:+.1f}%, {stats['max']*100:+.1f}%]")
    print(f"fleet wall time: {result.wall_seconds:.1f}s "
          f"for {stats['sessions']} x {args.steps}-step sessions")


if __name__ == "__main__":
    main()
