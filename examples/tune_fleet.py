"""Fleet tuning: the paper's whole evaluation grid as one fused JAX program.

Runs a seeds x workloads x objectives grid of independent Magpie tuning
sessions concurrently, then prints per-session results plus the aggregate
gain statistics the paper reports in Fig. 4/5 (91.8% average throughput gain
across workloads).

    PYTHONPATH=src python examples/tune_fleet.py
    PYTHONPATH=src python examples/tune_fleet.py --sessions 64 --chunk 16
    PYTHONPATH=src python examples/tune_fleet.py --service --checkpoint /tmp/f
    PYTHONPATH=src python examples/tune_fleet.py --resume /tmp/f
    PYTHONPATH=src python examples/tune_fleet.py --guardrails --min-gain 0.02
    PYTHONPATH=src python examples/tune_fleet.py --chaos --max-resets 3

``--sessions N`` spreads N sessions (seeds) over the workloads and runs them
through the streaming chunked scan engine: chunks of ``--chunk`` sessions
stream through ONE compiled episode program, so peak device memory is
O(chunk) no matter how large the fleet — the printed ``memory_plan()``
summary shows the capacity math before anything runs. ``--compile-cache``
persists the compiled episode across processes (back-to-back runs skip
XLA compilation entirely).

``--service`` runs the same grid through the persistent ``FleetService``
(leased chunk slots, advance() rounds, checkpoint every round when
``--checkpoint DIR`` is set); ``--resume DIR`` restores a checkpointed
service and finishes its remaining rounds bit-identically to a run that
was never interrupted.

``--guardrails`` wraps every session in a shadow/canary ``DeploymentPolicy``
(``core/guardrails.py``): proposals are shadow-scored without touching the
live config, promoted only above ``--min-gain`` within the
``--restart-budget`` downtime cap, and rolled back if the live objective
regresses inside ``--rollback-window`` steps. Guarded runs print the fleet's
promotion/rollback/budget counters; a resumed service keeps the policy it
was checkpointed with.

``--resilience`` turns on the self-healing scan body (``core/resilience.py``)
for every session: the engine detects non-finite params/losses/metrics after
each learn scan and branch-free resets the diverged session to its last-good
snapshot; past ``--max-resets`` the session degrades to frozen-incumbent
mode so the rest of the fleet keeps training. ``--chaos`` injects a
deterministic NaN poison into every session's metric stream (implies
``--resilience``) so you can watch the recovery happen — the run ends with
the fleet's health counters (non-finite detections, resets, degraded
sessions). A service checkpoint keeps the resilience policy: ``--resume``
continues self-healing with the policy it was checkpointed with.

``--share`` turns on cross-session experience sharing (``core/sharing.py``)
within each workload cell — the sessions tuning the same workload under
different seeds. ``--share replay`` merges each cell's replay into one
shared FIFO window (replay bytes per session drop by the cell size);
``--share replay+avg`` additionally averages the cell's learner parameters
every ``--avg-every`` env steps. Sharing forces the scan engine; the run
prints the ``memory_plan()`` replay delta and, per cell, how many steps the
cell took to reach 90% of its final objective. A service checkpoint keeps
the sharing config — ``--resume`` continues with the cells (and their
merged windows) it was checkpointed with.
"""

import argparse

from repro.core import DeploymentPolicy, FleetService, FleetTuner, SharingConfig


def _policy(args):
    """The DeploymentPolicy the --guardrails flags describe (None when off)."""
    if not args.guardrails:
        return None
    return DeploymentPolicy(min_gain=args.min_gain,
                            max_restart_seconds=args.restart_budget,
                            rollback_window=args.rollback_window)


def _resilience(args):
    """The ResiliencePolicy the --resilience/--chaos flags describe
    (None when off — the plain engine, same compiled program)."""
    if not (args.resilience or args.chaos):
        return None
    from repro.core import ResiliencePolicy
    return ResiliencePolicy(max_resets=args.max_resets)


def _chaos_env_factory(args):
    """--chaos: every session's env wraps its model in one shared NaN-poison
    schedule (one step_fn identity keeps the fleet on one compiled
    program) — the canonical divergence the resilient engine must absorb."""
    if not args.chaos:
        return None
    from repro.envs import ChaosConfig, FaultInjectedModel, ModelEnv
    from repro.envs.lustre_sim import LustreSimEnv
    specs = ChaosConfig(nan_metric="throughput", nan_start=6,
                        nan_duration=2).fault_specs()

    def env_factory(workload, seed):
        base = LustreSimEnv(workload, seed=seed).as_model()
        return ModelEnv(FaultInjectedModel(base, specs), seed=seed)

    return env_factory


def _print_health_summary(stats) -> None:
    """Fleet-wide health counters for a resilient run (in-graph totals)."""
    stats = [s for s in stats if s]
    if not stats:
        return
    print(f"health ({len(stats)} resilient sessions): "
          f"{sum(s['nonfinite_total'] for s in stats)} non-finite steps, "
          f"{sum(s['resets_total'] for s in stats)} resets, "
          f"{sum(1 for s in stats if s['degraded'])} degraded")


def _sharing(args):
    """The SharingConfig the --share flag describes (None when off)."""
    if args.share == "off":
        return None
    if args.share == "replay":
        return SharingConfig(shared_replay=True)
    return SharingConfig(shared_replay=True, avg_every=args.avg_every,
                         avg_opt_state=True)


def _steps_to_target(histories, fraction=0.9, window=4):
    """First step at which the cell's trailing-``window`` mean objective
    holds ``fraction`` of its end-of-run value (None = never)."""
    import numpy as np
    per = np.stack([[h.objective for h in hist] for hist in histories])
    mean = per.mean(axis=0)
    trail = np.convolve(mean, np.ones(window) / window, mode="valid")
    target = fraction * trail[-1]
    hit = np.nonzero(trail >= target)[0]
    return int(hit[0] + window) if hit.size else None


def _print_cell_targets(labels, results, cell_size) -> None:
    for c0 in range(0, len(results), cell_size):
        cell = results[c0:c0 + cell_size]
        label = labels[c0].rsplit("|", 1)[0]  # strip the |seedN suffix
        steps = _steps_to_target([r.history for r in cell])
        print(f"  cell {label:30s} steps to 90% of final objective: "
              f"{steps if steps is not None else 'never'}")


def _run_service(args) -> None:
    """The grid as a persistent FleetService: advance() rounds with an
    optional checkpoint each round; --resume continues bit-identically."""
    weights = {"throughput": 1.0}
    if args.resume:
        # restore() rebuilds the policy from the checkpoint, so a resumed
        # service keeps the guardrails it was running with; the env
        # DEFINITION is code, not data — a chaos-checkpointed service must
        # resume with --chaos so the rebuilt envs match (drift raises)
        svc = FleetService.restore(args.resume,
                                   env_factory=_chaos_env_factory(args))
        print(f"resumed service from {args.resume}: {len(svc.active)} "
              f"sessions at step {svc.total_steps}/{args.steps}")
        if svc.sharing is not None:
            # restore() rebuilt the cells (and their merged replay windows)
            # from the checkpoint — the sharing config is durable state
            print(f"  sharing (from checkpoint): {svc.sharing} "
                  f"cell_size={svc.cell_size}")
        if svc.resilience is not None:
            # the resilience policy is durable state too: a resumed service
            # keeps self-healing exactly as it was checkpointed
            print(f"  resilience (from checkpoint): {svc.resilience}")
    else:
        workloads = ["seq_write", "video_server", "file_server"]
        seeds = list(range(max(1, round(args.sessions / len(workloads)))))
        sharing = _sharing(args)
        cs = len(seeds) if sharing is not None else 1
        # the lease width must hold whole cells
        chunk = args.chunk or max(8 // cs, 1) * cs
        svc = FleetService(chunk=chunk, eval_runs=1,
                           checkpoint_dir=args.checkpoint,
                           env_factory=_chaos_env_factory(args),
                           policy=_policy(args), sharing=sharing,
                           cell_size=cs, resilience=_resilience(args))
        # same per-cell seed offsets as FleetTuner.from_grid, so a service
        # run is comparable session-for-session with the batch path
        cell = 0
        for w in workloads:
            for s in seeds:
                svc.request_join(w, weights, s + 1000 * cell)
                cell += 1
        print(f"service: {cell} sessions joining, chunk {svc.chunk}")
    while svc.total_steps < args.steps:
        steps = min(args.round_steps, args.steps - svc.total_steps)
        sids = svc.advance(steps)
        st = svc.last_stats
        print(f"round -> step {svc.total_steps}/{args.steps}: "
              f"{len(sids)} sessions, "
              f"{st['session_steps_per_sec']:.1f} session-steps/s")
        if "guardrails" in st:
            g = st["guardrails"]
            print(f"  guardrails: {g['promotions']:.0f} promoted, "
                  f"{g['rejected_min_gain']:.0f}/{g['rejected_budget']:.0f} "
                  f"rejected (gain/budget), {g['rollbacks']:.0f} rollbacks, "
                  f"{g['restart_seconds']:.1f}s restart downtime this round")
        if svc.checkpoint_dir:
            print(f"  checkpoint: {svc.checkpoint()}")
    labels = dict(svc.active)
    for sid in labels:
        svc.request_leave(sid)
    svc.advance(0)
    gains = []
    for sid, label in list(labels.items())[:12]:
        res = svc.result(sid)
        print(f"{label:40s} {res.default_metrics['throughput']:7.1f} "
              f"-> {res.best_metrics['throughput']:7.1f} MB/s "
              f"({res.gain('throughput')*100:+.1f}%)")
    for sid in labels:
        gains.append(svc.result(sid).gain("throughput"))
    print(f"\naggregate throughput gain over {len(gains)} sessions: "
          f"mean {sum(gains)/len(gains)*100:+.1f}%")
    if svc.sharing is not None and svc.cell_size > 1:
        sids = list(labels)
        _print_cell_targets([labels[sid] for sid in sids],
                            [svc.result(sid) for sid in sids],
                            svc.cell_size)
    results = [svc.result(sid) for sid in labels]
    _print_guardrail_summary([r.guardrail_stats for r in results])
    _print_health_summary([r.health_stats for r in results])


def _print_guardrail_summary(stats) -> None:
    """Fleet-wide promotion/rollback/budget totals for a guarded run."""
    stats = [s for s in stats if s]
    if not stats:
        return
    print(f"guardrails ({len(stats)} guarded sessions): "
          f"{sum(s['promotions'] for s in stats):.0f} promotions, "
          f"{sum(s['rejected_min_gain'] for s in stats):.0f}/"
          f"{sum(s['rejected_budget'] for s in stats):.0f} rejected "
          f"(gain/budget), {sum(s['rollbacks'] for s in stats):.0f} "
          f"rollbacks, {sum(s['restart_budget_spent'] for s in stats):.1f}s "
          f"restart downtime")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--sessions", type=int, default=9,
                        help="total tuning sessions (spread over 3 workloads)")
    parser.add_argument("--chunk", type=int, default=None,
                        help="sessions per streamed chunk (scan engine); "
                        "default: one chunk of the whole fleet")
    parser.add_argument("--steps", type=int, default=30,
                        help="tuning steps per session (paper budget: 30)")
    parser.add_argument("--compile-cache", nargs="?", const="", default=None,
                        metavar="DIR",
                        help="enable JAX's persistent compilation cache "
                        "(optional DIR; default ~/.cache/repro-jax-cache)")
    parser.add_argument("--service", action="store_true",
                        help="run through the persistent FleetService "
                        "(leased slots, advance() rounds)")
    parser.add_argument("--checkpoint", default=None, metavar="DIR",
                        help="service mode: checkpoint directory, written "
                        "every round")
    parser.add_argument("--resume", default=None, metavar="DIR",
                        help="restore a checkpointed service from DIR and "
                        "finish its rounds (implies --service)")
    parser.add_argument("--round-steps", type=int, default=5,
                        help="service mode: tuning steps per advance() round")
    parser.add_argument("--guardrails", action="store_true",
                        help="gate every apply behind a shadow/canary "
                        "DeploymentPolicy (forces the scan engine)")
    parser.add_argument("--min-gain", type=float, default=0.01,
                        help="guardrails: minimum shadow-projected relative "
                        "gain to promote a proposal")
    parser.add_argument("--restart-budget", type=float, default=float("inf"),
                        metavar="SECONDS",
                        help="guardrails: total restart downtime a session "
                        "may spend on promotions")
    parser.add_argument("--rollback-window", type=int, default=4,
                        metavar="STEPS",
                        help="guardrails: steps a fresh canary is watched "
                        "for a live regression before it becomes the "
                        "incumbent")
    parser.add_argument("--resilience", action="store_true",
                        help="self-heal diverged sessions: snapshot/reset on "
                        "non-finite detection, degrade-to-frozen past the "
                        "reset budget (forces the scan engine)")
    parser.add_argument("--max-resets", type=int, default=3,
                        help="resilience: snapshot resets a session may "
                        "spend before the next divergence degrades it")
    parser.add_argument("--chaos", action="store_true",
                        help="inject a deterministic NaN poison into every "
                        "session's metric stream to demonstrate recovery "
                        "(implies --resilience; a chaos-checkpointed "
                        "service must --resume with --chaos too)")
    parser.add_argument("--share", choices=["off", "replay", "replay+avg"],
                        default="off",
                        help="cross-session experience sharing per workload "
                        "cell: merged replay window, optionally + periodic "
                        "parameter averaging (forces the scan engine)")
    parser.add_argument("--avg-every", type=int, default=4, metavar="STEPS",
                        help="share=replay+avg: env steps between cell "
                        "parameter averages")
    args = parser.parse_args()

    if args.compile_cache is not None:
        from repro.core import enable_persistent_compilation_cache
        path = enable_persistent_compilation_cache(args.compile_cache or None)
        print(f"persistent compilation cache: {path}")

    if args.service or args.resume:
        _run_service(args)
        return

    workloads = ["seq_write", "video_server", "file_server"]
    # the grid is a full workloads x seeds cross product, so the session
    # count is rounded to the nearest multiple of len(workloads) — say so
    # instead of silently running a different fleet than requested
    seeds = list(range(max(1, round(args.sessions / len(workloads)))))
    n_sessions = len(workloads) * len(seeds)
    if n_sessions != args.sessions:
        print(f"note: running {n_sessions} sessions "
              f"({len(workloads)} workloads x {len(seeds)} seeds; "
              f"{args.sessions} requested)")
    sharing = _sharing(args)
    resilience = _resilience(args)
    engine = ("scan" if (args.guardrails or args.chunk is not None
                         or sharing is not None or resilience is not None
                         or n_sessions > 9)
              else "host")
    fleet = FleetTuner.from_grid(
        workloads=workloads,
        objectives=[{"throughput": 1.0}],
        seeds=seeds,
        env_factory=_chaos_env_factory(args),
        engine=engine,
        chunk=args.chunk if engine == "scan" else None,
        eval_runs=1 if n_sessions > 9 else 3,
        policy=_policy(args),
        sharing=sharing,
        resilience=resilience,
    )

    if engine == "scan":
        plan = fleet.memory_plan(steps=args.steps)
        per = plan["per_session"]
        print(f"memory plan ({plan['sessions']} sessions, chunk "
              f"{plan['chunk']}, {plan['steps']} steps):")
        replay_note = ""
        if plan["cell_size"] > 1:
            # the merged cell window amortizes one buffer over the cell
            replay_note = (f" = 1/{plan['cell_size']} of the independent "
                           f"{per['replay_bytes'] * plan['cell_size']:,} B")
        print(f"  per session: learner {per['learner_bytes']:,} B, replay "
              f"{per['replay_bytes']:,} B ({plan['replay_dtype']})"
              f"{replay_note}, trace {per['trace_bytes_per_step']} B/step")
        print(f"  device (one chunk resident): "
              f"{plan['chunk_device_bytes']:,} B")
        print(f"  host (whole fleet): {plan['fleet_host_bytes']:,} B "
              f"(validated vs live buffers: {plan['matches_live']})")

    print(f"running {fleet.agent.num_sessions} tuning sessions "
          f"({engine} engine)...")
    result = fleet.run(steps=args.steps)

    shown = min(len(result.results), 12)
    for label, res in zip(result.labels[:shown], result.results[:shown]):
        print(f"{label:40s} {res.default_metrics['throughput']:7.1f} "
              f"-> {res.best_metrics['throughput']:7.1f} MB/s "
              f"({res.gain('throughput')*100:+.1f}%)  best={res.best_config}")
    if shown < len(result.results):
        print(f"... ({len(result.results) - shown} more sessions)")

    stats = result.summary("throughput")
    print(f"\naggregate throughput gain over {stats['sessions']} sessions: "
          f"mean {stats['mean']*100:+.1f}%  "
          f"p25/p50/p75 {stats['p25']*100:+.1f}/{stats['p50']*100:+.1f}/"
          f"{stats['p75']*100:+.1f}%  "
          f"range [{stats['min']*100:+.1f}%, {stats['max']*100:+.1f}%]")
    print(f"fleet wall time: {result.wall_seconds:.1f}s "
          f"for {stats['sessions']} x {args.steps}-step sessions")
    if sharing is not None and fleet.cell_size > 1:
        print(f"sharing: {args.share} over cells of {fleet.cell_size}")
        _print_cell_targets(result.labels, result.results, fleet.cell_size)
    _print_guardrail_summary([r.guardrail_stats for r in result.results])
    _print_health_summary([r.health_stats for r in result.results])


if __name__ == "__main__":
    main()
