"""Tune the realistic 8-knob Lustre space — metric-state DDPG vs black-box
BestConfig at the dimensionality where the paper's thesis bites.

The paper evaluates on 2 parameters (stripe_count, stripe_size); related work
(DIAL, CARAT) shows production client stacks expose 6-10 interacting knobs.
``LustreSimV2`` layers the client knobs (max_rpcs_in_flight,
max_pages_per_rpc, max_dirty_mb, read_ahead_mb, checksums) and the OSS
service-thread count on the paper's stripe model. At 8-D, exhaustive grids
are intractable (~5.5M points) and black-box search degrades — while Magpie's
metric state still attributes what each knob did.

    PYTHONPATH=src python examples/tune_8knob.py
    PYTHONPATH=src python examples/tune_8knob.py --engine scan --steps 50

With ``--engine scan`` both tuners run against the pure-JAX env model:
Magpie's episode fuses into one XLA program (``core.episode``), and
BestConfig pushes each DDS probe batch through the vectorized pure env in a
single dispatch.
"""

import argparse

from repro.core import BestConfigTuner, DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimV2


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--engine", choices=("host", "scan"), default="host",
                        help="host = dict loop on the numpy simulator; "
                        "scan = fused episode + batched probes on the "
                        "pure-JAX env model")
    parser.add_argument("--steps", type=int, default=30,
                        help="tuning budget (paper: 30)")
    args = parser.parse_args()
    steps = args.steps

    def make_env(seed):
        env = LustreSimV2("seq_write", seed=seed)
        return env.to_model_env() if args.engine == "scan" else env

    # -- Magpie: DDPG sized from the 8-D ParamSpace -------------------------
    env = make_env(0)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=0)
    magpie = Tuner(env, scal, agent, engine=args.engine).run(steps)

    # -- BestConfig: same budget, same environment seed, objective only -----
    env_bc = make_env(0)
    scal_bc = Scalarizer(weights={"throughput": 1.0}, specs=env_bc.metric_specs)
    bestconfig = BestConfigTuner(env_bc, scal_bc, round_size=10, seed=0).run(steps)

    print(f"engine: {args.engine} ({steps} steps)")
    print(f"space: {env.param_space.dim}-D "
          f"({', '.join(env.param_space.names)})\n")
    print(f"default config: {magpie.default_config}")
    print(f" -> {magpie.default_metrics['throughput']:.1f} MB/s\n")
    for name, res in (("Magpie (DDPG)", magpie), ("BestConfig", bestconfig)):
        print(f"{name}:")
        print(f"  best config: {res.best_config}")
        print(f"  throughput:  {res.best_metrics['throughput']:.1f} MB/s "
              f"({res.gain('throughput')*100:+.1f}%)")
    print(f"\nrestart downtime breakdown (Magpie episode): "
          f"{env.restart_summary()}")


if __name__ == "__main__":
    main()
