"""Tune the realistic 8-knob Lustre space — metric-state DDPG vs black-box
BestConfig at the dimensionality where the paper's thesis bites.

The paper evaluates on 2 parameters (stripe_count, stripe_size); related work
(DIAL, CARAT) shows production client stacks expose 6-10 interacting knobs.
``LustreSimV2`` layers the client knobs (max_rpcs_in_flight,
max_pages_per_rpc, max_dirty_mb, read_ahead_mb, checksums) and the OSS
service-thread count on the paper's stripe model. At 8-D, exhaustive grids
are intractable (~5.5M points) and black-box search degrades — while Magpie's
metric state still attributes what each knob did.

    PYTHONPATH=src python examples/tune_8knob.py
"""

from repro.core import BestConfigTuner, DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimV2


def main() -> None:
    steps = 30  # the paper's tuning budget, now spent on an 8-D space

    # -- Magpie: DDPG sized from the 8-D ParamSpace -------------------------
    env = LustreSimV2("seq_write", seed=0)
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=0)
    magpie = Tuner(env, scal, agent).run(steps)

    # -- BestConfig: same budget, same environment seed, objective only -----
    env_bc = LustreSimV2("seq_write", seed=0)
    scal_bc = Scalarizer(weights={"throughput": 1.0}, specs=env_bc.metric_specs)
    bestconfig = BestConfigTuner(env_bc, scal_bc, round_size=10, seed=0).run(steps)

    print(f"space: {env.param_space.dim}-D "
          f"({', '.join(env.param_space.names)})\n")
    print(f"default config: {magpie.default_config}")
    print(f" -> {magpie.default_metrics['throughput']:.1f} MB/s\n")
    for name, res in (("Magpie (DDPG)", magpie), ("BestConfig", bestconfig)):
        print(f"{name}:")
        print(f"  best config: {res.best_config}")
        print(f"  throughput:  {res.best_metrics['throughput']:.1f} MB/s "
              f"({res.gain('throughput')*100:+.1f}%)")
    print(f"\nrestart downtime breakdown (Magpie episode): "
          f"{env.restart_summary()}")


if __name__ == "__main__":
    main()
