"""Quickstart: tune the simulated Lustre file system with Magpie (the paper's
headline experiment, single performance indicator) in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimEnv


def main() -> None:
    # Environment: 6-OST Lustre + Sequential Write workload (paper §III-B).
    env = LustreSimEnv("seq_write", seed=0)

    # Objective: throughput only (paper §III-C); weights define preference.
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)

    # The agent: DDPG sized from the (stripe_count, stripe_size) ParamSpace.
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=0)

    tuner = Tuner(env, scal, agent)
    result = tuner.run(steps=30)  # paper's budget

    print(f"default config:   {result.default_config} "
          f"-> {result.default_metrics['throughput']:.1f} MB/s")
    print(f"tuned config:     {result.best_config} "
          f"-> {result.best_metrics['throughput']:.1f} MB/s")
    print(f"throughput gain:  {result.gain('throughput')*100:.1f}% "
          f"(paper: +250.4% on this workload)")
    print(f"simulated restart downtime: "
          f"{result.simulated_restart_seconds:.0f} s over 30 tuning steps")


if __name__ == "__main__":
    main()
