"""Quickstart: tune the simulated Lustre file system with Magpie (the paper's
headline experiment, single performance indicator) in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --engine scan --steps 30
    PYTHONPATH=src python examples/quickstart.py --sessions 32 --chunk 8

``--engine host`` steps the Fig. 1 loop from Python against the numpy
simulator; ``--engine scan`` runs the identical episode as ONE fused XLA
program over the pure-JAX env model (``core.episode``) — same algorithm,
same budget, no host boundary per step. ``--sessions N`` (> 1) tunes N
same-workload sessions (different seeds) through the streaming chunked fleet
runtime — ``--chunk C`` sessions at a time through one compiled episode
program, with the ``memory_plan()`` capacity summary printed up front.
``--compile-cache`` persists compiled programs across invocations.
"""

import argparse

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimEnv


def _run_fleet(args) -> None:
    from repro.core import FleetTuner
    fleet = FleetTuner.from_grid(
        ["seq_write"], [{"throughput": 1.0}], list(range(args.sessions)),
        engine="scan", chunk=args.chunk, eval_runs=1)
    plan = fleet.memory_plan(steps=args.steps)
    per = plan["per_session"]
    print(f"memory plan ({plan['sessions']} sessions, chunk {plan['chunk']}, "
          f"{plan['steps']} steps): learner {per['learner_bytes']:,} B + "
          f"replay {per['replay_bytes']:,} B per session; one chunk keeps "
          f"{plan['chunk_device_bytes']:,} B on device "
          f"(validated vs live: {plan['matches_live']})")
    result = fleet.run(steps=args.steps)
    stats = result.summary("throughput")
    print(f"{stats['sessions']} sessions tuned in {result.wall_seconds:.1f}s: "
          f"mean throughput gain {stats['mean']*100:+.1f}% "
          f"(p50 {stats['p50']*100:+.1f}%)")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--engine", choices=("host", "scan"), default="host",
                        help="host = dict loop on the numpy simulator; "
                        "scan = fused whole-episode engine on the pure-JAX "
                        "env model")
    parser.add_argument("--steps", type=int, default=30,
                        help="tuning steps (paper budget: 30)")
    parser.add_argument("--sessions", type=int, default=1,
                        help="tune this many same-workload sessions as a "
                        "streamed fleet (> 1 implies the scan engine)")
    parser.add_argument("--chunk", type=int, default=None,
                        help="sessions per streamed chunk (fleet mode)")
    parser.add_argument("--compile-cache", nargs="?", const="", default=None,
                        metavar="DIR",
                        help="enable JAX's persistent compilation cache "
                        "(optional DIR; default ~/.cache/repro-jax-cache)")
    args = parser.parse_args()

    if args.compile_cache is not None:
        from repro.core import enable_persistent_compilation_cache
        path = enable_persistent_compilation_cache(args.compile_cache or None)
        print(f"persistent compilation cache: {path}")

    if args.sessions > 1:
        _run_fleet(args)
        return

    # Environment: 6-OST Lustre + Sequential Write workload (paper §III-B).
    # The scan engine needs the pure-model adapter; the host engine can run
    # either — numpy simulator kept here to match the paper scripts.
    env = LustreSimEnv("seq_write", seed=0)
    if args.engine == "scan":
        env = env.to_model_env()

    # Objective: throughput only (paper §III-C); weights define preference.
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)

    # The agent: DDPG sized from the (stripe_count, stripe_size) ParamSpace.
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=0)

    tuner = Tuner(env, scal, agent, engine=args.engine)
    result = tuner.run(steps=args.steps)

    print(f"engine:           {args.engine} ({args.steps} steps)")
    print(f"default config:   {result.default_config} "
          f"-> {result.default_metrics['throughput']:.1f} MB/s")
    print(f"tuned config:     {result.best_config} "
          f"-> {result.best_metrics['throughput']:.1f} MB/s")
    print(f"throughput gain:  {result.gain('throughput')*100:.1f}% "
          f"(paper: +250.4% on this workload)")
    print(f"simulated restart downtime: "
          f"{result.simulated_restart_seconds:.0f} s over {args.steps} tuning steps")


if __name__ == "__main__":
    main()
