"""Quickstart: tune the simulated Lustre file system with Magpie (the paper's
headline experiment, single performance indicator) in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --engine scan --steps 30

``--engine host`` steps the Fig. 1 loop from Python against the numpy
simulator; ``--engine scan`` runs the identical episode as ONE fused XLA
program over the pure-JAX env model (``core.episode``) — same algorithm,
same budget, no host boundary per step.
"""

import argparse

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimEnv


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--engine", choices=("host", "scan"), default="host",
                        help="host = dict loop on the numpy simulator; "
                        "scan = fused whole-episode engine on the pure-JAX "
                        "env model")
    parser.add_argument("--steps", type=int, default=30,
                        help="tuning steps (paper budget: 30)")
    args = parser.parse_args()

    # Environment: 6-OST Lustre + Sequential Write workload (paper §III-B).
    # The scan engine needs the pure-model adapter; the host engine can run
    # either — numpy simulator kept here to match the paper scripts.
    env = LustreSimEnv("seq_write", seed=0)
    if args.engine == "scan":
        env = env.to_model_env()

    # Objective: throughput only (paper §III-C); weights define preference.
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)

    # The agent: DDPG sized from the (stripe_count, stripe_size) ParamSpace.
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=0)

    tuner = Tuner(env, scal, agent, engine=args.engine)
    result = tuner.run(steps=args.steps)

    print(f"engine:           {args.engine} ({args.steps} steps)")
    print(f"default config:   {result.default_config} "
          f"-> {result.default_metrics['throughput']:.1f} MB/s")
    print(f"tuned config:     {result.best_config} "
          f"-> {result.best_metrics['throughput']:.1f} MB/s")
    print(f"throughput gain:  {result.gain('throughput')*100:.1f}% "
          f"(paper: +250.4% on this workload)")
    print(f"simulated restart downtime: "
          f"{result.simulated_restart_seconds:.0f} s over {args.steps} tuning steps")


if __name__ == "__main__":
    main()
