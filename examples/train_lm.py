"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps on
CPU with the full production stack (sharded-ready train step, microbatching,
checkpointing, deterministic data, resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.data import TokenPipeline
from repro.models import init_params, model_defs
from repro.models.base import param_count
from repro.training import TrainConfig, Trainer, TrainerConfig, make_train_step


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--tiny", action="store_true",
                   help="~10M params / short seq — finishes in ~2 min on CPU")
    args = p.parse_args()

    # ~100M-param llama-style config (yi-9b family, scaled down); --tiny
    # shrinks it for CPU smoke runs (the full 100M x 300 steps is a real
    # multi-hour CPU workload — run it on accelerators).
    if args.tiny:
        cfg = dataclasses.replace(
            configs.get_smoke_config("yi-9b"),
            num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
            d_ff=768, vocab_size=4096)
        args.seq = min(args.seq, 128)
    else:
        cfg = dataclasses.replace(
            configs.get_smoke_config("yi-9b"),
            num_layers=8, d_model=512, num_heads=8, num_kv_heads=2,
            d_ff=1536, vocab_size=8192)
    defs = model_defs(cfg)
    print(f"model: {param_count(defs)/1e6:.1f}M params")

    params = init_params(defs, jax.random.PRNGKey(0))
    tx = optim.adamw(optim.warmup_cosine_schedule(3e-4, 20, args.steps),
                     weight_decay=0.1)
    opt = tx.init(params)
    step = jax.jit(make_train_step(cfg, tx, TrainConfig(microbatches=2)),
                   donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, global_batch=args.batch,
                         seq_len=args.seq, seed=0)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    trainer = Trainer(
        step, pipe, params, opt,
        TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                      checkpoint_dir=ckpt_dir, log_every=25),
        to_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({out['step']} steps; checkpoints in {ckpt_dir})")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
