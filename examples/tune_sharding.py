"""Beyond-paper: Magpie tunes THIS framework's static compile parameters
(microbatches, remat policy, scan unroll) for a training cell — same DDPG
agent, different environment; the restart cost is the real recompile time.

    PYTHONPATH=src python examples/tune_sharding.py
"""

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.envs.sharding_env import ShardingEnv
from repro.launch.mesh import make_test_mesh


def main() -> None:
    mesh = make_test_mesh((1, 1), ("data", "model"))
    env = ShardingEnv("yi-9b", "train_4k", mesh=mesh, smoke=True,
                      microbatch_choices=(1, 2, 4, 8))
    scal = Scalarizer(weights={"steps_per_s": 1.0}, specs=env.metric_specs)
    agent = MagpieAgent(
        DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim),
        seed=0, warmup_steps=5)
    tuner = Tuner(env, scal, agent, eval_runs=1)
    res = tuner.run(10)
    print(f"default: {res.default_config} -> "
          f"{res.default_metrics['steps_per_s']:.3f} steps/s bound")
    print(f"tuned:   {res.best_config} -> "
          f"{res.best_metrics['steps_per_s']:.3f} steps/s bound")
    print(f"recompile ('restart') time accounted: "
          f"{res.simulated_restart_seconds:.1f} s")


if __name__ == "__main__":
    main()
