"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416 — qwen1.5-arch (QKV bias) [hf:Qwen/CodeQwen1.5-7B]."""

import jax.numpy as jnp

from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
        d_ff=13440, vocab_size=92416,
        attention="gqa", qkv_bias=True, rope_theta=1e6,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="codeqwen1.5-7b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        attention="gqa", qkv_bias=True,
    )
