"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only: the vision frontend is a stub; input_specs provides
pre-computed patch embeddings. M-RoPE: (t, h, w) sections (16, 24, 24) over
the 64 rotary frequency bands (head_dim 128)."""

import jax.numpy as jnp

from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b", family="vlm",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=29568, vocab_size=152064,
        attention="gqa", qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        notes="vision frontend stubbed (precomputed patch embeddings)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-72b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        attention="gqa", qkv_bias=True, rope_theta=1e6,
        mrope_sections=(2, 3, 3),  # half-dim = 8
    )
