"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf].

Approximation (DESIGN.md §4): the real model's dense first layer is folded
into the shared experts (all 28 layers are MoE+shared here)."""

import jax.numpy as jnp

from repro.models.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=102400,
        attention="gqa", rope_theta=1e4,
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared_experts=2, capacity_factor=1.25),
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        notes="dense first layer folded into shared experts (approx)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=512,
        attention="gqa",
        # capacity_factor >= E/k so no token is ever dropped at smoke sizes:
        # capacity-based drops depend on the *batch* of tokens routed together,
        # which makes incremental decode legitimately diverge from the parallel
        # forward — the prefill/decode consistency property only holds drop-free.
        moe=MoEConfig(num_experts=8, top_k=3, d_ff_expert=96,
                      num_shared_experts=2, capacity_factor=3.0),
    )
