"""minicpm3-4b [dense]: 62L d_model=2560 40H (GQA kv=40) d_ff=6400
vocab=73448 — MLA [hf:openbmb/MiniCPM3-4B].

Multi-head Latent Attention: q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32,
v_head 64. The serving cache stores only the (c, k_pe) latents — the MLA
memory win; decode uses the absorbed form."""

import jax.numpy as jnp

from repro.models.base import ArchConfig, MLAConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b", family="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448, head_dim=64,
        attention="mla",
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
        rope_theta=1e4, tie_embeddings=True,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="minicpm3-4b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512, head_dim=16,
        attention="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
        tie_embeddings=True,
    )
