"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA [arXiv:2412.08905]. Tied embeddings."""

import jax.numpy as jnp

from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b", family="dense",
        num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=200064,
        attention="gqa", rope_theta=1e4, tie_embeddings=True,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512,
        attention="gqa", tie_embeddings=True,
    )
