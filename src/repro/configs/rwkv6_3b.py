"""rwkv6-3b [ssm]: 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892]. head_size 64 -> 40 heads.
O(1) recurrent state for decode; chunked-parallel WKV for train/prefill."""

import jax.numpy as jnp

from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b", family="ssm",
        num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=8960, vocab_size=65536,
        attention="none", rwkv_head_size=64,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        attention="none", rwkv_head_size=16,
    )
