"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

Dense-MoE hybrid: every layer sums a dense d_ff=4864 MLP branch with a
128-expert top-2 MoE (expert d_ff 4864). fp32 params + Adafactor (AdamW
states do not fit 256 x 16 GB — DESIGN.md §6)."""

import jax.numpy as jnp

from repro.models.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        attention="gqa", rope_theta=1e6,
        moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                      dense_residual=True, capacity_factor=1.25),
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16,
        notes="Adafactor optimizer (AdamW state does not fit; DESIGN §6)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=512,
        attention="gqa",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96,
                      dense_residual=True, capacity_factor=1.5),
    )
