"""whisper-large-v3 [audio]: 32L d_model=1280 20H (GQA kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend (stub) [arXiv:2212.04356].

32 encoder + 32 decoder layers; the mel/conv frontend is a stub —
input_specs provides (B, 1500, 1280) frame embeddings. GELU MLPs,
layernorm, no RoPE (sinusoidal positions; decoder positions sinusoidal as an
approximation of Whisper's learned ones — see DESIGN.md)."""

import jax.numpy as jnp

from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3", family="encdec",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        attention="gqa", act="gelu", norm="layernorm",
        encoder_layers=32, encoder_seq=1500,
        tie_embeddings=True,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        notes="conv frontend stubbed; sinusoidal decoder positions (approx)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3-smoke", family="encdec",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        attention="gqa", act="gelu", norm="layernorm",
        encoder_layers=2, encoder_seq=30,
        tie_embeddings=True,
    )
