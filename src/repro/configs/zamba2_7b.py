"""zamba2-7b [hybrid]: 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242].

Approximation (DESIGN.md §4): 81 Mamba2 blocks with ONE weight-shared GQA
attention block applied after every 9th block (9 applications). The real
model interleaves two shared blocks with LoRA-modulated reuse; the shared-
weights-many-applications structure is preserved. d_ff is unused (no MLP in
the mamba blocks; the shared block is attention-only here)."""

import jax.numpy as jnp

from repro.models.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        attention="gqa", rope_theta=1e4,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
        hybrid_attn_every=9,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        notes="shared attention applied once per 9 mamba blocks (approx)",
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-7b-smoke", family="hybrid",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=512,
        attention="gqa",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
        hybrid_attn_every=2,
    )
