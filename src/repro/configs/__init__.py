"""Architecture registry + assigned input-shape sets + input_specs().

Every assigned (architecture x shape) cell resolves here: ``get_config`` /
``get_smoke_config`` return ArchConfigs; ``input_specs`` builds the
weak-type-correct ShapeDtypeStruct stand-ins the dry-run lowers against;
``cell_supported`` encodes the assignment's skip rules (long_500k only for
sub-quadratic archs)."""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig

_MODULES = {
    "qwen2-vl-72b": "qwen2_vl_72b",
    "zamba2-7b": "zamba2_7b",
    "whisper-large-v3": "whisper_large_v3",
    "arctic-480b": "arctic_480b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "minicpm3-4b": "minicpm3_4b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "yi-9b": "yi_9b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_NAMES = list(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _mod(name).config()


def get_smoke_config(name: str) -> ArchConfig:
    return _mod(name).smoke_config()


# ---------------------------------------------------------------------------
# Assigned shapes (LM-family: seq_len x global_batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SHAPE_NAMES = list(SHAPES)


def cell_supported(cfg: ArchConfig, shape_name: str) -> tuple:
    """(supported, reason). Encodes the assignment's own skip rules."""
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("SKIP(full-attention): 500k-token dense-attention KV "
                       "decode is infeasible by design; run only for "
                       "SSM/hybrid archs per the assignment")
    return True, ""


def input_specs(cfg: ArchConfig, shape_name: str, *,
                batch_override: int = 0) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:    {tokens, labels[, positions][, input_embeds/encoder frames]}
    prefill:  {tokens[, input_embeds]}           (cache is built inside)
    decode:   {tokens[B,1], cache_index}          (cache specs live in
               models.transformer.abstract_cache; the serve_step assembles)
    """
    shape = SHAPES[shape_name]
    B = batch_override or shape.batch
    S = shape.seq
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        if cfg.mrope_sections:
            specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), i32)
        if cfg.family == "vlm":
            specs["input_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), cfg.compute_dtype)
        if cfg.is_encdec:
            specs["input_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.mrope_sections:
            specs["positions"] = jax.ShapeDtypeStruct((B, 3, S), i32)
        if cfg.is_encdec:
            specs["input_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)
        return specs
    # decode: one new token against a seq-long cache
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "cache_index": jax.ShapeDtypeStruct((), i32),
    }
    return specs


# ---------------------------------------------------------------------------
# Paper config (the Lustre tuning experiment)
# ---------------------------------------------------------------------------

def paper_lustre():
    """Everything the paper's experiments need, bundled."""
    from repro.envs.lustre_sim import paper_param_space
    return {
        "param_space": paper_param_space(),
        "workloads": ["file_server", "video_server", "seq_write",
                      "seq_read", "random_rw"],
        "single_objective": {"throughput": 1.0},
        "multi_objective": {"throughput": 1.0, "iops": 1.0},
        "tuning_steps": 30,
        "extended_steps": 100,
        "eval_runs": 3,
    }
