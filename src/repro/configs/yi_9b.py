"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 —
llama-arch GQA [arXiv:2403.04652]."""

import jax.numpy as jnp

from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="yi-9b", family="dense",
        num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
        d_ff=11008, vocab_size=64000,
        attention="gqa", rope_theta=1e4,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="yi-9b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=128, vocab_size=512,
        attention="gqa",
    )
