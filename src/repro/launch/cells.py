"""Cell builders shared by the dry-run, the roofline pass and the Magpie
sharding environment: given (arch x shape x mesh [+ static train params]),
produce the jitted step with in/out shardings and abstract inputs, ready to
.lower().compile().

No jax device state is touched at import time (dryrun.py sets the 512-device
XLA flag before importing this module).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import jax.tree_util as jtu
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs, optim
from repro.models import abstract_cache, abstract_params, model_defs
from repro.models.base import ArchConfig, ParamDef
from repro.models.transformer import cache_spec
from repro.sharding.activation import activation_sharding
from repro.sharding.rules import (
    SERVE_RULES, TRAIN_RULES, ShardingRules, batch_pspec, defs_to_pspecs,
    spec_for,
)
from repro.training.steps import (
    TrainConfig, make_decode_step, make_prefill_step, make_train_step,
)

#: per-arch gradient-accumulation defaults for train_4k (keeps activations
#: inside 16 GB at local_batch = 256/16; hillclimbed values live in
#: EXPERIMENTS.md §Perf)
TRAIN_MICROBATCHES = {
    "qwen2-vl-72b": 16,
    "arctic-480b": 16,
    "zamba2-7b": 8,
    "whisper-large-v3": 4,
    "deepseek-moe-16b": 8,
    "minicpm3-4b": 8,
    "phi4-mini-3.8b": 8,
    "yi-9b": 8,
    "codeqwen1.5-7b": 8,
    "rwkv6-3b": 8,
}


#: hillclimbed static-parameter settings (EXPERIMENTS.md §Perf); cells not
#: listed use TrainConfig(microbatches=TRAIN_MICROBATCHES[arch], remat=full)
TRAIN_OVERRIDES = {
    "deepseek-moe-16b": TrainConfig(microbatches=16, remat="full"),
    "yi-9b": TrainConfig(microbatches=16, remat="dots",
                         gather_weights_once=True),
    "whisper-large-v3": TrainConfig(microbatches=8, remat="full"),
    "zamba2-7b": TrainConfig(microbatches=16, remat="full"),
    # NB: minicpm3 at mb=16 leaves per-microbatch batch 16 < 32 (pod x data)
    # on the multi-pod mesh — not batch-shardable; stays at mb=8.
}


def make_optimizer(cfg: ArchConfig) -> optim.GradientTransformation:
    """AdamW for <=72B-class; Adafactor for the 480B-class MoE (DESIGN §6)."""
    if cfg.name.startswith("arctic"):
        return optim.adafactor(1e-4)
    return optim.adamw(3e-4, weight_decay=0.1)


def _shard(mesh: Mesh, spec_tree):
    return jtu.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_pspecs(abstract_opt, defs, pspecs, rules: ShardingRules,
                     mesh: Mesh):
    """Shardings for optimizer state by shape correlation with params:
    exact-shape match inherits the param spec; Adafactor's factored slots
    (shape[:-1] / shape[:-2]+[last]) inherit the reduced spec; anything else
    (counters) replicates."""
    shape_to_spec: dict = {}
    for d, s in zip(
            jtu.tree_leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef)),
            jtu.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))):
        shape_to_spec.setdefault(tuple(d.shape), s)
        if len(d.shape) >= 2:
            shape_to_spec.setdefault(tuple(d.shape[:-1]), P(*s[:len(d.shape) - 1]))
            shape_to_spec.setdefault(
                tuple(d.shape[:-2]) + (d.shape[-1],),
                P(*(list(s[:len(d.shape) - 2]) + [s[len(d.shape) - 1]])))

    def spec(leaf):
        return shape_to_spec.get(tuple(leaf.shape), P())

    return jtu.tree_map(spec, abstract_opt)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ArchConfig
    fn: object                 # the step callable
    args: tuple                # abstract args
    in_shardings: tuple
    out_shardings: object
    donate_argnums: tuple
    kind: str
    act_batch: int = 0         # per-step activation batch (post-microbatch)
    rules: ShardingRules = TRAIN_RULES

    def jit(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self, mesh: Mesh):
        with mesh, activation_sharding(mesh, self.act_batch, self.rules):
            return self.jit().lower(*self.args)


def logits_pspec(cfg: ArchConfig, mesh: Mesh, batch: int,
                 rules: ShardingRules) -> P:
    b = batch_pspec(mesh, batch, extra_dims=0, rules=rules)
    v = spec_for((1, 1, cfg.vocab_size), ("batch", "seq", "vocab"), rules,
                 mesh)
    return P(b[0], None, v[2])


def build_cell(arch: str, shape_name: str, mesh: Mesh, *,
               tc: Optional[TrainConfig] = None, smoke: bool = False,
               batch_override: int = 0, seq_override: int = 0) -> Cell:
    cfg = (configs.get_smoke_config(arch) if smoke
           else configs.get_config(arch))
    shape = configs.SHAPES[shape_name]
    B = batch_override or shape.batch
    S = seq_override or shape.seq
    defs = model_defs(cfg)
    aparams = abstract_params(defs)

    if shape.kind == "train":
        rules = TRAIN_RULES
        pspecs = defs_to_pspecs(defs, rules, mesh)
        tx = make_optimizer(cfg)
        if tc is None:
            tc = TRAIN_OVERRIDES.get(arch) or TrainConfig(
                microbatches=TRAIN_MICROBATCHES.get(arch, 8),
                remat="full", attn_impl="auto")
        aopt = jax.eval_shape(tx.init, aparams)
        opt_specs = opt_state_pspecs(aopt, defs, pspecs, rules, mesh)
        bspec = batch_pspec(mesh, B, extra_dims=1, rules=rules)
        batch_specs = {"tokens": bspec, "labels": bspec}
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.mrope_sections:
            batch["positions"] = jax.ShapeDtypeStruct((B, 3, S), jnp.int32)
            batch_specs["positions"] = batch_pspec(mesh, B, extra_dims=2,
                                                   rules=rules)
        if cfg.family == "vlm":
            batch["input_embeds"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), cfg.compute_dtype)
            batch_specs["input_embeds"] = batch_pspec(mesh, B, extra_dims=2,
                                                      rules=rules)
        if cfg.is_encdec:
            batch["input_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)
            batch_specs["input_embeds"] = batch_pspec(mesh, B, extra_dims=2,
                                                      rules=rules)
        fn = make_train_step(cfg, tx, tc)
        if tc.gather_weights_once and tc.microbatches > 1:
            # Hypothesis->change (EXPERIMENTS §Perf): FSDP re-gathers every
            # parameter once per microbatch; constraining params to their
            # non-FSDP (TP-only) sharding once at step entry makes GSPMD
            # all-gather once per STEP, and the constraint's transpose
            # reduce-scatters the grads back — classic "FSDP prefetch once"
            # at the cost of one gathered copy of the weights in HBM.
            nofsdp = ShardingRules(rules={**dict(rules.rules), "embed": (),
                                          "experts": ()})
            gathered = _shard(mesh, defs_to_pspecs(defs, nofsdp, mesh))
            inner = fn

            def fn(params, opt_state, batch, _inner=inner,
                   _spec=gathered):
                params = jax.lax.with_sharding_constraint(params, _spec)
                return _inner(params, opt_state, batch)
        return Cell(
            arch=arch, shape=shape_name, cfg=cfg, fn=fn,
            args=(aparams, aopt, batch),
            in_shardings=(_shard(mesh, pspecs), _shard(mesh, opt_specs),
                          _shard(mesh, batch_specs)),
            out_shardings=(_shard(mesh, pspecs), _shard(mesh, opt_specs),
                           None),
            donate_argnums=(0, 1), kind="train",
            act_batch=B // max(1, tc.microbatches), rules=rules,
        )

    rules = SERVE_RULES
    pspecs = defs_to_pspecs(defs, rules, mesh)
    cspec_defs = cache_spec(cfg, B, S)
    cache_specs = defs_to_pspecs(cspec_defs, rules, mesh)

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, B, S, attn_impl="auto")
        args = [aparams, jax.ShapeDtypeStruct((B, S), jnp.int32)]
        in_sh = [_shard(mesh, pspecs),
                 NamedSharding(mesh, batch_pspec(mesh, B, 1, rules))]
        kw_positions = None
        if cfg.mrope_sections:
            args.append(jax.ShapeDtypeStruct((B, 3, S), jnp.int32))
            in_sh.append(NamedSharding(mesh, batch_pspec(mesh, B, 2, rules)))
        else:
            args.append(None)
            in_sh.append(None)
        if cfg.is_encdec:
            args.append(jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                             cfg.compute_dtype))
            in_sh.append(NamedSharding(mesh, batch_pspec(mesh, B, 2, rules)))
        else:
            args.append(None)
            in_sh.append(None)
        out_sh = (NamedSharding(mesh, logits_pspec(cfg, mesh, B, rules)),
                  _shard(mesh, cache_specs))
        return Cell(arch=arch, shape=shape_name, cfg=cfg, fn=fn,
                    args=tuple(args), in_shardings=tuple(in_sh),
                    out_shardings=out_sh, donate_argnums=(), kind="prefill",
                    act_batch=B, rules=rules)

    # decode
    fn = make_decode_step(cfg)
    acache = abstract_cache(cfg, B, S)
    args = (aparams, jax.ShapeDtypeStruct((B, 1), jnp.int32), acache,
            jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (_shard(mesh, pspecs),
             NamedSharding(mesh, batch_pspec(mesh, B, 1, rules)),
             _shard(mesh, cache_specs),
             NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, logits_pspec(cfg, mesh, B, rules)),
              _shard(mesh, cache_specs))
    return Cell(arch=arch, shape=shape_name, cfg=cfg, fn=fn, args=args,
                in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=(2,), kind="decode",
                act_batch=B, rules=rules)
