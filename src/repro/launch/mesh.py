"""Production meshes.

Pure functions (no module-level jax device access — importing this module
never initializes the backend, so tests keep their 1-CPU view).

Production topology (TPU v5e): one pod = 256 chips as a (16, 16) mesh with
axes ("data", "model"); multi-pod = 2 pods = 512 chips as (2, 16, 16) with
axes ("pod", "data", "model"). The "pod" axis extends data parallelism by
default (per-step gradient all-reduce crosses the inter-pod links once);
launch/train.py can alternatively map pipeline stages onto it.
"""

from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices but only {len(devices)} are "
            f"visible — run under XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={need} (launch/dryrun.py does this automatically)")
    return jax.make_mesh(shape, axes, devices=np.asarray(devices[:need]))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (subprocesses set device count)."""
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    return jax.make_mesh(shape, axes, devices=np.asarray(devices[:need]))
