import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module: jax locks the
# host platform device count at first backend initialization, and the
# production meshes below need 512 placeholder devices (2 pods x 16 x 16).

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape) cell
on the production meshes, print memory_analysis / cost_analysis, and record
the roofline inputs (FLOPs, bytes, per-collective payload bytes).

    PYTHONPATH=src python -m repro.launch.dryrun                # everything
    ... --arch yi-9b --shape train_4k --mesh both               # one cell
    ... --out benchmarks/results/dryrun.json                    # output path

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — the dry-run is the proof that the distribution
config is coherent."""

import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import (
    collective_bytes_from_hlo, model_flops, roofline_terms,
)
from repro.roofline.hw import TPU_V5E
from repro.roofline.structural import structural_costs


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tc=None) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    cfg = configs.get_config(arch)
    ok, reason = configs.cell_supported(cfg, shape_name)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch, shape_name, mesh, tc=tc)
        lowered = cell.lower(mesh)
        rec["lower_s"] = round(time.time() - t0, 1)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_estimate_bytes": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis()
        # XLA HloCostAnalysis counts while bodies ONCE (verified) — recorded
        # for reference only; the roofline uses scan-aware structural costs.
        rec["xla_cost_flops_raw"] = float(ca.get("flops", 0.0))
        rec["xla_cost_bytes_raw"] = float(ca.get("bytes accessed", 0.0))

        chips = 512 if multi_pod else 256
        sc = structural_costs(cell.fn, *cell.args)
        rec["flops_global"] = sc["flops"]
        rec["bytes_global"] = sc["bytes"]
        rec["flops_per_device"] = sc["flops"] / chips
        rec["bytes_per_device"] = sc["bytes"] / chips

        coll = collective_bytes_from_hlo(compiled.as_text())
        rec["collectives"] = coll

        shape = configs.SHAPES[shape_name]
        mf = model_flops(cfg, shape.kind, shape.batch, shape.seq)
        rec["model_flops_global"] = mf
        rec["model_flops_per_device"] = mf / chips
        rec["useful_flops_ratio"] = (
            mf / chips / rec["flops_per_device"]
            if rec["flops_per_device"] else 0.0)
        rec["roofline"] = roofline_terms(
            rec["flops_per_device"], rec["bytes_per_device"],
            coll["weighted_bytes"])
        rec["fits_hbm"] = rec["memory"]["peak_estimate_bytes"] < TPU_V5E.hbm_bytes
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — every failure is a finding
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def fmt_line(rec: dict) -> str:
    if rec["status"] == "skip":
        return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} "
                f"SKIP  ({rec['reason'][:60]}...)")
    if rec["status"] == "fail":
        return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} "
                f"FAIL  {rec['error'][:90]}")
    r = rec["roofline"]
    mem_gb = rec["memory"]["peak_estimate_bytes"] / 1e9
    return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:8s} OK  "
            f"compile={rec['compile_s']:>6.1f}s "
            f"mem/dev={mem_gb:6.2f}GB "
            f"C={r['compute_s']:.3f}s M={r['memory_s']:.3f}s "
            f"X={r['collective_s']:.3f}s dom={r['dominant'][:-2]:10s} "
            f"useful={rec['useful_flops_ratio']:.2f}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all",
                   help="arch id or 'all' (see repro.configs.ARCH_NAMES)")
    p.add_argument("--shape", default="all",
                   help="shape name or 'all' (train_4k/prefill_32k/...)")
    p.add_argument("--mesh", default="both",
                   choices=["pod", "multipod", "both"])
    p.add_argument("--out", default="benchmarks/results/dryrun.json")
    p.add_argument("--microbatches", type=int, default=0,
                   help="override TrainConfig.microbatches (hillclimb)")
    p.add_argument("--remat", default="",
                   help="override TrainConfig.remat (none|dots|full)")
    args = p.parse_args()

    archs = configs.ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = configs.SHAPE_NAMES if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]

    tc = None
    if args.microbatches or args.remat:
        from repro.training.steps import TrainConfig
        tc = TrainConfig(microbatches=args.microbatches or 8,
                         remat=args.remat or "full")

    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skip")}

    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                key = (arch, shape, "2x16x16" if multi_pod else "16x16")
                if key in done and args.arch == "all":
                    continue
                rec = run_cell(arch, shape, multi_pod, tc=tc)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                print(fmt_line(rec), flush=True)
                if args.out:
                    os.makedirs(os.path.dirname(args.out), exist_ok=True)
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skip (documented), {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
