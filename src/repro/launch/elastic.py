"""Elastic re-scale: reshard the latest checkpoint onto a different mesh.

The pieces that make elasticity work at 1000+ nodes:
  * checkpoints are stored unsharded (each host writes its addressable
    shards; the manifest stitches them) — restore_into() places leaves onto
    the *new* mesh's shardings (checkpoint/store.py);
  * the data pipeline is stateless in (seed, step, shard) — re-sharding the
    pipeline is TokenPipeline.shard(i, n'), no epoch bookkeeping moves;
  * the optimizer state reshards exactly like params (same rule table).

``reshard(checkpoint_dir, old_template, new_mesh)`` is the whole mechanism;
the CLI below demonstrates a 4-device -> 2-device rescale at CPU scale (the
same call handles 512 -> 256 after losing a pod).
"""

from __future__ import annotations

import argparse

import jax

from repro import checkpoint as ckpt
from repro.sharding.rules import TRAIN_RULES, defs_to_shardings


def reshard_checkpoint(directory: str, template, new_mesh, defs,
                       rules=TRAIN_RULES, step=None):
    """Load latest checkpoint and place params/opt onto ``new_mesh``.

    ``template``: {"params": ..., "opt_state": ...} pytree of arrays or
    ShapeDtypeStructs matching the checkpoint structure.
    Returns (step, restored tree with leaves sharded on new_mesh).
    """
    found_step, flat, _ = ckpt.restore_checkpoint(directory, step)
    param_shardings = defs_to_shardings(defs, rules, new_mesh)
    # opt-state shardings by shape correlation (same helper as the dry-run)
    from repro.launch.cells import opt_state_pspecs
    from repro.sharding.rules import defs_to_pspecs
    from jax.sharding import NamedSharding
    pspecs = defs_to_pspecs(defs, rules, new_mesh)
    opt_specs = opt_state_pspecs(template["opt_state"], defs, pspecs, rules,
                                 new_mesh)
    opt_shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(new_mesh, s), opt_specs,
        is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval")
        or type(x).__name__ == "PartitionSpec")
    shardings = {"params": param_shardings, "opt_state": opt_shardings}
    restored = ckpt.restore_into(template, flat, shardings=shardings)
    return found_step, restored


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--checkpoint-dir", required=True)
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--mesh", default="2x2", help="new mesh, e.g. 2x2 or 4x1")
    args = p.parse_args()

    from repro import configs
    from repro.launch.cells import make_optimizer
    from repro.launch.mesh import make_test_mesh
    from repro.models import abstract_params, model_defs

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    defs = model_defs(cfg)
    aparams = abstract_params(defs)
    tx = make_optimizer(cfg)
    aopt = jax.eval_shape(tx.init, aparams)
    shape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_test_mesh(shape)
    step, restored = reshard_checkpoint(
        args.checkpoint_dir, {"params": aparams, "opt_state": aopt},
        mesh, defs)
    leaf = jax.tree_util.tree_leaves(restored["params"])[0]
    print(f"resharded checkpoint step {step} onto mesh {mesh.shape}; "
          f"first leaf sharding: {leaf.sharding}")


if __name__ == "__main__":
    main()
