"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \\
        --steps 50 --checkpoint-dir /tmp/ckpt

On this CPU container use --smoke (reduced config, 1 device). On a real
cluster the same driver runs the full config on the production mesh: every
piece (sharded params, microbatched remat'd train_step, checkpoint/resume,
preemption, watchdog) is identical — only the mesh and config size change.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.data import TokenPipeline
from repro.launch.mesh import make_production_mesh
from repro.models import init_params, model_defs
from repro.sharding.activation import activation_sharding
from repro.sharding.rules import TRAIN_RULES, defs_to_shardings
from repro.training import TrainConfig, Trainer, TrainerConfig, make_train_step
from repro.launch.cells import make_optimizer


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="yi-9b")
    p.add_argument("--smoke", action="store_true",
                   help="reduced config on local devices")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--remat", default="none")
    p.add_argument("--checkpoint-dir", default="")
    p.add_argument("--checkpoint-every", type=int, default=50)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--compress-grads", type=float, default=0.0,
                   help="top-k gradient compression fraction (0 = off)")
    args = p.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(args.seed))

    tx = make_optimizer(cfg)
    if args.compress_grads:
        from repro.training.compression import topk_error_feedback
        tx = optim.chain(topk_error_feedback(args.compress_grads), tx)
    opt_state = tx.init(params)

    tc = TrainConfig(microbatches=args.microbatches, remat=args.remat)
    step_fn = make_train_step(cfg, tx, tc)

    n_dev = len(jax.devices())
    if n_dev > 1:
        mesh = make_production_mesh() if n_dev >= 256 else None
        if mesh is None:
            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh((n_dev // 2, 2))
        shardings = defs_to_shardings(defs, TRAIN_RULES, mesh)
        params = jax.device_put(params, shardings)
        step_fn_jit = jax.jit(step_fn, donate_argnums=(0, 1))

        def step(params, opt_state, batch):
            with mesh, activation_sharding(
                    mesh, args.global_batch // max(1, args.microbatches),
                    TRAIN_RULES):
                return step_fn_jit(params, opt_state, batch)
    else:
        step = jax.jit(step_fn, donate_argnums=(0, 1))

    pipeline = TokenPipeline(vocab_size=cfg.vocab_size,
                             global_batch=args.global_batch,
                             seq_len=args.seq, seed=args.seed)

    def to_batch(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    trainer = Trainer(step, pipeline, params, opt_state,
                      TrainerConfig(total_steps=args.steps,
                                    checkpoint_every=args.checkpoint_every,
                                    checkpoint_dir=args.checkpoint_dir),
                      to_batch=to_batch)
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")
    out = trainer.run()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"done: {out['step']} steps; loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f}")


if __name__ == "__main__":
    main()
