# Launch layer: production mesh, multi-pod dry-run, train/serve drivers,
# elastic re-mesh. dryrun.py must be executed as a module entry point
# (python -m repro.launch.dryrun) — it force-sets 512 host devices FIRST.
