"""Serving driver: batched prefill + decode with continuous token emission.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \\
        --batch 4 --prompt-len 32 --gen 16

Demonstrates the full serving path of the framework on any architecture:
prompt batch -> prefill (cache fill) -> decode loop (one token/step, greedy).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import init_params, make_cache, model_defs
from repro.training.steps import make_decode_step, make_prefill_step


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="rwkv6-3b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = init_params(model_defs(cfg), key)
    B, S = args.batch, args.prompt_len
    max_seq = S + args.gen

    prompts = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    enc = None
    if cfg.is_encdec:
        enc = jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model))

    prefill_fn = jax.jit(make_prefill_step(cfg, B, max_seq))
    decode_fn = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill_fn(params, prompts, None, enc)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t_prefill = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode_fn(params, tok, cache,
                                  jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} generated={gen.shape[1]}")
    print(f"prefill: {t_prefill*1e3:.1f} ms; decode: "
          f"{t_decode/max(1, args.gen-1)*1e3:.2f} ms/token")
    print("first sequence:", gen[0].tolist())


if __name__ == "__main__":
    main()
