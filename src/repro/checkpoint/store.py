"""Fault-tolerant checkpointing: atomic writes, integrity manifest, keep-k
pruning, resume-latest, and reshard-on-load (elastic re-scale).

Layout: <dir>/step_<N>/ holding arrays.npz + manifest.json. A checkpoint is
written to step_<N>.tmp-<nonce> and atomically os.rename'd into place — a
crash mid-write never corrupts the latest checkpoint (restart resumes from
the previous one). Every array is CRC'd in the manifest and verified on
restore (detects torn/partial writes on non-atomic network filesystems).
Both payload files are fsync'd before the rename and the parent directory
is fsync'd after it, so a power cut in the publish window cannot surface a
step_<N> directory whose contents never reached the platter. If the newest
checkpoint still fails verification (e.g. media corruption after publish),
``restore_checkpoint(..., fallback=True)`` walks the keep-k history to the
newest verifiable step instead of abandoning the run.

Resharding: arrays are stored unsharded (gathered); ``restore_into`` places
them onto the *current* mesh with ``jax.device_put`` against the template's
shardings, so a checkpoint taken on one mesh restores onto any other mesh
whose axis sizes divide the dims (launch/elastic.py drives this).

Multi-host note: in a real multi-pod job each host gathers and writes only
its addressable shards (process_index suffix); this container is
single-process so the gather is trivial — the protocol (tmp+rename+manifest,
keep-k, verify-on-read) is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import zipfile
import zlib

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save_checkpoint(directory: str, step: int, tree, keep: int = 3,
                    extra: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + f".tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": int(step),
        "crc": {k: _crc(v) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # the tmp dir's entries (file names) must be durable before the rename
    # publishes them under the final name
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    _fsync_dir(directory)                     # make the rename itself durable
    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)
    # clean stale tmp dirs from crashed writers
    for name in os.listdir(directory):
        if ".tmp-" in name:
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def list_steps(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and ".tmp" not in name:
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str):
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory: str, step: int | None = None,
                       verify: bool = True, fallback: bool = False) -> tuple:
    """Returns (step, flat dict of arrays, extra).

    With ``fallback=True`` (and no explicit ``step``), a latest checkpoint
    that fails to load or verify does not abort the run: the keep-k history
    is walked newest-to-oldest and the newest verifiable step is returned —
    the recovered step is the first element of the result, so callers can
    report how far back the restore had to reach.
    """
    if step is None:
        steps = list_steps(directory)
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        if not fallback:
            return _restore_step(directory, steps[-1], verify)
        last_err = None
        for s in reversed(steps):
            try:
                return _restore_step(directory, s, verify)
            except _RESTORE_ERRORS as e:
                last_err = e
        raise IOError(
            f"no verifiable checkpoint among steps {steps} in {directory}"
        ) from last_err
    return _restore_step(directory, step, verify)


# everything a torn or corrupted step directory can throw while loading:
# missing files, truncated npz (BadZipFile is a zipfile error), mangled
# json, a manifest missing a key, or the CRC IOError below
_RESTORE_ERRORS = (OSError, ValueError, KeyError, EOFError,
                   zipfile.BadZipFile, json.JSONDecodeError)


def _restore_step(directory: str, step: int, verify: bool) -> tuple:
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    if verify:
        for k, v in flat.items():
            if _crc(v) != manifest["crc"][k]:
                raise IOError(f"checkpoint corruption detected in {k!r} "
                              f"({path})")
    return step, flat, manifest.get("extra", {})


def restore_into(template, flat: dict, shardings=None):
    """Rebuild the pytree of ``template`` from a flat dict, placing each leaf
    with the template leaf's sharding (or the explicit ``shardings`` pytree) —
    this is where cross-mesh resharding happens."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key!r}: checkpoint "
                             f"{arr.shape} vs template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        target_sharding = sh if sh is not None else getattr(
            leaf, "sharding", None)
        if target_sharding is not None and hasattr(target_sharding, "mesh"):
            leaves.append(jax.device_put(arr, target_sharding))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
