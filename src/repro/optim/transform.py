"""Composable gradient transformations over pytrees (mini-optax)."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (updates, state, params=None) -> (updates, state)


def identity() -> GradientTransformation:
    def init(_params):
        return ()

    def update(updates, state, params=None):
        del params
        return updates, state

    return GradientTransformation(init, update)


def apply_updates(params, updates):
    """params + updates, preserving param dtypes (updates may be fp32)."""
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates
    )


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(updates, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            updates, s = t.update(updates, s, params)
            new_state.append(s)
        return updates, tuple(new_state)

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(_params):
        return ()

    def update(updates, state, params=None):
        del params
        return jax.tree_util.tree_map(lambda u: u * factor, updates), state

    return GradientTransformation(init, update)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray]) -> GradientTransformation:
    def init(_params):
        return ScaleByScheduleState(count=jnp.zeros((), jnp.int32))

    def update(updates, state, params=None):
        del params
        factor = schedule(state.count)
        updates = jax.tree_util.tree_map(lambda u: u * factor, updates)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init, update)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(_params):
        return ()

    def update(updates, state, params=None):
        del params
        norm = global_norm(updates)
        factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        updates = jax.tree_util.tree_map(lambda u: u * factor, updates)
        return updates, state

    return GradientTransformation(init, update)


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransformation:
    """Adam moment rescaling. Moments are kept in fp32 regardless of grad dtype."""

    def init(params):
        mu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        nu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return ScaleByAdamState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(updates, state, params=None):
        del params
        count = state.count + 1
        updates32 = jax.tree_util.tree_map(lambda u: u.astype(jnp.float32), updates)
        mu = jax.tree_util.tree_map(lambda m, u: b1 * m + (1 - b1) * u, state.mu, updates32)
        nu = jax.tree_util.tree_map(lambda v, u: b2 * v + (1 - b2) * jnp.square(u), state.nu, updates32)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        out = jax.tree_util.tree_map(
            lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu
        )
        return out, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float, mask: Callable[[Any], Any] | None = None) -> GradientTransformation:
    """AdamW-style decoupled weight decay. ``mask(params)`` -> pytree of bools."""

    def init(_params):
        return ()

    def update(updates, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights requires params")
        if mask is not None:
            m = mask(params)
            updates = jax.tree_util.tree_map(
                lambda u, p, keep: u + weight_decay * p.astype(u.dtype) if keep else u,
                updates, params, m,
            )
        else:
            updates = jax.tree_util.tree_map(
                lambda u, p: u + weight_decay * p.astype(u.dtype), updates, params
            )
        return updates, state

    return GradientTransformation(init, update)
