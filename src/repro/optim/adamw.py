"""Adam / AdamW built from the composable transforms."""

from __future__ import annotations

from typing import Callable

from repro.optim.transform import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    scale,
    scale_by_adam,
    scale_by_schedule,
)


def _lr_transform(learning_rate) -> GradientTransformation:
    if callable(learning_rate):
        return scale_by_schedule(lambda count: -learning_rate(count))
    return scale(-float(learning_rate))


def adam(learning_rate, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransformation:
    return chain(scale_by_adam(b1=b1, b2=b2, eps=eps), _lr_transform(learning_rate))


def adamw(
    learning_rate,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask: Callable | None = None,
) -> GradientTransformation:
    """AdamW with decoupled weight decay (decay applied after moment rescaling,
    multiplied by the learning rate, as in Loshchilov & Hutter)."""
    return chain(
        scale_by_adam(b1=b1, b2=b2, eps=eps),
        add_decayed_weights(weight_decay, mask=mask),
        _lr_transform(learning_rate),
    )
