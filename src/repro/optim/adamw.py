"""Adam / AdamW built from the composable transforms."""

from __future__ import annotations

import functools
from typing import Callable

from repro.optim.transform import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    scale,
    scale_by_adam,
    scale_by_schedule,
)


def _lr_transform(learning_rate) -> GradientTransformation:
    if callable(learning_rate):
        return scale_by_schedule(lambda count: -learning_rate(count))
    return scale(-float(learning_rate))


@functools.lru_cache(maxsize=None)
def _adam_cached(learning_rate: float, b1: float, b2: float,
                 eps: float) -> GradientTransformation:
    return chain(scale_by_adam(b1=b1, b2=b2, eps=eps),
                 _lr_transform(learning_rate))


def adam(learning_rate, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> GradientTransformation:
    """Adam. Constant-rate instances are memoized: transforms are stateless
    ``(init, update)`` pairs, and returning the SAME object for the same
    hyperparameters lets every jit cache keyed on a transform (the fused
    learner, the episode engine's compile cache) hit across independently
    constructed agents — a fleet grid compiles its episode program once, not
    once per ``FleetTuner``."""
    if not callable(learning_rate):
        return _adam_cached(float(learning_rate), b1, b2, eps)
    return chain(scale_by_adam(b1=b1, b2=b2, eps=eps), _lr_transform(learning_rate))


def adamw(
    learning_rate,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask: Callable | None = None,
) -> GradientTransformation:
    """AdamW with decoupled weight decay (decay applied after moment rescaling,
    multiplied by the learning rate, as in Loshchilov & Hutter)."""
    return chain(
        scale_by_adam(b1=b1, b2=b2, eps=eps),
        add_decayed_weights(weight_decay, mask=mask),
        _lr_transform(learning_rate),
    )
