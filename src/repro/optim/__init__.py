"""Optimizer substrate (no optax available offline — built from scratch).

A ``GradientTransformation`` is an ``(init, update)`` pair over arbitrary pytrees,
mirroring the optax API so the code reads familiarly:

    tx = adamw(1e-3, weight_decay=0.1)
    state = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

Used by both the Magpie DDPG agent (actor/critic Adam) and LM training
(AdamW for <=72B-class, Adafactor for the 480B-class MoE — see DESIGN.md §6).
"""

from repro.optim.transform import (
    GradientTransformation,
    apply_updates,
    chain,
    clip_by_global_norm,
    global_norm,
    scale,
    scale_by_adam,
    scale_by_schedule,
    add_decayed_weights,
    identity,
)
from repro.optim.adamw import adam, adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import constant_schedule, warmup_cosine_schedule, linear_schedule

__all__ = [
    "GradientTransformation",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "global_norm",
    "scale",
    "scale_by_adam",
    "scale_by_schedule",
    "add_decayed_weights",
    "identity",
    "adam",
    "adamw",
    "adafactor",
    "constant_schedule",
    "warmup_cosine_schedule",
    "linear_schedule",
]
