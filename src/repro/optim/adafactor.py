"""Adafactor (Shazeer & Stern, 2018) — factored second moments, no first moment.

Chosen for the 480B-class MoE (Arctic): AdamW fp32 states for 475B params need
~30 GB/chip on the 256-chip pod and do not fit 16 GB v5e HBM; Adafactor's factored
second moment is O(rows+cols) instead of O(rows*cols). See DESIGN.md §6.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.transform import GradientTransformation


class _FactoredSlot(NamedTuple):
    v_row: Any  # (..., rows) running mean of squares over the last dim
    v_col: Any  # (..., cols) running mean of squares over the second-to-last dim
    v: Any      # unfactored fallback for <2D params


class AdafactorState(NamedTuple):
    count: jnp.ndarray
    slots: Any  # pytree of _FactoredSlot


def _is_factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor(
    learning_rate,
    decay_rate: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 2,
) -> GradientTransformation:
    del min_dim_size_to_factor  # _is_factored handles the degenerate dims

    def init(params):
        def make_slot(p):
            if _is_factored(p.shape):
                return _FactoredSlot(
                    v_row=jnp.zeros(p.shape[:-1], jnp.float32),
                    v_col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    v=jnp.zeros((), jnp.float32),
                )
            return _FactoredSlot(
                v_row=jnp.zeros((), jnp.float32),
                v_col=jnp.zeros((), jnp.float32),
                v=jnp.zeros(p.shape, jnp.float32),
            )

        slots = jax.tree_util.tree_map(make_slot, params)
        return AdafactorState(count=jnp.zeros((), jnp.int32), slots=slots)

    def update(updates, state, params=None):
        del params
        count = state.count + 1
        t = count.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay_rate)

        if callable(learning_rate):
            lr = learning_rate(state.count)
        else:
            lr = jnp.asarray(learning_rate, jnp.float32)

        def upd(g, slot):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _is_factored(g.shape):
                v_row = beta2 * slot.v_row + (1 - beta2) * jnp.mean(g2, axis=-1)
                v_col = beta2 * slot.v_col + (1 - beta2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                row_mean = jnp.mean(v_row, axis=-1, keepdims=True)
                r = (v_row / jnp.maximum(row_mean, eps))[..., None]
                c = v_col[..., None, :]
                u = g32 / jnp.sqrt(r * c + eps)
                new_slot = _FactoredSlot(v_row=v_row, v_col=v_col, v=slot.v)
            else:
                v = beta2 * slot.v + (1 - beta2) * g2
                u = g32 / jnp.sqrt(v + eps)
                new_slot = _FactoredSlot(v_row=slot.v_row, v_col=slot.v_col, v=v)
            # update clipping by RMS (Adafactor's d=1.0 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, new_slot

        flat_u, treedef = jax.tree_util.tree_flatten(updates)
        flat_s = treedef.flatten_up_to(state.slots)
        out = [upd(g, s) for g, s in zip(flat_u, flat_s)]
        new_updates = treedef.unflatten([o[0] for o in out])
        new_slots = treedef.unflatten([o[1] for o in out])
        return new_updates, AdafactorState(count=count, slots=new_slots)

    return GradientTransformation(init, update)
