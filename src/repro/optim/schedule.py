"""Learning-rate schedules (callables: step -> lr)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float):
    def schedule(count):
        return jnp.asarray(value, jnp.float32) + 0.0 * count
    return schedule


def linear_schedule(init_value: float, end_value: float, transition_steps: int):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(1, transition_steps), 0.0, 1.0)
        return init_value + frac * (end_value - init_value)
    return schedule


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int, end_lr_frac: float = 0.1):
    """Linear warmup to peak, cosine decay to end_lr_frac*peak."""
    def schedule(count):
        t = count.astype(jnp.float32)
        warm = peak_lr * t / max(1, warmup_steps)
        frac = jnp.clip((t - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (end_lr_frac + (1 - end_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(t < warmup_steps, warm, cos)
    return schedule
