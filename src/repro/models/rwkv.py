"""RWKV6 ("Finch") block: attention-free time-mix with *data-dependent decay*
(the architecture's headline feature) + channel-mix FFN.

Math per head (head size c): state S in R^{c x c} over (key, value):
    y_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T
with w_t = exp(-exp(w0 + tanh(x_w A_w) B_w)) — per-channel, data-dependent.

Train/prefill uses a chunked-parallel form: within-chunk [Q,Q] masked decay
tensors (fp32 log-space cumsums, no exp overflow: every exponent <= 0) +
an inter-chunk state scan; decode is the O(1) recurrence. Heads shard over
the model axis; the recurrence itself needs no collectives.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ParamDef, rmsnorm


def rwkv_dims(cfg: ArchConfig) -> tuple:
    c = cfg.rwkv_head_size
    H = cfg.d_model // c
    return H, c


def rwkv6_defs(cfg: ArchConfig, stacked_layers: int = 0) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, c = rwkv_dims(cfg)
    lora = 64
    L = (stacked_layers,) if stacked_layers else ()
    ax = ("layers",) if stacked_layers else ()
    dt = cfg.param_dtype
    d = {
        # time-mix token-shift lerp coefficients
        "mu_r": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "mu_k": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "mu_v": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "mu_g": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "mu_w": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        # data-dependent decay LoRA
        "w0": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "w_lora_a": ParamDef(L + (D, lora), ax + ("embed", "q_lora"), "small", dt),
        "w_lora_b": ParamDef(L + (lora, D), ax + ("q_lora", "embed"), "small", dt),
        # projections
        "wr": ParamDef(L + (D, D), ax + ("embed", "ssm_inner"), "normal", dt),
        "wk": ParamDef(L + (D, D), ax + ("embed", "ssm_inner"), "normal", dt),
        "wv": ParamDef(L + (D, D), ax + ("embed", "ssm_inner"), "normal", dt),
        "wg": ParamDef(L + (D, D), ax + ("embed", "ssm_inner"), "normal", dt),
        "u": ParamDef(L + (H, c), ax + ("ssm_heads", "head_dim"), "zeros", dt),
        "ln_x": ParamDef(L + (D,), ax + ("ssm_inner",), "ones", dt),
        "wo": ParamDef(L + (D, D), ax + ("ssm_inner", "embed"), "normal", dt),
        # channel mix
        "cm_mu_k": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "cm_mu_r": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        "cm_wk": ParamDef(L + (D, F), ax + ("embed", "mlp"), "normal", dt),
        "cm_wv": ParamDef(L + (F, D), ax + ("mlp", "embed"), "normal", dt),
        "cm_wr": ParamDef(L + (D, D), ax + ("embed", "ssm_inner"), "normal", dt),
    }
    return d


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray] = None):
    """x_{t-1} with zero (or ``last``, decode) initial. x [B,S,D]."""
    if x.shape[1] == 1 and last is not None:
        return last[:, None, :]
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
    if last is not None:
        prev = prev.at[:, 0, :].set(last)
    return prev


def _lerp(x, prev, mu):
    return x + (prev - x) * mu


def wkv_chunked(r, k, v, logw, u, chunk: int, init_state=None):
    """Chunked WKV6. r/k/v [B,S,H,c]; logw [B,S,H,c] (<=0); u [H,c].
    Returns (y [B,S,H,c], final_state [B,H,c,c])."""
    B, S, H, c = r.shape
    assert S % chunk == 0
    z = S // chunk
    rc = r.reshape(B, z, chunk, H, c)
    kc = k.reshape(B, z, chunk, H, c)
    vc = v.reshape(B, z, chunk, H, c)
    lw = logw.reshape(B, z, chunk, H, c).astype(jnp.float32)
    cum = jnp.cumsum(lw, axis=2)                          # inclusive
    cum_prev = cum - lw                                   # exclusive
    cum_tot = cum[:, :, -1]                               # [B,z,H,c]

    # intra-chunk: decay(t,s) = exp(cum_prev[t] - cum[s]) for s < t (strict);
    # diagonal handled by the u bonus. All exponents <= 0.
    dec = jnp.exp(jnp.clip(cum_prev[:, :, :, None] - cum[:, :, None, :],
                           a_max=0.0))                    # [B,z,t,s,H,c]
    strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    dec = jnp.where(strict[None, None, :, :, None, None], dec, 0.0)
    scores = jnp.einsum("bzthc,bztshc,bzshc->bztsh",
                        rc.astype(jnp.float32), dec, kc.astype(jnp.float32))
    y_intra = jnp.einsum("bztsh,bzshd->bzthd", scores.astype(r.dtype), vc)
    diag = jnp.einsum("bzthc,hc,bzthc->bzth",
                      rc.astype(jnp.float32), u.astype(jnp.float32),
                      kc.astype(jnp.float32))
    y_intra = y_intra + diag[..., None].astype(r.dtype) * vc

    # chunk-local end state: sum_s exp(cum_tot - cum[s]) k_s (x) v_s
    dte = jnp.exp(cum_tot[:, :, None] - cum)              # [B,z,q,H,c] <=1
    s_local = jnp.einsum("bzshc,bzshd->bzhcd",
                         (dte.astype(r.dtype) * kc), vc)

    def body(S_prev, inp):
        s_loc, ct = inp                                   # [B,H,c,d], [B,H,c]
        S_new = jnp.exp(ct)[..., None].astype(S_prev.dtype) * S_prev + s_loc
        return S_new, S_prev

    S0 = (jnp.zeros((B, H, c, c), r.dtype) if init_state is None
          else init_state.astype(r.dtype))
    S_final, S_starts = jax.lax.scan(
        body, S0, (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(cum_tot, 1, 0)))
    S_starts = jnp.moveaxis(S_starts, 0, 1)               # [B,z,H,c,d]

    y_inter = jnp.einsum("bzthc,bzhcd->bzthd",
                         (jnp.exp(cum_prev).astype(r.dtype) * rc), S_starts)
    y = (y_intra + y_inter).reshape(B, S, H, c)
    return y, S_final


def _decay(cfg, p, xw):
    """Data-dependent per-channel decay, log-space (<= -1e-4)."""
    lora = jnp.einsum("bsl,ld->bsd",
                      jnp.tanh(jnp.einsum("bsd,dl->bsl", xw, p["w_lora_a"])),
                      p["w_lora_b"])
    return -jnp.exp(jnp.clip((p["w0"] + lora).astype(jnp.float32), -8.0, 6.0))


def rwkv6_time_mix(cfg: ArchConfig, p: dict, x: jnp.ndarray, *,
                   cache: Optional[dict] = None) -> tuple:
    """Time-mix block. cache (decode): {"state" [B,H,c,c], "last_x" [B,D]}."""
    H, c = rwkv_dims(cfg)
    B, S, D = x.shape
    decode = cache is not None and "state" in cache and S == 1
    last = cache.get("last_x") if cache else None
    prev = _token_shift(x, last)
    xr = _lerp(x, prev, p["mu_r"])
    xk = _lerp(x, prev, p["mu_k"])
    xv = _lerp(x, prev, p["mu_v"])
    xg = _lerp(x, prev, p["mu_g"])
    xw = _lerp(x, prev, p["mu_w"])

    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, c)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, c)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, S, H, c)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    logw = _decay(cfg, p, xw).reshape(B, S, H, c)

    new_cache = None
    if decode:
        S_prev = cache["state"]                           # [B,H,c,c]
        r1, k1, v1 = r[:, 0], k[:, 0], v[:, 0]            # [B,H,c]
        w1 = jnp.exp(logw[:, 0]).astype(S_prev.dtype)     # [B,H,c]
        kv = jnp.einsum("bhc,bhd->bhcd", k1, v1)
        y = jnp.einsum("bhc,bhcd->bhd", r1,
                       S_prev + p["u"][None, :, :, None].astype(S_prev.dtype)
                       * kv)
        S_new = w1[..., None] * S_prev + kv
        y = y.reshape(B, 1, D)
        new_cache = {"state": S_new, "last_x": x[:, 0]}
    else:
        chunk = min(64, S)
        pad = (-S) % chunk
        if pad:
            rp, kp, vp, lwp = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                               for t in (r, k, v, logw))
        else:
            rp, kp, vp, lwp = r, k, v, logw
        init_state = cache.get("state") if cache else None
        if init_state is None:
            from repro.kernels import ops  # Pallas kernel on TPU
            y, S_fin = ops.wkv6(rp, kp, vp, lwp, p["u"], chunk)
        else:
            y, S_fin = wkv_chunked(rp, kp, vp, lwp, p["u"], chunk,
                                   init_state=init_state)
        y = y[:, :S].reshape(B, S, D)
        if cache is not None:  # prefill handover
            new_cache = {"state": S_fin, "last_x": x[:, -1]}

    # per-head group norm (ln_x), gate, out
    yh = y.reshape(B, S, H, c)
    y32 = yh.astype(jnp.float32)
    mean = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    yh = ((y32 - mean) * jax.lax.rsqrt(var + 64e-5)).astype(y.dtype)
    y = yh.reshape(B, S, D) * p["ln_x"]
    y = y * g
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    return out, new_cache


def rwkv6_channel_mix(cfg: ArchConfig, p: dict, x: jnp.ndarray, *,
                      cache: Optional[dict] = None) -> tuple:
    """Channel-mix FFN with token shift. cache (decode): {"last_x" [B,D]}."""
    last = cache.get("last_x") if cache else None
    prev = _token_shift(x, last)
    xk = _lerp(x, prev, p["cm_mu_k"])
    xr = _lerp(x, prev, p["cm_mu_r"])
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["cm_wk"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["cm_wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["cm_wr"])) * kv
    new_cache = {"last_x": x[:, -1]} if cache is not None else None
    return out, new_cache
