"""Mamba2 (SSD — state-space duality) block, chunked-parallel for train/prefill
and O(1)-state recurrent for decode.

TPU adaptation (DESIGN.md §2): the chunked SSD algorithm maps onto MXU matmuls
(intra-chunk [Q,Q] score matmuls + inter-chunk state scan); heads shard across
the model axis (B/C are per-group, replicated), so the SSD itself needs no
collectives — only the in/out projections reduce over embed.

Shapes: x [B,S,D]; heads H with head_dim P (d_inner = H*P); state N; groups
G=1. State carry [B,H,N,P].
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ParamDef, rmsnorm


def ssm_dims(cfg: ArchConfig) -> tuple:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def mamba2_defs(cfg: ArchConfig, stacked_layers: int = 0) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H = ssm_dims(cfg)
    GN = s.n_groups * s.d_state
    L = (stacked_layers,) if stacked_layers else ()
    ax = ("layers",) if stacked_layers else ()
    dt = cfg.param_dtype
    return {
        "wz": ParamDef(L + (D, d_inner), ax + ("embed", "ssm_inner"), "normal", dt),
        "wx": ParamDef(L + (D, d_inner), ax + ("embed", "ssm_inner"), "normal", dt),
        "wbc": ParamDef(L + (D, 2 * GN), ax + ("embed", "ssm_bc"), "normal", dt),
        "wdt": ParamDef(L + (D, H), ax + ("embed", "ssm_heads"), "normal", dt),
        "conv_x_w": ParamDef(L + (s.d_conv, d_inner), ax + ("conv", "ssm_inner"),
                             "small", dt),
        "conv_x_b": ParamDef(L + (d_inner,), ax + ("ssm_inner",), "zeros", dt),
        "conv_bc_w": ParamDef(L + (s.d_conv, 2 * GN), ax + ("conv", "ssm_bc"),
                              "small", dt),
        "conv_bc_b": ParamDef(L + (2 * GN,), ax + ("ssm_bc",), "zeros", dt),
        "A_log": ParamDef(L + (H,), ax + ("ssm_heads",), "zeros", dt),
        "D_skip": ParamDef(L + (H,), ax + ("ssm_heads",), "ones", dt),
        "dt_bias": ParamDef(L + (H,), ax + ("ssm_heads",), "zeros", dt),
        "norm": ParamDef(L + (d_inner,), ax + ("ssm_inner",), "ones", dt),
        "wo": ParamDef(L + (d_inner, D), ax + ("ssm_inner", "embed"), "normal", dt),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> tuple:
    """Depthwise causal conv over seq. u [B,S,C], w [K,C]. ``state`` is the
    last K-1 inputs from the previous call (decode); returns (out, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([state, u], axis=1)              # [B, S+K-1, C]
    out = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(K)) + b
    new_state = up[:, -(K - 1):, :] if K > 1 else state
    return out, new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x [b,s,h,p], dt [b,s,h] (>=0, already softplus'ed), A [h] (negative),
    Bm/Cm [b,s,n] (G=1, broadcast over heads). Returns (y [b,s,h,p],
    final_state [b,h,n,p]).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    z = s // chunk
    xc = x.reshape(b, z, chunk, h, p)
    dtc = dt.reshape(b, z, chunk, h).astype(jnp.float32)
    Bc = Bm.reshape(b, z, chunk, n)
    Cc = Cm.reshape(b, z, chunk, n)

    a = dtc * A.astype(jnp.float32)                       # [b,z,q,h] log-decay
    a_cum = jnp.cumsum(a, axis=2)                         # inclusive cumsum
    a_tot = a_cum[:, :, -1, :]                            # [b,z,h]

    # ---- intra-chunk (quadratic within chunk, masked causal) -------------
    # decay(t,s) = exp(a_cum[t] - a_cum[s]) for s <= t (state after step s
    # carries through steps s+1..t; dt_s already scales the input at s).
    Ldec = jnp.exp(a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :])
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    Ldec = jnp.where(mask[None, None, :, :, None], Ldec, 0.0)
    CB = jnp.einsum("bzqn,bzsn->bzqs", Cc, Bc).astype(jnp.float32)
    scores = CB[..., None] * Ldec * dtc[:, :, None, :, :]  # [b,z,q,s,h]
    y_intra = jnp.einsum("bzqsh,bzshp->bzqhp", scores.astype(x.dtype), xc)

    # ---- chunk-local end states ------------------------------------------
    decay_to_end = jnp.exp(a_tot[:, :, None, :] - a_cum)  # [b,z,q,h]
    s_local = jnp.einsum("bzsn,bzsh,bzshp->bzhnp",
                         Bc, (decay_to_end * dtc).astype(x.dtype), xc)

    # ---- inter-chunk scan --------------------------------------------------
    def body(S_prev, inp):
        s_loc, at = inp                                   # [b,h,n,p], [b,h]
        S_new = jnp.exp(at)[:, :, None, None].astype(S_prev.dtype) * S_prev \
            + s_loc
        return S_new, S_prev                              # emit state at start

    S0 = (jnp.zeros((b, h, n, p), x.dtype) if init_state is None
          else init_state.astype(x.dtype))
    S_final, S_starts = jax.lax.scan(
        body, S0,
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(a_tot, 1, 0)))
    S_starts = jnp.moveaxis(S_starts, 0, 1)               # [b,z,h,n,p]

    y_inter = jnp.einsum("bzqn,bzhnp->bzqhp", Cc, S_starts) \
        * jnp.exp(a_cum)[..., None].astype(x.dtype)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, S_final


def mamba2_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray, *,
                 cache: Optional[dict] = None) -> tuple:
    """Train/prefill path. cache (prefill only): dict to be *produced*; pass
    cache={} sentinel via want_cache=True style — here: if cache is not None
    we return {"state","conv_x","conv_bc"} for decode handover."""
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    B, S, D = x.shape
    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xs = jnp.einsum("bsd,di->bsi", x, p["wx"])
    bc = jnp.einsum("bsd,dg->bsg", x, p["wbc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    xs, conv_x_state = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"])
    bc, conv_bc_state = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"])
    xs, bc = jax.nn.silu(xs), jax.nn.silu(bc)

    GN = s.n_groups * s.d_state
    Bm, Cm = bc[..., :GN], bc[..., GN:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(B, S, H, s.head_dim)
    chunk = min(s.chunk, S)
    from repro.kernels import ops  # late import; dispatches Pallas on TPU
    y, state = ops.ssd(xh, dt, A, Bm, Cm, chunk)
    y = y + xh * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    new_cache = None
    if cache is not None:
        new_cache = {"state": state, "conv_x": conv_x_state,
                     "conv_bc": conv_bc_state}
    return out, new_cache


def mamba2_decode(cfg: ArchConfig, p: dict, x: jnp.ndarray,
                  cache: dict) -> tuple:
    """One-token recurrent step. x [B,1,D]; cache {"state" [B,H,N,P],
    "conv_x" [B,K-1,d_inner], "conv_bc" [B,K-1,2GN]}."""
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    B = x.shape[0]
    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xs = jnp.einsum("bsd,di->bsi", x, p["wx"])
    bc = jnp.einsum("bsd,dg->bsg", x, p["wbc"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    xs, conv_x_state = _causal_conv(xs, p["conv_x_w"], p["conv_x_b"],
                                    cache["conv_x"])
    bc, conv_bc_state = _causal_conv(bc, p["conv_bc_w"], p["conv_bc_b"],
                                     cache["conv_bc"])
    xs, bc = jax.nn.silu(xs), jax.nn.silu(bc)
    GN = s.n_groups * s.d_state
    Bm, Cm = bc[:, 0, :GN], bc[:, 0, GN:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs[:, 0].reshape(B, H, s.head_dim)
    S_prev = cache["state"]                               # [B,H,N,P]
    dA = jnp.exp(dt * A)                                  # [B,H]
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm, dt.astype(x.dtype), xh)
    S_new = S_prev * dA[:, :, None, None].astype(S_prev.dtype) + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm, S_new)
    y = y + xh * p["D_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(B, 1, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["wo"])
    return out, {"state": S_new, "conv_x": conv_x_state,
                 "conv_bc": conv_bc_state}
