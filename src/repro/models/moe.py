"""Mixture-of-Experts with sort-based capacity dispatch.

Covers both assigned MoE architectures:
  * deepseek-moe-16b: 64 fine-grained routed experts (top-6) + 2 shared
    experts that process every token.
  * arctic-480b: 128 routed experts (top-2) + a parallel *dense residual*
    MLP branch summed with the MoE output.

Dispatch is sort-based (argsort by expert id + capacity cropping), which is
O(T*k + E*C*D) memory — no [T, E, C] one-hot tensors (those explode at
T ~ 1M global tokens). Experts compute as one grouped-FFN einsum over
[E, C, D], which shards as EP x TP (expert axis / expert_mlp axis) and is
kernel-swappable (kernels/gmm.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ParamDef
from repro.models.ffn import ffn_defs, ffn_apply


def moe_defs(cfg: ArchConfig, stacked_layers: int = 0) -> dict:
    m = cfg.moe
    D, E, Fe = cfg.d_model, m.num_experts, m.d_ff_expert
    L = (stacked_layers,) if stacked_layers else ()
    ax = ("layers",) if stacked_layers else ()
    dt = cfg.param_dtype
    d = {
        "router": ParamDef(L + (D, E), ax + ("embed", "experts"), "small", dt),
        "experts": {
            "gate": ParamDef(L + (E, D, Fe),
                             ax + ("experts", "embed", "expert_mlp"), "normal", dt),
            "up": ParamDef(L + (E, D, Fe),
                           ax + ("experts", "embed", "expert_mlp"), "normal", dt),
            "down": ParamDef(L + (E, Fe, D),
                             ax + ("experts", "expert_mlp", "embed"), "normal", dt),
        },
    }
    if m.num_shared_experts:
        d["shared"] = ffn_defs(cfg, d_ff=m.num_shared_experts * Fe,
                               stacked_layers=stacked_layers)
    if m.dense_residual:
        d["dense"] = ffn_defs(cfg, d_ff=cfg.d_ff,
                              stacked_layers=stacked_layers)
    return d


def expert_ffn(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Grouped SwiGLU over [E, C, D] (kernel-swappable hot spot)."""
    from repro.kernels import ops  # late import: kernels never import models
    return ops.grouped_swiglu(x, p["gate"], p["up"], p["down"])


def moe_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> tuple:
    """Returns (out [B,S,D], aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    k, E = m.top_k, m.num_experts
    xf = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xf.astype(m.router_dtype),
                        p["router"].astype(m.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- sort-based dispatch with per-expert capacity -------------------
    # 2-D [E, C+1, D] scatter (not a flat [E*C] buffer) + an explicit expert
    # sharding constraint: GSPMD then moves tokens batch-shard -> expert-shard
    # with ONE all-to-all instead of all-gathering every token everywhere.
    from repro.sharding.activation import constrain_batch, constrain_experts
    C = int(math.ceil(T * k / E * m.capacity_factor))
    C = min(T, max(8, -(-C // 8) * 8))                        # pad to /8
    flat_e = top_e.reshape(-1)                                # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_p = top_p.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.bincount(flat_e, length=E)                   # tokens/expert
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[se]                      # rank in expert
    keep = pos < C
    slot_c = jnp.where(keep, pos, C)                          # C = drop slot

    buf = jnp.zeros((E, C + 1, D), x.dtype)
    updates = constrain_batch(xf[st])                         # [T*k, D] sharded
    buf = buf.at[se, slot_c].set(updates)                     # unique slots
    expert_in = constrain_experts(buf[:, :C])                 # [E, C, D]

    h = expert_ffn(p["experts"], expert_in)                   # [E, C, D]

    contrib = h[se, jnp.minimum(slot_c, C - 1)] * (sp * keep)[:, None]
    contrib = constrain_batch(contrib)        # keep [T*k, D] row-sharded
    out = jnp.zeros((T, D), x.dtype).at[st].add(contrib)
    out = constrain_batch(out).reshape(B, S, D)

    # ---- always-on branches ---------------------------------------------
    if m.num_shared_experts:
        out = out + ffn_apply(cfg, p["shared"], x)
    if m.dense_residual:
        out = out + ffn_apply(cfg, p["dense"], x)

    # ---- load-balance aux (Switch-style): E * sum_e f_e * P_e ------------
    f = counts.astype(jnp.float32) / jnp.maximum(1, T * k)
    pe = jnp.mean(probs.astype(jnp.float32), axis=0)
    aux = E * jnp.sum(f * pe)
    return out, aux
