"""Model substrate foundation: architecture config, parameter definition
system, and shared numerics (norms, initializers).

Design: purely functional. A model is (a) an ``ArchConfig``, (b) a pytree of
``ParamDef`` leaves describing every parameter's shape + *logical axes*, and
(c) forward functions over the materialized param pytree. The same ParamDef
tree drives three things:

    init_params(defs, key)        -> real arrays (smoke tests, examples)
    abstract_params(defs)         -> ShapeDtypeStructs (dry-run: no allocation)
    sharding/rules.defs_to_pspecs -> PartitionSpecs (pjit in/out shardings)

Logical-axis vocabulary (DESIGN.md §4): layers, embed, heads, kv_heads,
head_dim, q_head_dim, mlp, vocab, experts, expert_mlp, state, conv, q_lora,
kv_lora, rwkv_head — a single rules table maps these to mesh axes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0      # deepseek-moe: always-on experts
    dense_residual: bool = False     # arctic: parallel dense MLP branch
    capacity_factor: float = 1.25
    router_dtype: Any = jnp.float32


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64               # mamba2 SSD head size
    chunk: int = 256                 # SSD chunk length
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention
    attention: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False           # qwen-style QKV bias
    rope_theta: float = 1e4
    mrope_sections: tuple = ()       # qwen2-vl M-RoPE (t, h, w) half-dim split
    # submodule configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one *shared* attention block applied every k SSM blocks
    hybrid_attn_every: int = 0
    # rwkv6
    rwkv_head_size: int = 64
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0             # e.g. 1500 mel frames
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    # notes for DESIGN.md bookkeeping (approximations etc.)
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context (SSM/linear-attention state)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Total parameter count (from the ParamDef tree, exact)."""
        from repro.models.transformer import model_defs  # local import (cycle)
        defs = model_defs(self)
        return sum(int(np.prod(d.shape)) for d in jax.tree_util.tree_leaves(
            defs, is_leaf=lambda x: isinstance(x, ParamDef)))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k+shared of E experts)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        from repro.models.transformer import model_defs
        defs = model_defs(self)
        expert_leaves = [
            d for path, d in _iter_defs(defs)
            if "experts" in path
        ]
        expert_params = sum(int(np.prod(d.shape)) for d in expert_leaves)
        active_frac = m.top_k / m.num_experts
        return int(total - expert_params * (1.0 - active_frac))


def _iter_defs(defs, prefix=()):
    if isinstance(defs, ParamDef):
        yield prefix, defs
        return
    for k, v in defs.items():
        yield from _iter_defs(v, prefix + (k,))


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple                      # logical axis name per dim (same length)
    init: str = "normal"             # normal | zeros | ones | small
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def abstract_params(defs):
    """ShapeDtypeStruct pytree — for .lower() without allocating anything."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(defs, key: jax.Array):
    """Materialize real parameters (smoke tests / examples / real training)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))

    def make(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(1, d.shape[-1])
        scale = 0.02 if d.init == "small" else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(d.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [make(d, k) for d, k in zip(leaves, keys)])


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree_util.tree_leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)))


def param_bytes(defs) -> int:
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
               for d in jax.tree_util.tree_leaves(
                   defs, is_leaf=lambda x: isinstance(x, ParamDef)))


# ---------------------------------------------------------------------------
# Shared numerics
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * scale.astype(dt) + bias.astype(dt)


def apply_norm(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def norm_defs(cfg: ArchConfig, stacked: bool = True) -> dict:
    L = (cfg.num_layers,) if stacked else ()
    ax = ("layers",) if stacked else ()
    d = {"scale": ParamDef(L + (cfg.d_model,), ax + ("embed",), "ones",
                           cfg.param_dtype)}
    if cfg.norm == "layernorm":
        d["bias"] = ParamDef(L + (cfg.d_model,), ax + ("embed",), "zeros",
                             cfg.param_dtype)
    return d
