"""The unified model stack: assembles attention/FFN/MoE/SSM/RWKV blocks into
decoder-only (dense, moe, vlm, ssm, hybrid) models; enc-dec lives in
encdec.py and dispatches through the same ``model_defs`` entry point.

Layers are *stacked* along a leading ``layers`` axis and executed with
``jax.lax.scan`` (HLO stays O(1) in depth — essential for 80-layer dry-run
compiles) with optional per-layer remat. Caches follow the same stacking.

Entry points:
    model_defs(cfg)                        -> ParamDef pytree
    forward(cfg, params, tokens, ...)      -> (logits, aux)   [train/eval]
    make_cache(cfg, batch, max_seq, ...)   -> cache pytree (zeros)
    abstract_cache(cfg, batch, max_seq)    -> ShapeDtypeStructs
    prefill(cfg, params, tokens, cache)    -> (logits, cache)
    decode_step(cfg, params, tok, cache, i)-> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (
    gqa_apply, gqa_defs, mla_apply, mla_defs, rope_angles,
)
from repro.models.base import (
    ArchConfig, ParamDef, apply_norm, norm_defs,
)
from repro.models.ffn import ffn_apply, ffn_defs
from repro.sharding.activation import constrain_batch
from repro.models.moe import moe_apply, moe_defs
from repro.models.rwkv import (
    rwkv6_channel_mix, rwkv6_defs, rwkv6_time_mix, rwkv_dims,
)
from repro.models.ssm import mamba2_apply, mamba2_decode, mamba2_defs, ssm_dims


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def embed_defs(cfg: ArchConfig) -> dict:
    # NB: the table's embed dim uses "embed_table" (replicated), NOT the
    # FSDP'd "embed" — a two-way-sharded table turns the token gather into an
    # SPMD involuntary-full-remat (batch-replicated activations downstream).
    # vocab stays sharded over "model".
    d = {"tok": ParamDef((cfg.vocab_size, cfg.d_model),
                         ("vocab", "embed_table"), "small", cfg.param_dtype)}
    return d


def _decoder_layer_defs(cfg: ArchConfig, L: int) -> dict:
    """One stacked decoder layer (attention + mlp/moe families)."""
    d = {"attn_norm": norm_defs(cfg)}
    if cfg.attention == "mla":
        d["attn"] = mla_defs(cfg, stacked_layers=L)
    else:
        d["attn"] = gqa_defs(cfg, stacked_layers=L)
    d["mlp_norm"] = norm_defs(cfg)
    if cfg.moe is not None:
        d["moe"] = moe_defs(cfg, stacked_layers=L)
    else:
        d["mlp"] = ffn_defs(cfg, stacked_layers=L)
    return d


def model_defs(cfg: ArchConfig) -> dict:
    if cfg.is_encdec:
        from repro.models.encdec import encdec_defs
        return encdec_defs(cfg)
    L = cfg.num_layers
    defs: dict = {"embed": embed_defs(cfg)}
    if cfg.family in ("dense", "moe", "vlm"):
        defs["layers"] = _decoder_layer_defs(cfg, L)
    elif cfg.family == "ssm":  # rwkv6
        defs["layers"] = {
            "tm_norm": norm_defs(cfg),
            "time_mix": rwkv6_defs(cfg, stacked_layers=L),
            "cm_norm": norm_defs(cfg),
        }
        # channel-mix defs live inside rwkv6_defs (cm_*) for cache symmetry
    elif cfg.family == "hybrid":  # zamba2
        defs["layers"] = {
            "norm": norm_defs(cfg),
            "mamba": mamba2_defs(cfg, stacked_layers=L),
        }
        defs["shared_attn"] = {
            "norm": norm_defs(cfg, stacked=False),
            "attn": gqa_defs(cfg, stacked_layers=0),
        }
    else:
        raise ValueError(f"unknown family {cfg.family}")
    defs["final_norm"] = norm_defs(cfg, stacked=False)
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), "small", cfg.param_dtype)
    return defs


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def cache_spec(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """ParamDef-style spec of the serving cache (drives zeros + abstract +
    shardings uniformly)."""
    dt = cfg.compute_dtype
    L = cfg.num_layers
    Dh = cfg.resolved_head_dim
    if cfg.is_encdec:
        from repro.models.encdec import encdec_cache_spec
        return encdec_cache_spec(cfg, batch, max_seq)
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.attention == "mla":
            m = cfg.mla
            return {
                "c": ParamDef((L, batch, max_seq, m.kv_lora_rank),
                              ("layers", "batch", "cache_seq", "kv_lora"),
                              "zeros", dt),
                "k_pe": ParamDef((L, batch, max_seq, m.qk_rope_head_dim),
                                 ("layers", "batch", "cache_seq", "q_head_dim"),
                                 "zeros", dt),
            }
        Kv = cfg.num_kv_heads
        return {
            "k": ParamDef((L, batch, max_seq, Kv, Dh),
                          ("layers", "batch", "cache_seq", "kv_heads",
                           "head_dim"), "zeros", dt),
            "v": ParamDef((L, batch, max_seq, Kv, Dh),
                          ("layers", "batch", "cache_seq", "kv_heads",
                           "head_dim"), "zeros", dt),
        }
    if cfg.family == "ssm":
        H, c = rwkv_dims(cfg)
        return {
            "state": ParamDef((L, batch, H, c, c),
                              ("layers", "batch", "ssm_heads", "head_dim",
                               "head_dim"), "zeros", jnp.float32),
            "tm_last": ParamDef((L, batch, cfg.d_model),
                                ("layers", "batch", "embed"), "zeros", dt),
            "cm_last": ParamDef((L, batch, cfg.d_model),
                                ("layers", "batch", "embed"), "zeros", dt),
        }
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_inner, H = ssm_dims(cfg)
        GN = s.n_groups * s.d_state
        n_attn = cfg.num_layers // cfg.hybrid_attn_every
        return {
            "state": ParamDef((L, batch, H, GN // s.n_groups, s.head_dim),
                              ("layers", "batch", "ssm_heads", "state",
                               "head_dim"), "zeros", jnp.float32),
            "conv_x": ParamDef((L, batch, s.d_conv - 1, d_inner),
                               ("layers", "batch", "conv", "ssm_inner"),
                               "zeros", dt),
            "conv_bc": ParamDef((L, batch, s.d_conv - 1, 2 * GN),
                                ("layers", "batch", "conv", "ssm_bc"),
                                "zeros", dt),
            "attn_k": ParamDef((n_attn, batch, max_seq, cfg.num_kv_heads, Dh),
                               ("layers", "batch", "cache_seq", "kv_heads",
                                "head_dim"), "zeros", dt),
            "attn_v": ParamDef((n_attn, batch, max_seq, cfg.num_kv_heads, Dh),
                               ("layers", "batch", "cache_seq", "kv_heads",
                                "head_dim"), "zeros", dt),
        }
    raise ValueError(cfg.family)


def make_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.tree_util.tree_map(
        lambda d: jnp.zeros(d.shape, d.dtype), cache_spec(cfg, batch, max_seq),
        is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int):
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype),
        cache_spec(cfg, batch, max_seq),
        is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _default_positions(cfg: ArchConfig, batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[:, None, :], (batch, 3, seq))
    return pos


def _scan_layers(layer_fn, stacked_params, x0, caches=None, *,
                 remat: str = "none", unroll: int = 1):
    """Scan over the stacked layer axis. ``layer_fn(x, lp, lc) -> (x, new_lc,
    aux)``. Returns (x, new_caches, aux_sum)."""
    def body(carry, inp):
        x, aux = carry
        lp, lc = inp
        if remat == "full":
            fn = jax.checkpoint(layer_fn)
        elif remat == "dots":
            fn = jax.checkpoint(
                layer_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            fn = layer_fn
        x, new_lc, a = fn(x, lp, lc)
        return (x, aux + a), new_lc

    (x, aux), new_caches = jax.lax.scan(
        body, (x0, jnp.zeros((), jnp.float32)), (stacked_params, caches),
        unroll=unroll)
    return x, new_caches, aux


def _attn_mlp_layer(cfg: ArchConfig, angles, impl, cache_index):
    """Builds layer_fn for the dense/moe/vlm families."""
    def layer_fn(x, lp, lc):
        x = constrain_batch(x)
        h = apply_norm(cfg, lp["attn_norm"], x)
        if cfg.attention == "mla":
            a, new_c = mla_apply(cfg, lp["attn"], h, angles=angles, cache=lc,
                                 cache_index=cache_index, impl=impl)
        else:
            a, new_c = gqa_apply(cfg, lp["attn"], h, angles=angles, cache=lc,
                                 cache_index=cache_index, impl=impl)
        x = x + a.astype(x.dtype)
        h = apply_norm(cfg, lp["mlp_norm"], x)
        aux = jnp.zeros((), jnp.float32)
        if cfg.moe is not None:
            f, aux = moe_apply(cfg, lp["moe"], h)
        else:
            f = ffn_apply(cfg, lp["mlp"], h)
        return x + f.astype(x.dtype), new_c, aux
    return layer_fn


def _rwkv_layer(cfg: ArchConfig):
    def layer_fn(x, lp, lc):
        x = constrain_batch(x)
        tm_cache = None if lc is None else \
            {"state": lc["state"], "last_x": lc["tm_last"]}
        h = apply_norm(cfg, lp["tm_norm"], x)
        a, new_tm = rwkv6_time_mix(cfg, lp["time_mix"], h, cache=tm_cache)
        x = x + a.astype(x.dtype)
        cm_cache = None if lc is None else {"last_x": lc["cm_last"]}
        h = apply_norm(cfg, lp["cm_norm"], x)
        f, new_cm = rwkv6_channel_mix(cfg, lp["time_mix"], h, cache=cm_cache)
        x = x + f.astype(x.dtype)
        new_lc = None if lc is None else {
            "state": new_tm["state"], "tm_last": new_tm["last_x"],
            "cm_last": new_cm["last_x"]}
        return x, new_lc, jnp.zeros((), jnp.float32)
    return layer_fn


def _stack(cfg: ArchConfig, params: dict, x: jnp.ndarray, *, angles,
           caches=None, cache_index=None, impl="auto", remat="none",
           unroll: int = 1, decode: bool = False):
    """Runs the layer stack for every decoder-only family. Returns
    (hidden, new_caches, aux)."""
    if cfg.family in ("dense", "moe", "vlm"):
        layer_fn = _attn_mlp_layer(cfg, angles, impl, cache_index)
        lc = None if caches is None else {"k": caches["k"], "v": caches["v"]} \
            if cfg.attention != "mla" else \
            {"c": caches["c"], "k_pe": caches["k_pe"]}
        x, new_lc, aux = _scan_layers(layer_fn, params["layers"], x, lc,
                                      remat=remat, unroll=unroll)
        return x, new_lc, aux

    if cfg.family == "ssm":
        if decode or caches is not None:
            layer_fn_d = _rwkv_layer(cfg)
            x, new_lc, aux = _scan_layers(
                layer_fn_d, params["layers"], x,
                {"state": caches["state"], "tm_last": caches["tm_last"],
                 "cm_last": caches["cm_last"]},
                remat=remat, unroll=unroll)
            return x, new_lc, aux
        x, _, aux = _scan_layers(_rwkv_layer(cfg), params["layers"], x, None,
                                 remat=remat, unroll=unroll)
        return x, None, aux

    if cfg.family == "hybrid":
        return _hybrid_stack(cfg, params, x, angles=angles, caches=caches,
                             cache_index=cache_index, impl=impl, remat=remat,
                             decode=decode)
    raise ValueError(cfg.family)


def _hybrid_stack(cfg: ArchConfig, params: dict, x, *, angles, caches,
                  cache_index, impl, remat, decode):
    """zamba2: groups of ``hybrid_attn_every`` Mamba2 blocks, each group
    followed by ONE application of the weight-shared attention block."""
    every = cfg.hybrid_attn_every
    L = cfg.num_layers
    assert L % every == 0, (L, every)
    groups = L // every
    shared = params["shared_attn"]

    def regroup(t):  # [L, ...] -> [groups, every, ...]
        return t.reshape((groups, every) + t.shape[1:])

    g_params = jax.tree_util.tree_map(regroup, params["layers"])
    g_mamba_cache = None
    g_attn_cache = None
    if caches is not None:
        g_mamba_cache = {k: regroup(caches[k])
                         for k in ("state", "conv_x", "conv_bc")}
        g_attn_cache = {"k": caches["attn_k"], "v": caches["attn_v"]}

    def mamba_layer(h, lp, lc):
        h = constrain_batch(h)
        hn = apply_norm(cfg, lp["norm"], h)
        if decode:
            o, new_lc = mamba2_decode(cfg, lp["mamba"], hn, lc)
        else:
            o, new_lc = mamba2_apply(cfg, lp["mamba"], hn,
                                     cache=lc)
        return h + o.astype(h.dtype), new_lc, jnp.zeros((), jnp.float32)

    def group_fn(carry, inp):
        h, aux = carry
        gp, g_mc, g_ac = inp
        h, new_mc, a = _scan_layers(mamba_layer, gp, h, g_mc, remat=remat)
        hn = apply_norm(cfg, shared["norm"], h)
        attn_out, new_ac = gqa_apply(cfg, shared["attn"], hn, angles=angles,
                                     cache=g_ac, cache_index=cache_index,
                                     impl=impl)
        return (h + attn_out.astype(h.dtype), aux + a), (new_mc, new_ac)

    (x, aux), packed = jax.lax.scan(
        group_fn, (x, jnp.zeros((), jnp.float32)),
        (g_params, g_mamba_cache, g_attn_cache))
    new_caches = None
    if caches is not None:
        new_mc, new_ac = packed
        new_caches = {
            "state": new_mc["state"].reshape((L,) + new_mc["state"].shape[2:]),
            "conv_x": new_mc["conv_x"].reshape((L,) + new_mc["conv_x"].shape[2:]),
            "conv_bc": new_mc["conv_bc"].reshape(
                (L,) + new_mc["conv_bc"].shape[2:]),
            "attn_k": new_ac["k"], "attn_v": new_ac["v"],
        }
    return x, new_caches, aux


def _logits(cfg: ArchConfig, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embed"]["tok"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, *,
            positions: Optional[jnp.ndarray] = None,
            input_embeds: Optional[jnp.ndarray] = None,
            attn_impl: str = "auto", remat: str = "none",
            unroll: int = 1) -> tuple:
    """Full-sequence forward (training / evaluation). Returns (logits, aux).

    ``input_embeds``: modality-frontend stub ([vlm]/[audio] patch or frame
    embeddings, pre-computed) — replaces the token embedding when given
    (decoder-only), or feeds the encoder (enc-dec).
    """
    if cfg.is_encdec:
        from repro.models.encdec import encdec_forward
        return encdec_forward(cfg, params, tokens, input_embeds,
                              attn_impl=attn_impl, remat=remat)
    B, S = tokens.shape[:2]
    if input_embeds is not None:
        x = input_embeds.astype(cfg.compute_dtype)
    else:
        x = params["embed"]["tok"][tokens].astype(cfg.compute_dtype)
    x = constrain_batch(x)
    angles = None
    if cfg.family != "ssm" and cfg.attention != "none":
        if positions is None:
            positions = _default_positions(cfg, B, S)
        hd = (cfg.mla.qk_rope_head_dim if cfg.attention == "mla"
              else cfg.resolved_head_dim)
        angles = rope_angles(positions, hd, cfg.rope_theta,
                             cfg.mrope_sections)
    x, _, aux = _stack(cfg, params, x, angles=angles, caches=None,
                       cache_index=None, impl=attn_impl, remat=remat,
                       unroll=unroll)
    return _logits(cfg, params, x), aux


def prefill(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, cache, *,
            positions: Optional[jnp.ndarray] = None,
            input_embeds: Optional[jnp.ndarray] = None,
            attn_impl: str = "auto", remat: str = "none") -> tuple:
    """Process the prompt, fill the cache; returns (last-token logits, cache)."""
    if cfg.is_encdec:
        from repro.models.encdec import encdec_prefill
        return encdec_prefill(cfg, params, tokens, input_embeds, cache,
                              attn_impl=attn_impl, remat=remat)
    B, S = tokens.shape[:2]
    x = constrain_batch(
        params["embed"]["tok"][tokens].astype(cfg.compute_dtype))
    angles = None
    if cfg.family != "ssm" and cfg.attention != "none":
        if positions is None:
            positions = _default_positions(cfg, B, S)
        hd = (cfg.mla.qk_rope_head_dim if cfg.attention == "mla"
              else cfg.resolved_head_dim)
        angles = rope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    x, new_cache, _ = _stack(cfg, params, x, angles=angles, caches=cache,
                             cache_index=None, impl=attn_impl, remat=remat)
    return _logits(cfg, params, x[:, -1:, :]), new_cache


def decode_step(cfg: ArchConfig, params: dict, tokens: jnp.ndarray, cache,
                cache_index: jnp.ndarray, *,
                positions: Optional[jnp.ndarray] = None) -> tuple:
    """One decode step. tokens [B, 1]; cache_index: scalar int32 (current
    length). Returns (logits [B,1,V], new_cache)."""
    if cfg.is_encdec:
        from repro.models.encdec import encdec_decode_step
        return encdec_decode_step(cfg, params, tokens, cache, cache_index)
    B = tokens.shape[0]
    x = constrain_batch(
        params["embed"]["tok"][tokens].astype(cfg.compute_dtype))
    angles = None
    if cfg.family != "ssm" and cfg.attention != "none":
        if positions is None:
            positions = _default_positions(cfg, B, 1, offset=cache_index)
        hd = (cfg.mla.qk_rope_head_dim if cfg.attention == "mla"
              else cfg.resolved_head_dim)
        angles = rope_angles(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    x, new_cache, _ = _stack(cfg, params, x, angles=angles, caches=cache,
                             cache_index=cache_index, impl="ref", decode=True)
    return _logits(cfg, params, x), new_cache
