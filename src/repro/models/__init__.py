from repro.models.base import (
    ArchConfig, MLAConfig, MoEConfig, ParamDef, SSMConfig,
    abstract_params, init_params, param_bytes, param_count,
)
from repro.models.transformer import (
    abstract_cache, decode_step, forward, make_cache, model_defs, prefill,
)

__all__ = [
    "ArchConfig", "MLAConfig", "MoEConfig", "ParamDef", "SSMConfig",
    "abstract_params", "init_params", "param_bytes", "param_count",
    "abstract_cache", "decode_step", "forward", "make_cache", "model_defs",
    "prefill",
]
