"""Feed-forward blocks: SwiGLU (llama-family) and GELU MLP (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ParamDef


def ffn_defs(cfg: ArchConfig, d_ff: int = 0, stacked_layers: int = 0) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    L = (stacked_layers,) if stacked_layers else ()
    ax = ("layers",) if stacked_layers else ()
    dt = cfg.param_dtype
    if cfg.act == "gelu":
        return {
            "up": ParamDef(L + (D, F), ax + ("embed", "mlp"), "normal", dt),
            "up_b": ParamDef(L + (F,), ax + ("mlp",), "zeros", dt),
            "down": ParamDef(L + (F, D), ax + ("mlp", "embed"), "normal", dt),
            "down_b": ParamDef(L + (D,), ax + ("embed",), "zeros", dt),
        }
    return {
        "gate": ParamDef(L + (D, F), ax + ("embed", "mlp"), "normal", dt),
        "up": ParamDef(L + (D, F), ax + ("embed", "mlp"), "normal", dt),
        "down": ParamDef(L + (F, D), ax + ("mlp", "embed"), "normal", dt),
    }


def ffn_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.act == "gelu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["up"]) + p["up_b"])
        return jnp.einsum("bsf,fd->bsd", h, p["down"]) + p["down_b"]
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["up"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["down"])
