"""Attention substrate: RoPE / M-RoPE, GQA, MLA (+ absorbed decode), and a
block-streaming causal attention used as the memory-safe XLA path for long
sequences (the Pallas flash kernel is the TPU fast path; see kernels/ops.py).

Tensor conventions: activations [B, S, D_model]; per-head [B, S, H, Dh];
caches [B, S_max, Kv, Dh] (or latent [B, S_max, R] for MLA).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ArchConfig, ParamDef


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                mrope_sections: tuple = ()) -> jnp.ndarray:
    """Rotation angles [B, S, half].

    ``positions``: [B, S] int32 — or [B, 3, S] for M-RoPE (t/h/w rows), in
    which case ``mrope_sections`` (summing to half) assigns each frequency
    band to one of the three position rows (Qwen2-VL §2.1).
    """
    half = head_dim // 2
    inv = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)  # [half]
    if mrope_sections:
        if sum(mrope_sections) != half:
            raise ValueError(f"mrope sections {mrope_sections} != half {half}")
        sec_of_freq = np.repeat(np.arange(len(mrope_sections)),
                                mrope_sections)  # [half] -> 0/1/2
        pos = positions.astype(jnp.float32)  # [B, 3, S]
        pos_per_freq = pos[:, sec_of_freq, :]             # [B, half, S]
        return jnp.einsum("bfs,f->bsf", pos_per_freq, inv)
    pos = positions.astype(jnp.float32)                   # [B, S]
    return pos[..., None] * inv                           # [B, S, half]


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate-half RoPE. x: [B, S, H, D]; angles: [B, S, D//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Scaled dot-product attention cores
# ---------------------------------------------------------------------------

def _expand_kv(q, k, v):
    """Broadcast GQA k/v up to the full head count.

    Deliberate for the train/prefill paths: the *head* dim (divisible by the
    model mesh axis) then shards cleanly, whereas kv_heads (4-8) < 16 cannot —
    without this GSPMD must keep [B,Kv,G,Sq,Sk] scores replicated across the
    model axis. The expanded k/v are small next to the scores, and the decode
    path keeps the compact Kv cache layout (seq-sharded instead)."""
    H, Kv = q.shape[2], k.shape[2]
    if H == Kv:
        return k, v
    g = H // Kv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    return k, v


def sdpa_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
             causal: bool, q_offset: int | jnp.ndarray = 0,
             kv_len: Optional[jnp.ndarray] = None,
             scale: Optional[float] = None) -> jnp.ndarray:
    """Reference attention (materializes scores). GQA k/v are head-expanded.

    ``q_offset``: absolute position of q[0] (prefill continuation / decode).
    ``kv_len``: valid prefix length of k/v (padded caches); None = full.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k, v = _expand_kv(q, k, v)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    scores = jnp.einsum("bqhd,bshd->bhqs", q * scale, k).astype(jnp.float32)
    kv_pos = jnp.arange(Sk)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        scores = jnp.where(kv_pos[None, :] <= q_pos[:, None], scores, neg)
    if kv_len is not None:
        scores = jnp.where(kv_pos < kv_len, scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out


def _causal_block_pairs(n_q: int, n_k: int) -> tuple:
    """Static (i, j) block-pair lists covering j<=i (plus the diagonal when
    n_q == n_k); used to skip fully-masked blocks — exact causal FLOPs."""
    pairs = [(i, j) for i in range(n_q) for j in range(n_k) if j <= i]
    idx = np.array(pairs, np.int32)
    return idx[:, 0], idx[:, 1]


def sdpa_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool, q_offset: int = 0,
                 kv_len: Optional[jnp.ndarray] = None,
                 block_q: int = 1024, block_k: int = 1024,
                 scale: Optional[float] = None) -> jnp.ndarray:
    """Flash-style streaming attention in pure JAX (online softmax over block
    pairs). Peak memory O(Bq*Bk) per step instead of O(Sq*Sk); causal block
    pairs below the diagonal are statically skipped (no masked-out FLOPs).

    Requires Sq % block_q == 0 and Sk % block_k == 0 (callers pad). For the
    causal case this assumes q and k cover the same token range (training /
    full prefill), i.e. q_offset aligns block-diagonals: q block i may attend
    k blocks j with j*block_k <= (i+1)*block_q - 1.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k, v = _expand_kv(q, k, v)
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    n_q, n_k = Sq // block_q, Sk // block_k
    qb = (q * scale).reshape(B, n_q, block_q, H, D)
    kb = k.reshape(B, n_k, block_k, H, D)
    vb = v.reshape(B, n_k, block_k, H, v.shape[-1])

    if causal:
        ii, jj = _causal_block_pairs(n_q, n_k)
    else:
        ii = np.repeat(np.arange(n_q, dtype=np.int32), n_k)
        jj = np.tile(np.arange(n_k, dtype=np.int32), n_q)

    Dv = v.shape[-1]                                      # may differ (MLA)
    m0 = jnp.full((B, n_q, block_q, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, n_q, block_q, H), jnp.float32)
    acc0 = jnp.zeros((B, n_q, block_q, H, Dv), jnp.float32)

    kv_pos_base = jnp.arange(block_k)

    def body(carry, ij):
        m, l, acc = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qb, i, axis=1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
        # scores [B, bq, H, bk] — materialized in the INPUT dtype (bf16 on
        # the production path): halves the dominant HBM traffic of XLA-
        # materialized attention; the running max/sum stay fp32.
        s = jnp.einsum("bqhd,bshd->bqhs", qi, kj)
        neg = jnp.asarray(-jnp.inf, s.dtype)
        q_pos = i * block_q + jnp.arange(block_q) + q_offset
        kv_pos = j * block_k + kv_pos_base
        if causal:
            mask = kv_pos[None, :] <= q_pos[:, None]      # [bq, bk]
            s = jnp.where(mask[None, :, None, :], s, neg)
        if kv_len is not None:
            s = jnp.where((kv_pos < kv_len)[None, None, None, :], s, neg)
        mi = jax.lax.dynamic_index_in_dim(m, i, axis=1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, axis=1, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, axis=1, keepdims=False)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1).astype(jnp.float32))
        alpha = jnp.exp(mi - m_new)
        # p materializes once in the input dtype (the pv-dot operand); the
        # l-sum reads the same tensor with fp32 accumulation.
        p = jnp.exp(s.astype(jnp.float32)
                    - m_new[..., None]).astype(s.dtype)
        l_new = li * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
        a_new = ai * alpha[..., None] + jnp.einsum(
            "bqhs,bshd->bqhd", p, vj).astype(jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=1)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, axis=1)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.asarray(ii), jnp.asarray(jj)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def sdpa_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray, *,
                kv_len: jnp.ndarray,
                scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode attention over the full (padded) KV cache.

    Deliberately a single masked einsum-softmax, NOT a sequential block scan:
    with the cache *sequence* axis sharded across the model mesh axis
    (flash-decode style — kv_heads are too few to shard), GSPMD partitions the
    einsums along seq and inserts one all-reduce for the softmax max/sum and
    one for the weighted sum. A scan over blocks would serialize into
    per-block cross-shard collectives. Score memory is tiny (q_len == 1).
    """
    B, Sq, H, D = q.shape
    assert Sq == 1
    Sk, Kv = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    g = H // Kv
    qg = (q * scale).reshape(B, Kv, g, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    kv_pos = jnp.arange(Sk)
    s = jnp.where((kv_pos < kv_len)[None, None, None, :], s,
                  jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache)
    return out.reshape(B, 1, H, D)


def sdpa(q, k, v, *, causal, q_offset=0, kv_len=None, impl: str = "auto",
         scale=None):
    """Dispatch: 'ref' | 'chunked' | 'auto' (chunked once Sq*Sk is large;
    Pallas flash kernel on TPU via kernels.ops when shapes align and no
    custom scale/offset/len is needed)."""
    Sq, Sk = q.shape[1], k.shape[1]
    if impl == "auto" and scale is None and kv_len is None and q_offset == 0:
        import jax as _jax
        if (_jax.default_backend() == "tpu" and Sq % 128 == 0
                and Sk % 128 == 0):
            from repro.kernels import ops
            return ops.attention(q, k, v, causal=causal)
    if impl == "auto":
        impl = "chunked" if (Sq * Sk >= 2048 * 2048 and Sq % 1024 == 0
                             and Sk % 1024 == 0) else "ref"
    if impl == "chunked":
        return sdpa_chunked(q, k, v, causal=causal, q_offset=q_offset,
                            kv_len=kv_len, scale=scale)
    return sdpa_ref(q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
                    scale=scale)


# ---------------------------------------------------------------------------
# GQA block (projections + attention + cache)
# ---------------------------------------------------------------------------

def gqa_defs(cfg: ArchConfig, stacked_layers: int = 0,
             cross: bool = False) -> dict:
    """Parameter defs for one (or a stack of) GQA attention block(s)."""
    D, H, Kv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    L = (stacked_layers,) if stacked_layers else ()
    ax = ("layers",) if stacked_layers else ()
    dt = cfg.param_dtype
    d = {
        "wq": ParamDef(L + (D, H, Dh), ax + ("embed", "heads", "head_dim"),
                       "normal", dt),
        "wk": ParamDef(L + (D, Kv, Dh), ax + ("embed", "kv_heads", "head_dim"),
                       "normal", dt),
        "wv": ParamDef(L + (D, Kv, Dh), ax + ("embed", "kv_heads", "head_dim"),
                       "normal", dt),
        "wo": ParamDef(L + (H, Dh, D), ax + ("heads", "head_dim", "embed"),
                       "normal", dt),
    }
    if cfg.qkv_bias and not cross:
        d["bq"] = ParamDef(L + (H, Dh), ax + ("heads", "head_dim"), "zeros", dt)
        d["bk"] = ParamDef(L + (Kv, Dh), ax + ("kv_heads", "head_dim"), "zeros", dt)
        d["bv"] = ParamDef(L + (Kv, Dh), ax + ("kv_heads", "head_dim"), "zeros", dt)
    return d


def gqa_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray, *,
              angles: Optional[jnp.ndarray], causal: bool = True,
              cache: Optional[dict] = None,
              cache_index: Optional[jnp.ndarray] = None,
              kv_source: Optional[jnp.ndarray] = None,
              impl: str = "auto") -> tuple:
    """One attention block.

    Modes:
      train/eval:      cache=None                      -> (out, None)
      prefill:         cache={"k","v"} zero-init       -> writes [0:S)
      decode:          cache + cache_index (scalar)    -> updates 1 slot
      cross-attention: kv_source=encoder output        -> ignores cache logic
                       (caller pre-projects via cache at prefill if desired)
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    kv_in = kv_source if kv_source is not None else x
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    if angles is not None:
        q = apply_rope(q, angles)
        if kv_source is None:
            k = apply_rope(k, angles)

    new_cache = None
    if cache is not None and cache_index is None:
        # prefill: write k/v into the padded cache
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        out = sdpa(q, k, v, causal=causal, impl=impl)
    elif cache is not None:
        # decode: S == 1
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype),
            (0, cache_index, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype),
            (0, cache_index, 0, 0))
        new_cache = {"k": k_cache, "v": v_cache}
        out = sdpa_decode(q, k_cache, v_cache, kv_len=cache_index + 1)
    else:
        out = sdpa(q, k, v, causal=causal, impl=impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention, DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------

def mla_defs(cfg: ArchConfig, stacked_layers: int = 0) -> dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    L = (stacked_layers,) if stacked_layers else ()
    ax = ("layers",) if stacked_layers else ()
    dt = cfg.param_dtype
    return {
        "wq_a": ParamDef(L + (D, m.q_lora_rank), ax + ("embed", "q_lora"),
                         "normal", dt),
        "q_norm": ParamDef(L + (m.q_lora_rank,), ax + ("q_lora",), "ones", dt),
        "wq_b": ParamDef(L + (m.q_lora_rank, H, qd),
                         ax + ("q_lora", "heads", "q_head_dim"), "normal", dt),
        "wkv_a": ParamDef(L + (D, m.kv_lora_rank + m.qk_rope_head_dim),
                          ax + ("embed", "kv_lora"), "normal", dt),
        "kv_norm": ParamDef(L + (m.kv_lora_rank,), ax + ("kv_lora",), "ones", dt),
        "wk_b": ParamDef(L + (m.kv_lora_rank, H, m.qk_nope_head_dim),
                         ax + ("kv_lora", "heads", "q_head_dim"), "normal", dt),
        "wv_b": ParamDef(L + (m.kv_lora_rank, H, m.v_head_dim),
                         ax + ("kv_lora", "heads", "head_dim"), "normal", dt),
        "wo": ParamDef(L + (H, m.v_head_dim, D),
                       ax + ("heads", "head_dim", "embed"), "normal", dt),
    }


def _mla_q(cfg, p, x):
    from repro.models.base import rmsnorm
    m = cfg.mla
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    return q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def _mla_kv_latent(cfg, p, x):
    from repro.models.base import rmsnorm
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c, k_pe = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    return rmsnorm(c, p["kv_norm"], cfg.norm_eps), k_pe


def mla_apply(cfg: ArchConfig, p: dict, x: jnp.ndarray, *,
              angles: jnp.ndarray, cache: Optional[dict] = None,
              cache_index: Optional[jnp.ndarray] = None,
              impl: str = "auto") -> tuple:
    """MLA attention. Cache = {"c": [B,S,R], "k_pe": [B,S,dr]} — the latent
    cache is the MLA memory win (R + dr per token vs 2*Kv*Dh).

    Train/prefill: expand k/v from the latent and run standard attention.
    Decode: *absorbed* form — fold wk_b into q and wv_b after the probs so
    attention runs directly against the latent cache (no per-step expansion).
    """
    m = cfg.mla
    B, S, D = x.shape
    q_nope, q_pe = _mla_q(cfg, p, x)
    q_pe = apply_rope(q_pe, angles)
    c, k_pe = _mla_kv_latent(cfg, p, x)
    k_pe = apply_rope(k_pe[:, :, None, :], angles)[:, :, 0, :]  # single "head"
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    if cache is not None and cache_index is not None:
        # ---- absorbed decode ------------------------------------------
        c_cache = jax.lax.dynamic_update_slice(
            cache["c"], c.astype(cache["c"].dtype), (0, cache_index, 0))
        pe_cache = jax.lax.dynamic_update_slice(
            cache["k_pe"], k_pe.astype(cache["k_pe"].dtype),
            (0, cache_index, 0))
        new_cache = {"c": c_cache, "k_pe": pe_cache}
        kv_len = cache_index + 1
        # q absorbed into latent space: [B,1,H,R]
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
        s = (jnp.einsum("bshr,btr->bhst", q_lat, c_cache)
             + jnp.einsum("bshk,btk->bhst", q_pe, pe_cache)) * scale
        kv_pos = jnp.arange(c_cache.shape[1])
        s = jnp.where((kv_pos < kv_len)[None, None, None, :],
                      s.astype(jnp.float32), jnp.finfo(jnp.float32).min)
        probs = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_cache)
        out = jnp.einsum("bshr,rhv->bshv", ctx_lat, p["wv_b"])
    else:
        # ---- train / prefill: expand k, v from latent ------------------
        k_nope = jnp.einsum("bsr,rhk->bshk", c, p["wk_b"])
        v = jnp.einsum("bsr,rhv->bshv", c, p["wv_b"])
        H = cfg.num_heads
        k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
        out = sdpa(q, k, v, causal=True, impl=impl, scale=scale)
        new_cache = None
        if cache is not None:
            c_cache = jax.lax.dynamic_update_slice(
                cache["c"], c.astype(cache["c"].dtype), (0, 0, 0))
            pe_cache = jax.lax.dynamic_update_slice(
                cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), (0, 0, 0))
            new_cache = {"c": c_cache, "k_pe": pe_cache}
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache
