"""Encoder-decoder stack (Whisper-large-v3 backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` provides
pre-computed frame embeddings [B, 1500, D]. Encoder: bidirectional self-attn +
GELU MLP (pre-layernorm, sinusoidal positions, no RoPE). Decoder: causal
self-attn (KV cache) + cross-attn to the encoder output (cross K/V projected
once at prefill and cached) + GELU MLP. Decoder positions are sinusoidal
(approximation — real Whisper uses learned positions up to 448; documented in
DESIGN.md; synthetic 32k-decode cells need unbounded positions).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import gqa_apply, gqa_defs, sdpa_decode, sdpa_ref
from repro.models.base import ArchConfig, ParamDef, apply_norm, norm_defs
from repro.models.ffn import ffn_apply, ffn_defs
from repro.sharding.activation import constrain_batch


def sinusoid_positions(seq: int, d_model: int, offset=0) -> jnp.ndarray:
    """[seq, d_model] sinusoidal embedding (Vaswani et al.)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None] + offset
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                   / max(1, half - 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _cross_defs(cfg: ArchConfig, L: int) -> dict:
    return gqa_defs(cfg, stacked_layers=L, cross=True)


def encdec_defs(cfg: ArchConfig) -> dict:
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    return {
        "embed": {"tok": ParamDef((cfg.vocab_size, cfg.d_model),
                                  ("vocab", "embed_table"), "small",
                                  cfg.param_dtype)},
        "encoder": {
            "layers": {
                "attn_norm": norm_defs(cfg),
                "attn": gqa_defs(cfg, stacked_layers=Le),
                "mlp_norm": norm_defs(cfg),
                "mlp": ffn_defs(cfg, stacked_layers=Le),
            },
            "final_norm": norm_defs(cfg, stacked=False),
        },
        "decoder": {
            "layers": {
                "self_norm": norm_defs(cfg),
                "self_attn": gqa_defs(cfg, stacked_layers=Ld),
                "cross_norm": norm_defs(cfg),
                "cross_attn": _cross_defs(cfg, Ld),
                "mlp_norm": norm_defs(cfg),
                "mlp": ffn_defs(cfg, stacked_layers=Ld),
            },
        },
        "final_norm": norm_defs(cfg, stacked=False),
    }


def encdec_cache_spec(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    L = cfg.num_layers
    H, Dh = cfg.num_heads, cfg.resolved_head_dim
    dt = cfg.compute_dtype
    Se = cfg.encoder_seq
    return {
        "self_k": ParamDef((L, batch, max_seq, cfg.num_kv_heads, Dh),
                           ("layers", "batch", "cache_seq", "kv_heads",
                            "head_dim"), "zeros", dt),
        "self_v": ParamDef((L, batch, max_seq, cfg.num_kv_heads, Dh),
                           ("layers", "batch", "cache_seq", "kv_heads",
                            "head_dim"), "zeros", dt),
        "cross_k": ParamDef((L, batch, Se, cfg.num_kv_heads, Dh),
                            ("layers", "batch", "enc_seq", "kv_heads",
                             "head_dim"), "zeros", dt),
        "cross_v": ParamDef((L, batch, Se, cfg.num_kv_heads, Dh),
                            ("layers", "batch", "enc_seq", "kv_heads",
                             "head_dim"), "zeros", dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(cfg: ArchConfig, params: dict, frames: jnp.ndarray, *,
           attn_impl: str = "auto", remat: str = "none") -> jnp.ndarray:
    """frames: [B, S_enc, D] precomputed frame embeddings (frontend stub)."""
    enc = params["encoder"]
    x = frames.astype(cfg.compute_dtype)
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)

    def layer_fn(h, lp, _):
        h = constrain_batch(h)
        a, _ = gqa_apply(cfg, lp["attn"],
                         apply_norm(cfg, lp["attn_norm"], h),
                         angles=None, causal=False, impl=attn_impl)
        h = h + a
        f = ffn_apply(cfg, lp["mlp"], apply_norm(cfg, lp["mlp_norm"], h))
        return h + f, None, jnp.zeros((), jnp.float32)

    from repro.models.transformer import _scan_layers
    x, _, _ = _scan_layers(layer_fn, enc["layers"], x, None, remat=remat)
    return apply_norm(cfg, enc["final_norm"], x)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _cross_attend(cfg, lp, h, k_c, v_c):
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
    out = sdpa_ref(q, k_c, v_c, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, lp["wo"])


def _decoder_layer(cfg: ArchConfig, cache_index, enc_out, attn_impl):
    """enc_out: [B, Se, D] (train/prefill) or None (decode: cached cross K/V)."""
    def layer_fn(x, lp, lc):
        x = constrain_batch(x)
        h = apply_norm(cfg, lp["self_norm"], x)
        self_cache = None if lc is None else \
            {"k": lc["self_k"], "v": lc["self_v"]}
        a, new_self = gqa_apply(cfg, lp["self_attn"], h, angles=None,
                                causal=True, cache=self_cache,
                                cache_index=cache_index, impl=attn_impl)
        x = x + a

        h = apply_norm(cfg, lp["cross_norm"], x)
        if enc_out is not None:
            k_c = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
            v_c = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
        else:
            k_c, v_c = lc["cross_k"], lc["cross_v"]
        x = x + _cross_attend(cfg, lp["cross_attn"], h, k_c, v_c)

        f = ffn_apply(cfg, lp["mlp"], apply_norm(cfg, lp["mlp_norm"], x))
        x = x + f

        new_lc = None
        if lc is not None:
            new_lc = {
                "self_k": new_self["k"] if new_self else lc["self_k"],
                "self_v": new_self["v"] if new_self else lc["self_v"],
                "cross_k": k_c.astype(lc["cross_k"].dtype)
                if enc_out is not None else lc["cross_k"],
                "cross_v": v_c.astype(lc["cross_v"].dtype)
                if enc_out is not None else lc["cross_v"],
            }
        return x, new_lc, jnp.zeros((), jnp.float32)
    return layer_fn


def _dec_embed(cfg, params, tokens, offset=0):
    x = constrain_batch(params["embed"]["tok"][tokens]
                        .astype(cfg.compute_dtype))
    return x + sinusoid_positions(x.shape[1], cfg.d_model,
                                  offset=offset).astype(x.dtype)


def _logits(cfg, params, x):
    x = apply_norm(cfg, params["final_norm"], x)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"])  # tied head


def encdec_forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                   encoder_embeds: jnp.ndarray, *, attn_impl="auto",
                   remat="none") -> tuple:
    """Training forward: encode frames, decode full target sequence."""
    from repro.models.transformer import _scan_layers
    enc_out = encode(cfg, params, encoder_embeds, attn_impl=attn_impl,
                     remat=remat)
    x = _dec_embed(cfg, params, tokens)
    layer_fn = _decoder_layer(cfg, None, enc_out, attn_impl)
    x, _, aux = _scan_layers(layer_fn, params["decoder"]["layers"], x, None,
                             remat=remat)
    return _logits(cfg, params, x), aux


def encdec_prefill(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                   encoder_embeds: jnp.ndarray, cache, *,
                   attn_impl="auto", remat="none") -> tuple:
    from repro.models.transformer import _scan_layers
    enc_out = encode(cfg, params, encoder_embeds, attn_impl=attn_impl,
                     remat=remat)
    x = _dec_embed(cfg, params, tokens)
    layer_fn = _decoder_layer(cfg, None, enc_out, attn_impl)
    x, new_cache, _ = _scan_layers(layer_fn, params["decoder"]["layers"], x,
                                   cache, remat=remat)
    return _logits(cfg, params, x[:, -1:, :]), new_cache


def encdec_decode_step(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                       cache, cache_index) -> tuple:
    from repro.models.transformer import _scan_layers
    x = _dec_embed(cfg, params, tokens, offset=cache_index)
    layer_fn = _decoder_layer(cfg, cache_index, None, "ref")
    x, new_cache, _ = _scan_layers(layer_fn, params["decoder"]["layers"], x,
                                   cache)
    return _logits(cfg, params, x), new_cache
