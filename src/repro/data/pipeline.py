"""Deterministic sharded synthetic token pipeline with O(1) skip-ahead.

Every batch is a pure function of (seed, step, shard) via counter-based
Philox keys — resuming a run at step N reproduces exactly the batches a
never-interrupted run would have seen at step N (no state to checkpoint, no
epoch bookkeeping), and each data-parallel shard draws disjoint streams.

The stream has document structure (exponential lengths, EOS separators) and a
Zipfian unigram distribution so losses behave like language data rather than
uniform noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    shard_index: int = 0       # this host's data shard
    num_shards: int = 1
    eos_id: int = 0
    mean_doc_len: int = 512

    def __post_init__(self):
        if self.global_batch % self.num_shards:
            raise ValueError("global_batch must divide evenly across shards")

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_shards

    def _rng(self, step: int) -> np.random.Generator:
        # Philox counter key is 2x64-bit: (seed|shard, step)
        key = ((self.seed << 32) | self.shard_index, step)
        return np.random.Generator(np.random.Philox(key=key))

    def batch(self, step: int) -> dict:
        """{"tokens": [local_batch, seq], "labels": same} int32.

        Labels are next-token targets (shift-by-one within the sampled
        window; the window is seq_len+1 wide so no token is wasted)."""
        rng = self._rng(step)
        B, S = self.local_batch, self.seq_len
        # Zipfian unigrams (clipped to vocab); EOS document separators.
        toks = rng.zipf(1.3, size=(B, S + 1)).astype(np.int64)
        toks = (toks % (self.vocab_size - 1)) + 1          # 0 reserved: EOS
        doc_end = rng.random((B, S + 1)) < (1.0 / self.mean_doc_len)
        toks = np.where(doc_end, self.eos_id, toks)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def shard(self, shard_index: int, num_shards: int) -> "TokenPipeline":
        """Re-shard (elastic re-scale): same seed -> same global stream."""
        return dataclasses.replace(self, shard_index=shard_index,
                                   num_shards=num_shards)
