"""Hardware constants for the roofline model (target: TPU v5e)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bandwidth: float       # B/s per chip
    ici_bandwidth: float       # B/s per chip per link (bidirectional approx)
    hbm_bytes: float           # capacity per chip


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16e9,
)
