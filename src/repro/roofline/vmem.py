"""VMEM-fit model for the whole-episode megakernel.

One megakernel grid instance owns one session's full episode, so everything
that must stay resident per instance is easy to enumerate: the packed
learner state (4 nets' weights/biases + both Adam moment sets), the FIFO
replay window, the gathered+packed minibatch workspace, the per-step trace,
the pre-drawn exploration inputs, and the env-model state. A Pallas OOM on
an oversized (chunk, capacity, space) combo names an internal buffer and
nothing else; this model rejects the combo BEFORE the kernel is built, with
the top contributors and the knobs that shrink them.

The chunk size itself does not change the per-instance VMEM footprint (the
grid serializes instances; extra sessions cost HBM, which ``core.fleet.
memory_plan`` accounts) — it is named in the error so the message describes
the launch the caller actually asked for.
"""

from __future__ import annotations

import math

# Per-core VMEM on current TPUs (v4/v5e/v5p all carry 16 MiB per core
# except v5p's 32; the conservative floor is the portable budget). Pallas
# double-buffers HBM<->VMEM block copies, which the pipeline factor covers.
VMEM_BYTES = 16 * 2 ** 20
_PIPELINE_FACTOR = 2

# fields of the packed learner layout (kernels.ddpg_fused.pack_params):
# weights [4,L,P,P] + biases [4,L,P] + mom_w [2,2,L,P,P] + mom_b [2,2,L,P]
# + counts [2], all f32/i32 (4 bytes)
_NUM_LAYERS = 3


def episode_vmem_plan(*, steps, capacity, state_dim, action_dim, hidden,
                      num_updates, batch_size, pad, env_state_bytes=0):
    """Byte budget of ONE megakernel grid instance (one session's episode).

    Returns ``{"contributions": {name: bytes}, "per_session_bytes",
    "pipelined_bytes", "budget_bytes", "fits"}``. ``pad`` is the packed
    lane width P from ``kernels.ddpg_fused.packed_dims``;
    ``env_state_bytes`` the flattened env-state leaf bytes per session.
    """
    P = int(pad)
    L = _NUM_LAYERS
    k, m = int(state_dim), int(action_dim)
    if len(tuple(hidden)) + 1 != L:
        raise ValueError(f"hidden={hidden!r}: packed layout is {L}-layer")
    contributions = {
        # 4 live nets + 2x2 Adam moments over the same shapes
        "learner_packed": (4 + 4) * L * P * P * 4
                          + (4 + 4) * L * P * 4 + 2 * 4,
        # FIFO window: s [cap,k], a [cap,m], r [cap], s2 [cap,k]
        "replay_window": int(capacity) * (2 * k + m + 1) * 4,
        # gathered minibatches packed to P lanes: sx/cx/s2x [U,B,P] + r [U,B]
        "minibatch_workspace": int(num_updates) * int(batch_size)
                               * (3 * P + 1) * 4,
        # trace: action_idx [T,m] i32 + metrics [T,k] + rewards/objectives
        # f32 + restarts i32
        "trace": int(steps) * (m * 4 + k * 4 + 12),
        # pre-drawn exploration inputs: warmup/noise [T,m] + use_warmup [T]
        "exploration_inputs": int(steps) * (2 * m * 4 + 1),
        "env_state": int(env_state_bytes),
    }
    per_session = sum(contributions.values())
    pipelined = _PIPELINE_FACTOR * per_session
    return {
        "contributions": contributions,
        "per_session_bytes": per_session,
        "pipelined_bytes": pipelined,
        "budget_bytes": VMEM_BYTES,
        "fits": pipelined <= VMEM_BYTES,
    }


_REMEDIES = {
    "replay_window": "shrink buffer capacity",
    "minibatch_workspace": "lower updates_per_step or batch_size",
    "trace": "run fewer steps per scan (smaller T)",
    "exploration_inputs": "run fewer steps per scan (smaller T)",
    "learner_packed": "smaller hidden widths (pad width P tracks them)",
    "env_state": "slim the env-model state",
}


def check_episode_vmem_fit(*, chunk, steps, capacity, state_dim, action_dim,
                           hidden, num_updates, batch_size, pad,
                           env_state_bytes=0, budget_bytes=None):
    """Raise ``ValueError`` with an actionable message when one episode's
    working set cannot stay VMEM-resident; return the plan when it fits."""
    plan = episode_vmem_plan(
        steps=steps, capacity=capacity, state_dim=state_dim,
        action_dim=action_dim, hidden=hidden, num_updates=num_updates,
        batch_size=batch_size, pad=pad, env_state_bytes=env_state_bytes)
    budget = VMEM_BYTES if budget_bytes is None else int(budget_bytes)
    if plan["pipelined_bytes"] <= budget:
        return plan
    top = sorted(plan["contributions"].items(), key=lambda kv: -kv[1])[:3]
    detail = "; ".join(
        f"{name}={bytes_ / 2 ** 20:.2f} MiB ({_REMEDIES[name]})"
        for name, bytes_ in top)
    raise ValueError(
        f"megakernel episode does not fit in VMEM: chunk={chunk}, "
        f"steps={steps}, capacity={capacity}, space k={state_dim}/"
        f"m={action_dim} needs "
        f"{plan['pipelined_bytes'] / 2 ** 20:.2f} MiB per grid instance "
        f"(x{_PIPELINE_FACTOR} pipelining) against a "
        f"{budget / 2 ** 20:.2f} MiB budget. Top contributors: {detail}. "
        f"Use the standard scan engine (REPRO_MEGAKERNEL=off) for this "
        f"configuration, or shrink the named knobs.")


def suggest_max_capacity(*, steps, state_dim, action_dim, hidden,
                         num_updates, batch_size, pad,
                         env_state_bytes=0, budget_bytes=None):
    """Largest replay capacity that still fits — the error message's main
    remedy, computed rather than guessed."""
    budget = VMEM_BYTES if budget_bytes is None else int(budget_bytes)
    base = episode_vmem_plan(
        steps=steps, capacity=0, state_dim=state_dim,
        action_dim=action_dim, hidden=hidden, num_updates=num_updates,
        batch_size=batch_size, pad=pad, env_state_bytes=env_state_bytes)
    fixed = base["per_session_bytes"]
    per_row = (2 * int(state_dim) + int(action_dim) + 1) * 4
    headroom = budget // _PIPELINE_FACTOR - fixed
    return max(0, math.floor(headroom / per_row))
