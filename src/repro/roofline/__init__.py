from repro.roofline.hw import TPU_V5E
from repro.roofline.analysis import (
    collective_bytes_from_hlo, roofline_terms, model_flops,
)

__all__ = ["TPU_V5E", "collective_bytes_from_hlo", "roofline_terms",
           "model_flops"]
