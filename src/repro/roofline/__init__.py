from repro.roofline.hw import TPU_V5E
from repro.roofline.analysis import (
    collective_bytes_from_hlo, roofline_terms, model_flops,
)
from repro.roofline.vmem import (
    VMEM_BYTES, check_episode_vmem_fit, episode_vmem_plan,
    suggest_max_capacity,
)

__all__ = ["TPU_V5E", "collective_bytes_from_hlo", "roofline_terms",
           "model_flops", "VMEM_BYTES", "check_episode_vmem_fit",
           "episode_vmem_plan", "suggest_max_capacity"]
