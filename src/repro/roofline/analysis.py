"""Roofline-term extraction from compiled dry-run artifacts.

Three terms, all in seconds on the per-device basis (the partitioned HLO IS
the per-device program, so cost_analysis() numbers are already per chip):

    compute    = HLO_FLOPs / peak_FLOP/s
    memory     = HLO_bytes_accessed / HBM_bw
    collective = sum over collective ops of result_bytes * op_factor / ICI_bw

collective bytes are NOT in cost_analysis — we parse the optimized HLO
(compiled.as_text(), after the SPMD partitioner inserted the collectives)
and sum the result-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute (including async -start
forms; -done forms are skipped). Ring-algorithm factors: all-reduce moves
~2x its payload; the others ~1x.

Caveat recorded in EXPERIMENTS.md: bytes_accessed comes from the XLA *CPU*
pipeline whose fusion differs from TPU — the memory term is an upper bound;
the hillclimb tracks its relative movement.
"""

from __future__ import annotations

import math
import re
from typing import Mapping

import numpy as np

from repro.models.base import ArchConfig
from repro.roofline.hw import TPU_V5E, HardwareSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

# `%name = TYPE op-name(` where TYPE is `dt[dims]` or a tuple of them
_LINE_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-op-kind payload bytes (per device, per execution) + counts."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    weighted = 0.0
    for m in _LINE_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        out[op] += b
        counts[op] += 1
        weighted += b * _OP_FACTOR[op]
    return {"bytes": out, "counts": counts, "weighted_bytes": weighted,
            "total_bytes": sum(out.values())}


def roofline_terms(flops: float, bytes_accessed: float,
                   weighted_coll_bytes: float,
                   hw: HardwareSpec = TPU_V5E) -> dict:
    compute = flops / hw.peak_flops_bf16
    memory = bytes_accessed / hw.hbm_bandwidth
    collective = weighted_coll_bytes / hw.ici_bandwidth
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    terms.update(
        dominant=dominant,
        step_s_lower_bound=bound,
        # roofline fraction: useful compute time over the binding term
        roofline_fraction=compute / bound if bound > 0 else 0.0,
    )
    return terms


# ---------------------------------------------------------------------------
# Useful (model) FLOPs — the 6·N·D convention + attention/SSM terms
# ---------------------------------------------------------------------------

def _attn_flops_per_layer(cfg: ArchConfig, B: int, S: int, kind: str) -> float:
    """Score+PV flops for full attention (causal halving for decoders)."""
    H, Dh = cfg.num_heads, cfg.resolved_head_dim
    fwd = 4.0 * B * S * S * H * Dh / 2.0         # qk + pv, causal half
    if kind == "train":
        return 3.0 * fwd                         # fwd + 2x bwd
    return fwd


def model_flops(cfg: ArchConfig, kind: str, batch: int, seq: int) -> float:
    """Global useful FLOPs per executed step.

    train:   6·N_active·tokens (+ attention/SSM sequence-interaction terms)
    prefill: 2·N_active·tokens (+ fwd attention term)
    decode:  2·N_active·batch  (+ attention against the seq-long cache)
    """
    n_active = cfg.active_param_count()
    tokens = batch * seq
    if kind == "train":
        base = 6.0 * n_active * tokens
    elif kind == "prefill":
        base = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        base = 2.0 * n_active * batch

    extra = 0.0
    H, Dh = cfg.num_heads, cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        L = cfg.num_layers
        if kind in ("train", "prefill"):
            extra = L * _attn_flops_per_layer(cfg, batch, seq, kind)
        else:  # decode against the cache
            extra = L * 4.0 * batch * seq * H * Dh
    elif cfg.family == "encdec":
        Ld, Le, Se = cfg.num_layers, cfg.encoder_layers, cfg.encoder_seq
        if kind in ("train", "prefill"):
            enc = Le * 4.0 * batch * Se * Se * H * Dh  # bidirectional
            dec_self = Ld * _attn_flops_per_layer(cfg, batch, seq, kind)
            cross = Ld * 4.0 * batch * seq * Se * H * Dh
            mult = 3.0 if kind == "train" else 1.0
            extra = mult * enc + dec_self + (mult * cross)
        else:
            extra = Ld * 4.0 * batch * seq * H * Dh  # self cache + cross(Se)
            extra += Ld * 4.0 * batch * cfg.encoder_seq * H * Dh
    elif cfg.family == "hybrid":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        hN = (d_inner // s.head_dim) * s.d_state * s.head_dim
        per_tok = 4.0 * hN                      # state update + readout
        L = cfg.num_layers
        n_attn = L // cfg.hybrid_attn_every
        if kind == "train":
            extra = 3.0 * L * per_tok * tokens
            extra += n_attn * _attn_flops_per_layer(cfg, batch, seq, kind)
        elif kind == "prefill":
            extra = L * per_tok * tokens
            extra += n_attn * _attn_flops_per_layer(cfg, batch, seq, kind)
        else:
            extra = L * per_tok * batch
            extra += n_attn * 4.0 * batch * seq * H * Dh
    elif cfg.family == "ssm":  # rwkv6
        Hh = cfg.d_model // cfg.rwkv_head_size
        c = cfg.rwkv_head_size
        per_tok = 4.0 * Hh * c * c
        L = cfg.num_layers
        mult = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[kind]
        n_tok = tokens if kind != "decode" else batch
        extra = mult * L * per_tok * n_tok
    return base + extra
