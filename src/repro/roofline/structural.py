"""Scan-aware structural FLOP/byte counting from jaxprs.

XLA's HloCostAnalysis counts while-loop bodies ONCE (verified empirically:
a 10-iteration scan reports exactly 1/10 of the unrolled flops), which makes
compiled cost_analysis useless for scanned-layer models — an 80-layer model
would be under-counted 80x. The roofline's compute/memory terms therefore
come from walking the traced jaxpr, where scan lengths are static:

  * FLOPs: dot_general (2*B*M*N*K) and conv (2*out*kernel*Cin/groups),
    multiplied through scan lengths; cond takes the max branch. This matches
    the MFU convention (matmul flops; elementwise excluded).
  * bytes: inputs+outputs of "materialization anchor" ops only — dots, convs,
    gathers/scatters, dynamic slices, sorts, reductions — approximating what
    survives XLA fusion (elementwise chains fuse into their anchors). An
    approximation, documented in EXPERIMENTS.md; used consistently for
    baseline-vs-optimized comparisons.

The remat/backward structure is already explicit in the traced gradient
jaxpr, so rematerialized recompute is counted exactly once per execution.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.extend import core as jcore


#: anchors whose full operands + outputs are genuinely read/written
_FULL_ANCHORS = {
    "dot_general", "conv_general_dilated", "sort", "top_k",
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce_and", "reduce_or", "argmax", "argmin", "cumsum",
    "cumlogsumexp", "cummax", "cumprod",
}


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # tokens / abstract units
        return 0


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = 1
    for d in lb:
        batch *= a.shape[d]
    contract = 1
    for d in lc:
        contract *= a.shape[d]
    m = 1
    for i, s in enumerate(a.shape):
        if i not in lc and i not in lb:
            m *= s
    n = 1
    for i, s in enumerate(b.shape):
        if i not in rc and i not in rb:
            n *= s
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    dn = eqn.params["dimension_numbers"]
    fgc = eqn.params.get("feature_group_count", 1)
    kernel_spatial = 1
    for d in dn.rhs_spec[2:]:
        kernel_spatial *= rhs.shape[d]
    cin = rhs.shape[dn.rhs_spec[1]]
    return 2.0 * int(np.prod(out.shape)) * kernel_spatial * cin / max(1, fgc)


def _sub_jaxprs(params: dict):
    for v in params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jcore.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jcore.Jaxpr):
                    yield x


def _count(jaxpr) -> tuple:
    flops = 0.0
    bytes_ = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            flops += _dot_flops(eqn)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
        elif name == "scan":
            f, b = _count(eqn.params["jaxpr"].jaxpr)
            n = eqn.params["length"]
            flops += n * f
            bytes_ += n * b
        elif name == "while":
            f, b = _count(eqn.params["body_jaxpr"].jaxpr)
            flops += f           # trip count unknowable; counted once
            bytes_ += b
        elif name == "cond":
            branches = eqn.params["branches"]
            costs = [_count(br.jaxpr) for br in branches]
            flops += max(c[0] for c in costs)
            bytes_ += max(c[1] for c in costs)
        elif name in _FULL_ANCHORS:
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.invars)
            bytes_ += sum(_aval_bytes(v.aval) for v in eqn.outvars)
        elif name == "dynamic_slice":
            # reads + writes only the slice (operand untouched elsewhere)
            bytes_ += 2 * sum(_aval_bytes(v.aval) for v in eqn.outvars)
        elif name == "dynamic_update_slice":
            # in-place region update: read + write the update operand only
            bytes_ += 2 * _aval_bytes(eqn.invars[1].aval)
        elif name == "gather":
            bytes_ += 2 * sum(_aval_bytes(v.aval) for v in eqn.outvars)
            bytes_ += _aval_bytes(eqn.invars[1].aval)   # indices
        elif name in ("scatter", "scatter-add", "scatter_add",
                      "scatter_mul", "scatter_min", "scatter_max"):
            bytes_ += 2 * _aval_bytes(eqn.invars[2].aval)  # updates r-m-w
            bytes_ += _aval_bytes(eqn.invars[1].aval)      # indices
        else:
            for sub in _sub_jaxprs(eqn.params):
                f, b = _count(sub)
                flops += f
                bytes_ += b
    return flops, bytes_


def structural_costs(fn, *abstract_args) -> dict:
    """Trace ``fn`` with abstract args; return global {flops, bytes}."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    flops, bytes_ = _count(closed.jaxpr)
    # top-level inputs are read (at least) once per execution
    bytes_ += sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
    return {"flops": flops, "bytes": bytes_}
