"""The paper's primary contribution: DDPG-based static-parameter tuning.

Public API:
    ParamSpec / ParamSpace       -- the m-dimensional static parameter space
                                    (continuous/discrete/choice/categorical/
                                    boolean/log2_int kinds, vectorized
                                    unit<->config round-trip)
    MetricSpec / Scalarizer      -- state normalization + multi-objective reward
    ReplayBuffer                 -- FIFO memory pool (single session)
    BatchedReplayBuffer          -- device-resident per-session FIFO fleet pool
    DDPGConfig / MagpieAgent     -- the RL agent (fused scan learner); size it
                                    from a space with DDPGConfig.for_env/for_space
    Tuner                        -- the Fig.1 tuning loop (engine="host" dict
                                    loop, or engine="scan" fused episodes)
    run_episode_scan / run_fleet_episode_scan -- the whole-episode engine: act, env
                                    step, reward, store, learn as ONE
                                    lax.scan program (vmapped + shard_mapped
                                    over the fleet session axis)
    FleetAgent / FleetTuner      -- N vmapped sessions as one fused program
    DeploymentPolicy             -- shadow/canary guardrails with rollback
                                    (core.guardrails), evaluated inside the
                                    episode scan; default off = bitwise the
                                    unguarded engines
    SharingConfig                -- cross-session experience sharing
                                    (core.sharing): cell-merged replay,
                                    periodic parameter averaging, DIAL-style
                                    scoped observation; default off = bitwise
                                    (and by executable identity) the
                                    independent fleet
    ResiliencePolicy             -- self-healing episodes (core.resilience):
                                    in-scan snapshot/reset on non-finite
                                    divergence, degrade-to-frozen past the
                                    reset budget; default off = bitwise (and
                                    by executable identity) the plain engine
    ChunkSupervisor              -- host-side chunk retry/backoff + watchdog
                                    for the streaming fleet runtime; failed
                                    chunks quarantine instead of crashing
    baselines.BestConfigTuner    -- the paper's baseline (plus grid/random)
"""

from repro.core.action_mapping import ParamSpec, ParamSpace
from repro.core.scalarization import MetricSpec, Scalarizer, normalize_state
from repro.core.replay_buffer import BatchedReplayBuffer, ReplayBuffer, Transition
from repro.core.ddpg import (
    DDPGConfig, DDPGState, OUNoise, ddpg_init, ddpg_learn_scan, ddpg_update,
    fleet_act, fleet_init, fleet_learn_scan, gather_minibatches,
    sample_minibatch_indices,
)
from repro.core.agent import MagpieAgent
from repro.core.tuner import Tuner, TuningResult, StepRecord, evaluate_config
from repro.core.episode import (
    EpisodeTrace, enable_persistent_compilation_cache, episode_cache_stats,
    last_fleet_run_stats, live_device_bytes, precompile_fleet_episode,
    run_episode_scan, run_fleet_episode_scan,
)
from repro.core.fleet import (
    FleetAgent, FleetResult, FleetTuner, memory_plan, replay_compact_trace,
)
from repro.core.service import FleetService
from repro.core.sharing import SharingConfig, normalize_sharing, resolve_obs_mask
from repro.core.guardrails import (
    DeploymentPolicy, GuardState, GuardedEpisodeTrace, gate_decision,
    guardrail_counters, guardrail_stats, init_fleet_guard_state,
    init_guard_state, merge_counters, rollback_decision,
)
from repro.core.resilience import (
    ChunkFailure, ChunkSupervisor, HealthState, ResiliencePolicy,
    ResilientEpisodeTrace, health_counters, health_decision, health_stats,
    init_fleet_health_state, init_health_state, merge_health_counters,
    normalize_resilience, normalize_supervisor,
)
from repro.core.baselines import (
    BestConfigTuner, GridSearchTuner, RandomSearchTuner,
)

__all__ = [
    "ParamSpec", "ParamSpace", "MetricSpec", "Scalarizer", "normalize_state",
    "ReplayBuffer", "BatchedReplayBuffer", "Transition",
    "DDPGConfig", "DDPGState", "OUNoise",
    "ddpg_init", "ddpg_update", "ddpg_learn_scan", "sample_minibatch_indices",
    "gather_minibatches", "fleet_init", "fleet_act", "fleet_learn_scan",
    "MagpieAgent", "Tuner", "TuningResult", "StepRecord", "evaluate_config",
    "EpisodeTrace", "run_episode_scan", "run_fleet_episode_scan",
    "enable_persistent_compilation_cache", "episode_cache_stats",
    "last_fleet_run_stats", "live_device_bytes", "precompile_fleet_episode",
    "FleetAgent", "FleetResult", "FleetTuner", "FleetService", "memory_plan",
    "replay_compact_trace",
    "SharingConfig", "normalize_sharing", "resolve_obs_mask",
    "DeploymentPolicy", "GuardState", "GuardedEpisodeTrace", "gate_decision",
    "rollback_decision", "init_guard_state", "init_fleet_guard_state",
    "guardrail_counters", "guardrail_stats", "merge_counters",
    "ResiliencePolicy", "HealthState", "ResilientEpisodeTrace",
    "ChunkSupervisor", "ChunkFailure", "health_decision", "health_counters",
    "health_stats", "merge_health_counters", "init_health_state",
    "init_fleet_health_state", "normalize_resilience", "normalize_supervisor",
    "BestConfigTuner", "GridSearchTuner", "RandomSearchTuner",
]
