"""The paper's primary contribution: DDPG-based static-parameter tuning.

Public API:
    ParamSpec / ParamSpace       -- the m-dimensional static parameter space
    MetricSpec / Scalarizer      -- state normalization + multi-objective reward
    ReplayBuffer                 -- FIFO memory pool
    DDPGConfig / MagpieAgent     -- the RL agent
    Tuner                        -- the Fig.1 tuning loop
    baselines.BestConfigTuner    -- the paper's baseline
"""

from repro.core.action_mapping import ParamSpec, ParamSpace
from repro.core.scalarization import MetricSpec, Scalarizer, normalize_state
from repro.core.replay_buffer import ReplayBuffer, Transition
from repro.core.ddpg import DDPGConfig, DDPGState, OUNoise, ddpg_init, ddpg_update
from repro.core.agent import MagpieAgent
from repro.core.tuner import Tuner, TuningResult, StepRecord

__all__ = [
    "ParamSpec", "ParamSpace", "MetricSpec", "Scalarizer", "normalize_state",
    "ReplayBuffer", "Transition", "DDPGConfig", "DDPGState", "OUNoise",
    "ddpg_init", "ddpg_update", "MagpieAgent", "Tuner", "TuningResult",
    "StepRecord",
]
