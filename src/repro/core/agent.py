"""MagpieAgent — the paper's agent: act (policy + exploration), observe, learn.

Combines the DDPG learner (core.ddpg), the FIFO replay buffer (§II-D) and the
exploration noise. Checkpointable so tuning sessions can be resumed (§III-E:
'users can still resume tuning ... at a later point in time').
"""

from __future__ import annotations

import pickle
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ddpg import (
    DDPGConfig,
    DDPGState,
    OUNoise,
    actor_apply,
    ddpg_init,
    ddpg_learn_scan,
    ddpg_update,
)
from repro.core.replay_buffer import ReplayBuffer


def lhs_warmup_plan(rng: np.random.Generator, warmup_steps: int,
                    action_dim: int) -> np.ndarray:
    """Latin-hypercube warmup plan: each warmup step lands in a distinct
    1/warmup_steps interval of every action coordinate.

    Shared by ``MagpieAgent`` and ``FleetAgent`` — fleet session i must build
    the exact plan its same-seed single agent would.
    """
    plan = np.empty((warmup_steps, action_dim), np.float32)
    for j in range(action_dim):
        perm = rng.permutation(warmup_steps)
        plan[:, j] = (perm + rng.uniform(size=warmup_steps)) / max(
            1, warmup_steps)
    return plan


class MagpieAgent:
    def __init__(self, cfg: DDPGConfig, buffer_capacity: int = 64, seed: int = 0,
                 warmup_steps: int = 8):
        """``warmup_steps``: number of initial exploratory actions before the
        policy takes over — standard DDPG cold-start practice; gives the critic
        something off-policy to regress on when history is empty. Warmup
        actions are *stratified* (Latin-hypercube over the unit action box)
        rather than i.i.d.-uniform so the tiny budget still covers the space."""
        self.cfg = cfg
        self.warmup_steps = warmup_steps
        self.state, (self._actor_tx, self._critic_tx) = ddpg_init(
            jax.random.PRNGKey(seed), cfg
        )
        self.buffer = ReplayBuffer(buffer_capacity, cfg.state_dim, cfg.action_dim)
        self.noise = OUNoise(cfg.action_dim, seed=seed + 1)
        self._np_rng = np.random.default_rng(seed + 2)
        self._learn_key = jax.random.PRNGKey(seed + 3)  # on-device minibatch RNG
        self.steps_taken = 0
        self.last_metrics: dict = {}
        self._warmup_plan = lhs_warmup_plan(self._np_rng, warmup_steps,
                                            cfg.action_dim)

    # -- acting -------------------------------------------------------------

    def act(self, state: np.ndarray, explore: bool = True) -> np.ndarray:
        """Action in [0,1]^m for the given normalized metric state."""
        if explore and self.steps_taken < self.warmup_steps:
            a = self._warmup_plan[self.steps_taken]
        else:
            a = np.asarray(actor_apply(self.state.actor, state.astype(np.float32)))
            if explore:
                a = a + self.noise()
        self.steps_taken += 1
        return np.clip(a, 0.0, 1.0).astype(np.float32)

    # -- learning -----------------------------------------------------------

    def observe(self, state, action, reward, next_state) -> None:
        self.buffer.add(state, action, float(reward), next_state)

    def learn(self, updates: Optional[int] = None, fused: bool = True) -> dict:
        """Run ``updates`` (default cfg.updates_per_step) minibatch gradient steps.

        ``fused=True`` (default) samples minibatches on-device and runs the
        whole inner loop as one jitted ``lax.scan`` (``ddpg_learn_scan``) — one
        dispatch per call instead of ``updates`` dispatches plus a host
        round-trip per minibatch. ``fused=False`` keeps the legacy per-update
        dispatch loop (benchmark reference; see benchmarks/fleet_throughput.py).
        """
        if len(self.buffer) == 0:
            # host-path guard for the empty-buffer hazard: learning before
            # the first observe() is a silent no-op here; the fused learner
            # itself raises if handed size == 0 directly (core.ddpg).
            return {}
        n = self.cfg.updates_per_step if updates is None else updates
        if n <= 0:
            return {}
        if fused:
            self._learn_key, key = jax.random.split(self._learn_key)
            data, size = self.buffer.storage()
            self.state, metrics = ddpg_learn_scan(
                self.state, data, size, key, self.cfg,
                self._actor_tx, self._critic_tx, n,
            )
            self.last_metrics = {k: float(v[-1]) for k, v in metrics.items()}
            return self.last_metrics
        metrics = {}
        for _ in range(n):
            batch = self.buffer.sample(self._np_rng, self.cfg.batch_size)
            self.state, metrics = ddpg_update(
                self.state, batch, self.cfg, self._actor_tx, self._critic_tx
            )
        self.last_metrics = {k: float(v) for k, v in metrics.items()}
        return self.last_metrics

    # -- persistence (resume tuning) ----------------------------------------

    def state_dict(self) -> dict:
        return {
            "ddpg": jax.tree_util.tree_map(np.asarray, self.state),
            "buffer": self.buffer.state_dict(),
            "noise": self.noise.state_dict(),
            "np_rng": self._np_rng.bit_generator.state,
            "learn_key": np.asarray(self._learn_key),
            "steps_taken": self.steps_taken,
            "cfg": tuple(self.cfg),
        }

    def load_state_dict(self, d: dict) -> None:
        if tuple(self.cfg) != tuple(d["cfg"]):
            raise ValueError("agent config mismatch on resume")
        self.state = DDPGState(*jax.tree_util.tree_map(
            lambda x: x, tuple(d["ddpg"])
        ))
        self.buffer.load_state_dict(d["buffer"])
        self.noise.load_state_dict(d["noise"])
        self._np_rng.bit_generator.state = d["np_rng"]
        if "learn_key" in d:  # pre-fused-learner checkpoints lack it
            self._learn_key = jnp.asarray(d["learn_key"])
        self.steps_taken = int(d["steps_taken"])

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self.state_dict(), f)

    def load(self, path: str) -> None:
        with open(path, "rb") as f:
            self.load_state_dict(pickle.load(f))
