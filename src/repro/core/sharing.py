"""Cross-session experience sharing for fleet tuning (DIAL, PAPERS.md).

The fleet's grid runs many sessions per workload×objective *cell* (one per
seed). Independent sessions rediscover the same correlated response surface
N times over; sharing amortizes exploration across the cell, which is the
paper's real cost metric — steps (wall-clock tuning time) to the gain.

Three composable, default-off modes, configured by ``SharingConfig``:

* ``shared_replay`` — the cell keeps ONE merged FIFO replay window instead
  of k independent ones (``BatchedReplayBuffer(groups=...)``); every member
  samples minibatches from it, so each learner sees k× transitions per env
  step and replay bytes/session drop k×.
* ``avg_every`` — every that many env steps, actor/critic (and target)
  parameter pytrees are averaged over the cell inside the episode scan
  (``avg_opt_state`` extends this to the Adam moments). ``None`` (or
  ``math.inf``) disables averaging; ``avg_every`` larger than the run just
  never fires.
* ``observation_scopes`` — DIAL-style local-metric observation: sessions
  see only metrics whose scope is in this tuple (e.g. ``("OSC",)`` for a
  client-side tuner); the objective/reward still read the full state, only
  the *learner's* observation is masked.

``normalize_sharing`` canonicalizes a fully-off config to ``None`` so that
"sharing off" keys the exact same ``_compiled_episode`` cache entry as code
that never heard of sharing — bitwise-off by executable identity.

Sharing composes with ``core.resilience``: in a resilient cell body the
contribution mask that gates merged-FIFO writes and the averaging mean is
narrowed to ``active & ~corrupted & ~degraded``, so one member's NaN can
never poison the cell's shared window or drag the averaged parameters —
while the degraded member keeps riding the cell program as a frozen
incumbent (it computes, it just no longer contributes).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple


class SharingConfig(NamedTuple):
    """Hashable — part of the compiled-episode cache key."""

    shared_replay: bool = False
    avg_every: Optional[int] = None
    avg_opt_state: bool = False
    observation_scopes: Optional[Tuple[str, ...]] = None

    @property
    def averaging(self) -> bool:
        return self.avg_every is not None

    @property
    def any_on(self) -> bool:
        return (self.shared_replay or self.averaging
                or self.observation_scopes is not None)


def normalize_sharing(sharing) -> Optional[SharingConfig]:
    """Canonical ``SharingConfig`` or ``None`` when every mode is off.

    ``avg_every=math.inf`` means "never average" and canonicalizes to
    ``None`` averaging; ``observation_scopes`` becomes a sorted tuple so two
    spellings of the same scope set hash identically.
    """
    if sharing is None:
        return None
    if not isinstance(sharing, SharingConfig):
        raise TypeError(f"expected SharingConfig or None, got {sharing!r}")
    avg = sharing.avg_every
    if avg is not None and (avg == math.inf or avg <= 0):
        avg = None
    elif avg is not None:
        avg = int(avg)
    scopes = sharing.observation_scopes
    if scopes is not None:
        scopes = tuple(sorted(str(s) for s in scopes))
    out = SharingConfig(shared_replay=bool(sharing.shared_replay),
                        avg_every=avg,
                        avg_opt_state=bool(sharing.avg_opt_state and
                                           avg is not None),
                        observation_scopes=scopes)
    return out if out.any_on else None


def resolve_obs_mask(sharing, metric_specs, state_metrics):
    """``sharing.observation_scopes`` resolved against an env's metric specs:
    a hashable 0/1 float tuple over the k state metrics (None when the mode
    is off) — the form the compiled-episode cache keys on."""
    sharing = normalize_sharing(sharing)
    if sharing is None or sharing.observation_scopes is None:
        return None
    from repro.envs.metrics import scope_mask
    return tuple(float(v) for v in scope_mask(
        metric_specs, state_metrics, sharing.observation_scopes))
