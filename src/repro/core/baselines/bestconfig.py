"""BestConfig (Zhu et al., SoCC'17) — the paper's baseline, reimplemented.

Two components, per the original paper and Magpie §IV-A:

1. Divide-and-Diverge Sampling (DDS): divide each parameter range into r
   intervals; Latin-hypercube diverge so each interval of each parameter is
   represented exactly once -> r samples per round.
2. Recursive Bound and Search (RBS): assume better configurations lie near the
   best point found so far; bound the space to the +-1-interval neighbourhood
   around it and re-run DDS inside the bounded space; recurse, shrinking.

Black-box: it sees only the scalar objective, never the internal system metrics —
exactly the contrast Magpie draws (§IV-A: search-based methods 'employ no
information from the DFS or workloads').
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.scalarization import Scalarizer
from repro.core.tuner import StepRecord, TuningResult, evaluate_config


@dataclasses.dataclass
class _Box:
    lo: np.ndarray  # unit-space lower bounds, shape [m]
    hi: np.ndarray  # unit-space upper bounds, shape [m]


class BestConfigTuner:
    """Same interface as core.tuner.Tuner (run(steps) -> TuningResult) so the
    benchmarks drive both tuners identically."""

    def __init__(self, env, scalarizer: Scalarizer, round_size: int = 100,
                 eval_runs: int = 3, seed: int = 0):
        """``round_size`` defaults to the original BestConfig's sample-set size
        (100): with a 30-step budget that is a single truncated DDS round over
        the full space — the configuration the Magpie authors compare against.
        Small ``round_size`` (e.g. 10) gives the paper's 'Progressive
        BestConfig' behaviour (Fig. 7): early recursive bounding that is easily
        trapped by noisy observations."""
        self.env = env
        self.scalarizer = scalarizer
        self.round_size = round_size
        self.eval_runs = eval_runs
        self._rng = np.random.default_rng(seed)
        self.history: list = []
        self.simulated_restart_seconds = 0.0
        self.default_config = env.param_space.default_config()
        self.default_metrics = self._evaluate(self.default_config, runs=eval_runs)
        self._cur_config = dict(self.default_config)
        self.best_config = dict(self.default_config)
        self.best_metrics = dict(self.default_metrics)
        self.best_objective = scalarizer.objective(self.default_metrics)
        self._box = _Box(
            lo=np.zeros(env.param_space.dim), hi=np.ones(env.param_space.dim)
        )
        self._best_unit = env.param_space.to_action(self.default_config).astype(float)

    def _evaluate(self, config: dict, runs: int) -> dict:
        return evaluate_config(self.env, config, runs)

    # -- DDS ----------------------------------------------------------------

    def _dds_round(self, box: _Box, r: int) -> list:
        """r Latin-hypercube samples: each of the r intervals of each parameter
        is represented exactly once across the sample set."""
        m = self.env.param_space.dim
        width = (box.hi - box.lo) / r
        samples = np.empty((r, m))
        for j in range(m):
            perm = self._rng.permutation(r)  # interval index per sample
            offsets = self._rng.uniform(0.0, 1.0, r)  # position within interval
            samples[:, j] = box.lo[j] + (perm + offsets) * width[j]
        return [np.clip(row, 0.0, 1.0) for row in samples]

    def _bound(self, center: np.ndarray, r: int) -> _Box:
        """RBS: shrink to the +-1-interval neighbourhood around the best point."""
        width = (self._box.hi - self._box.lo) / r
        return _Box(
            lo=np.clip(center - width, 0.0, 1.0),
            hi=np.clip(center + width, 0.0, 1.0),
        )

    # -- main loop ------------------------------------------------------------

    def _probe_batch(self, configs: list) -> tuple:
        """Run one probe batch: (metric dicts, restart costs, seconds/probe).

        Pure-model environments (``envs.base.ModelEnv``) evaluate the whole
        batch in ONE dispatch (``apply_batch`` chains the same per-probe step
        graph under ``lax.scan``, so results are bitwise those of sequential
        applies); every other environment falls back to the host loop."""
        import time
        t0 = time.perf_counter()
        if hasattr(self.env, "apply_batch"):
            metrics, restarts = self.env.apply_batch(configs)
        else:
            metrics, restarts, prev = [], [], self._cur_config
            for config in configs:
                metrics.append(self.env.apply(config))
                restarts.append(self.env.restart_cost(config, prev))
                prev = config
        per = (time.perf_counter() - t0) / max(1, len(configs))
        return metrics, restarts, per

    def run(self, steps: int, learn: bool = True) -> TuningResult:
        del learn  # interface parity with Tuner
        import time
        t_wall = time.perf_counter()
        start = len(self.history)
        taken = 0
        while taken < steps:
            r = min(self.round_size, steps - taken)
            units = self._dds_round(self._box, r)
            configs = [self.env.param_space.to_config(u) for u in units]
            all_metrics, restarts, action_seconds = self._probe_batch(configs)
            for unit, config, metrics, restart in zip(
                    units, configs, all_metrics, restarts):
                restart = float(restart)
                self.simulated_restart_seconds += restart
                objective = self.scalarizer.objective(metrics)
                if objective > self.best_objective:
                    self.best_objective = objective
                    self.best_config = dict(config)
                    self.best_metrics = dict(metrics)
                    self._best_unit = np.asarray(unit, float)
                self.history.append(StepRecord(
                    step=start + taken, config=config, metrics=metrics,
                    objective=objective, reward=0.0, restart_seconds=restart,
                    action_seconds=action_seconds, learn_seconds=0.0,
                ))
                self._cur_config = config
                taken += 1
                if taken >= steps:
                    break
            # Recursive bound around the best point for the next round.
            self._box = self._bound(self._best_unit, max(2, r))

        best_metrics = self._evaluate(self.best_config, runs=self.eval_runs)
        return TuningResult(
            best_config=dict(self.best_config),
            best_objective=self.scalarizer.objective(best_metrics),
            best_metrics=best_metrics,
            default_config=dict(self.default_config),
            default_metrics=dict(self.default_metrics),
            history=list(self.history),
            simulated_restart_seconds=self.simulated_restart_seconds,
            wall_seconds=time.perf_counter() - t_wall,
        )
