"""Exhaustive grid search (oracle-ish baseline for small spaces; used by tests
to find the true optimum of the simulator so Magpie's regret can be asserted)."""

from __future__ import annotations

import time

from repro.core.scalarization import Scalarizer
from repro.core.tuner import StepRecord, TuningResult, evaluate_config


class GridSearchTuner:
    def __init__(self, env, scalarizer: Scalarizer, points_per_dim: int = 8,
                 eval_runs: int = 3, max_grid_points: int = 200_000):
        """The grid is sized from the ``ParamSpace``: each axis contributes
        ``min(points_per_dim, cardinality)`` points (a boolean axis is 2, the
        11-value stripe-size axis at most 11), and construction fails fast if
        the Cartesian product still exceeds ``max_grid_points`` — exhaustive
        search stops being an oracle in high-dimensional mixed spaces, which is
        the paper's motivation for RL over black-box search."""
        self.env = env
        self.scalarizer = scalarizer
        self.points_per_dim = points_per_dim
        n = env.param_space.grid_size(points_per_dim)
        if n > max_grid_points:
            raise ValueError(
                f"grid of {n} points over {env.param_space.dim}-D space "
                f"exceeds max_grid_points={max_grid_points}; lower "
                f"points_per_dim or use a search baseline")
        self.eval_runs = eval_runs
        self.history: list = []
        self.simulated_restart_seconds = 0.0
        self.default_config = env.param_space.default_config()
        self.default_metrics = self._evaluate(self.default_config, runs=eval_runs)
        self._cur_config = dict(self.default_config)
        self.best_config = dict(self.default_config)
        self.best_metrics = dict(self.default_metrics)
        self.best_objective = scalarizer.objective(self.default_metrics)

    def _evaluate(self, config: dict, runs: int) -> dict:
        return evaluate_config(self.env, config, runs)

    def _evaluate_grid(self, configs: list) -> list:
        """Metric dicts for the whole grid, ``eval_runs`` runs each.

        Pure-model envs (``ModelEnv``) evaluate every (config, run) pair in
        ONE dispatch via ``apply_batch`` — bitwise the sequential
        ``evaluate_config`` calls, since the batch chains the identical step
        graph; other envs evaluate config by config."""
        runs = self.eval_runs
        repeated = [c for c in configs for _ in range(runs)]
        per_run, _ = self.env.apply_batch(repeated, eval_run=True)
        out = []
        for i in range(len(configs)):
            group = per_run[i * runs:(i + 1) * runs]
            acc: dict = {}
            for m in group:
                for k, v in m.items():
                    acc[k] = acc.get(k, 0.0) + v
            out.append({k: v / runs for k, v in acc.items()})
        return out

    def run(self, steps: int = 0, learn: bool = True) -> TuningResult:
        """Ignores ``steps``; visits the full grid."""
        del steps, learn
        t_wall = time.perf_counter()
        grid = self.env.param_space.grid(self.points_per_dim)
        # Batch envs evaluate the grid up front in one dispatch; host envs
        # keep the original evaluate-then-restart interleaving (their RNG
        # stream order is observable).
        batched = hasattr(self.env, "apply_batch")
        all_metrics = self._evaluate_grid(grid) if batched else None
        for i, config in enumerate(grid):
            metrics = (all_metrics[i] if batched
                       else self._evaluate(config, runs=self.eval_runs))
            restart = self.env.restart_cost(config, self._cur_config)
            self.simulated_restart_seconds += restart
            objective = self.scalarizer.objective(metrics)
            if objective > self.best_objective:
                self.best_objective = objective
                self.best_config = dict(config)
                self.best_metrics = dict(metrics)
            self.history.append(StepRecord(
                step=i, config=config, metrics=metrics, objective=objective,
                reward=0.0, restart_seconds=restart, action_seconds=0.0,
                learn_seconds=0.0,
            ))
            self._cur_config = config
        return TuningResult(
            best_config=dict(self.best_config),
            best_objective=self.best_objective,
            best_metrics=dict(self.best_metrics),
            default_config=dict(self.default_config),
            default_metrics=dict(self.default_metrics),
            history=list(self.history),
            simulated_restart_seconds=self.simulated_restart_seconds,
            wall_seconds=time.perf_counter() - t_wall,
        )
