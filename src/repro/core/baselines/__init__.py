from repro.core.baselines.bestconfig import BestConfigTuner
from repro.core.baselines.random_search import RandomSearchTuner
from repro.core.baselines.grid_search import GridSearchTuner

__all__ = ["BestConfigTuner", "RandomSearchTuner", "GridSearchTuner"]
