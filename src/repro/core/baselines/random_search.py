"""Uniform random search over the unit parameter box (sanity baseline)."""

from __future__ import annotations

import time

import numpy as np

from repro.core.scalarization import Scalarizer
from repro.core.tuner import StepRecord, TuningResult, evaluate_config


class RandomSearchTuner:
    """Samples the unit box of whatever ``ParamSpace`` the environment owns —
    the box's dimensionality and the unit->config decoding both come from the
    space, so the baseline runs unchanged on 2-D or 8-D mixed-type spaces."""

    def __init__(self, env, scalarizer: Scalarizer, eval_runs: int = 3, seed: int = 0):
        self.env = env
        self.scalarizer = scalarizer
        self.eval_runs = eval_runs
        self._rng = np.random.default_rng(seed)
        self.history: list = []
        self.simulated_restart_seconds = 0.0
        self.default_config = env.param_space.default_config()
        self.default_metrics = self._evaluate(self.default_config, runs=eval_runs)
        self._cur_config = dict(self.default_config)
        self.best_config = dict(self.default_config)
        self.best_metrics = dict(self.default_metrics)
        self.best_objective = scalarizer.objective(self.default_metrics)

    def _evaluate(self, config: dict, runs: int) -> dict:
        return evaluate_config(self.env, config, runs)

    def run(self, steps: int, learn: bool = True) -> TuningResult:
        del learn
        t_wall = time.perf_counter()
        start = len(self.history)
        # The whole run is one probe batch: draw units in the sequential RNG
        # order, then evaluate. Pure-model envs (``ModelEnv``) run the batch
        # as ONE dispatch (bitwise the sequential applies); others loop.
        units = [self._rng.uniform(0.0, 1.0, self.env.param_space.dim)
                 for _ in range(steps)]
        configs = [self.env.param_space.to_config(u) for u in units]
        if hasattr(self.env, "apply_batch"):
            all_metrics, restarts = self.env.apply_batch(configs)
        else:
            all_metrics, restarts, prev = [], [], self._cur_config
            for config in configs:
                all_metrics.append(self.env.apply(config))
                restarts.append(self.env.restart_cost(config, prev))
                prev = config
        for i, (config, metrics, restart) in enumerate(
                zip(configs, all_metrics, restarts), start=start):
            restart = float(restart)
            self.simulated_restart_seconds += restart
            objective = self.scalarizer.objective(metrics)
            if objective > self.best_objective:
                self.best_objective = objective
                self.best_config = dict(config)
                self.best_metrics = dict(metrics)
            self.history.append(StepRecord(
                step=i, config=config, metrics=metrics, objective=objective,
                reward=0.0, restart_seconds=restart, action_seconds=0.0,
                learn_seconds=0.0,
            ))
            self._cur_config = config
        best_metrics = self._evaluate(self.best_config, runs=self.eval_runs)
        return TuningResult(
            best_config=dict(self.best_config),
            best_objective=self.scalarizer.objective(best_metrics),
            best_metrics=best_metrics,
            default_config=dict(self.default_config),
            default_metrics=dict(self.default_metrics),
            history=list(self.history),
            simulated_restart_seconds=self.simulated_restart_seconds,
            wall_seconds=time.perf_counter() - t_wall,
        )
