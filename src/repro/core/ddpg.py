"""DDPG (Lillicrap et al.) in pure JAX — the paper's RL algorithm (§II-C).

The actor maps the metric state s_t in [0,1]^k to an action a in [0,1]^m (one
coordinate per static parameter; the action-mapping layer turns it into a real
configuration). The critic is the Q function Q_phi(s, a). Both are small MLPs —
the paper trains them on a single RTX 5000; at this size CPU training is faithful.

Learning follows §II-C exactly:
  critic:  argmin_phi E[(Q_phi(s,a) - (r + gamma * Q_targ(s', mu_targ(s'))))^2]
  actor:   argmax_theta E[Q_phi(s, mu_theta(s))]
with Polyak-averaged target networks for both.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, sizes: Sequence[int]) -> list:
    """He-uniform MLP init; returns a list of {"w","b"} layer dicts."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (fan_in, fan_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        bound = float(np.sqrt(6.0 / fan_in))
        w = jax.random.uniform(k, (fan_in, fan_out), jnp.float32, -bound, bound)
        params.append({"w": w, "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def mlp_apply(params: list, x: jnp.ndarray) -> jnp.ndarray:
    """ReLU MLP; no activation on the final layer (callers add their own)."""
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i + 1 < len(params):
            x = jax.nn.relu(x)
    return x


def actor_apply(params: list, state: jnp.ndarray) -> jnp.ndarray:
    """Deterministic policy mu_theta: state -> action in [0,1]^m (sigmoid head)."""
    return jax.nn.sigmoid(mlp_apply(params, state))


def critic_apply(params: list, state: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    """Q_phi(s, a) -> scalar (last axis squeezed)."""
    x = jnp.concatenate([state, action], axis=-1)
    return jnp.squeeze(mlp_apply(params, x), axis=-1)


# ---------------------------------------------------------------------------
# DDPG learner state + update
# ---------------------------------------------------------------------------

class DDPGConfig(NamedTuple):
    state_dim: int
    action_dim: int
    hidden: tuple = (64, 64)
    actor_lr: float = 1e-3
    critic_lr: float = 2e-3
    gamma: float = 0.9          # tuning steps are near-bandit; short horizon
    tau: float = 0.02           # Polyak coefficient for target networks
    updates_per_step: int = 96  # gradient steps per environment step (Table III)
    batch_size: int = 16

    @classmethod
    def for_space(cls, state_dim: int, space, **overrides) -> "DDPGConfig":
        """Size the learner from a ``ParamSpace``: the actor head emits one
        coordinate per static parameter (paper §II-C-1), so ``action_dim`` is
        ``space.dim`` — never a hand-maintained constant. The hidden trunk is
        dimensionality-independent (the paper's single small MLP), which keeps
        the fused learn step's cost flat as spaces grow from 2-D to 8-D.
        """
        return cls(state_dim=state_dim, action_dim=space.dim, **overrides)

    @classmethod
    def for_env(cls, env, **overrides) -> "DDPGConfig":
        """Derive state/action dims from a ``TuningEnvironment``: the state is
        its metric vector, the action its ``param_space``."""
        return cls.for_space(env.state_dim, env.param_space, **overrides)


class DDPGState(NamedTuple):
    actor: Any
    critic: Any
    actor_targ: Any
    critic_targ: Any
    actor_opt: Any
    critic_opt: Any
    step: jnp.ndarray


def _init_state(key: jax.Array, cfg: DDPGConfig,
                actor_tx: optim.GradientTransformation,
                critic_tx: optim.GradientTransformation) -> DDPGState:
    """Fresh learner state for one session; target nets start as copies."""
    ka, kc = jax.random.split(key)
    actor = mlp_init(ka, (cfg.state_dim, *cfg.hidden, cfg.action_dim))
    critic = mlp_init(kc, (cfg.state_dim + cfg.action_dim, *cfg.hidden, 1))
    return DDPGState(
        actor=actor,
        critic=critic,
        actor_targ=jax.tree_util.tree_map(jnp.copy, actor),
        critic_targ=jax.tree_util.tree_map(jnp.copy, critic),
        actor_opt=actor_tx.init(actor),
        critic_opt=critic_tx.init(critic),
        step=jnp.zeros((), jnp.int32),
    )


def ddpg_init(key: jax.Array, cfg: DDPGConfig) -> tuple:
    """Returns (DDPGState, (actor_tx, critic_tx))."""
    actor_tx = optim.adam(cfg.actor_lr)
    critic_tx = optim.adam(cfg.critic_lr)
    return _init_state(key, cfg, actor_tx, critic_tx), (actor_tx, critic_tx)


def _polyak(target, online, tau: float):
    return jax.tree_util.tree_map(lambda t, o: (1 - tau) * t + tau * o, target, online)


def _ddpg_step(
    state: DDPGState,
    batch: tuple,  # (s, a, r, s2) each [B, ...] float32
    cfg: DDPGConfig,
    actor_tx: optim.GradientTransformation,
    critic_tx: optim.GradientTransformation,
) -> tuple:
    """One critic + one actor gradient step + Polyak. Returns (state, metrics).

    Pure (un-jitted) body shared by ``ddpg_update`` (one jitted call per
    minibatch), ``ddpg_learn_scan`` (the whole inner loop fused into one
    ``lax.scan``) and the vmapped fleet learner.
    """
    s, a, r, s2 = batch

    # --- critic: Bellman regression against the frozen targets -------------
    a2 = actor_apply(state.actor_targ, s2)
    q_targ = r + cfg.gamma * critic_apply(state.critic_targ, s2, a2)
    q_targ = jax.lax.stop_gradient(q_targ)

    def critic_loss_fn(critic):
        q = critic_apply(critic, s, a)
        return jnp.mean(jnp.square(q - q_targ))

    critic_loss, critic_grads = jax.value_and_grad(critic_loss_fn)(state.critic)
    c_updates, critic_opt = critic_tx.update(critic_grads, state.critic_opt, state.critic)
    critic = optim.apply_updates(state.critic, c_updates)

    # --- actor: ascend Q_phi(s, mu_theta(s)) with the critic frozen --------
    def actor_loss_fn(actor):
        return -jnp.mean(critic_apply(critic, s, actor_apply(actor, s)))

    actor_loss, actor_grads = jax.value_and_grad(actor_loss_fn)(state.actor)
    a_updates, actor_opt = actor_tx.update(actor_grads, state.actor_opt, state.actor)
    actor = optim.apply_updates(state.actor, a_updates)

    new_state = DDPGState(
        actor=actor,
        critic=critic,
        actor_targ=_polyak(state.actor_targ, actor, cfg.tau),
        critic_targ=_polyak(state.critic_targ, critic, cfg.tau),
        actor_opt=actor_opt,
        critic_opt=critic_opt,
        step=state.step + 1,
    )
    metrics = {"critic_loss": critic_loss, "actor_loss": actor_loss,
               "q_mean": jnp.mean(critic_apply(critic, s, a))}
    return new_state, metrics


ddpg_update = functools.partial(
    jax.jit, static_argnames=("cfg", "actor_tx", "critic_tx")
)(_ddpg_step)


# ---------------------------------------------------------------------------
# Fused learner: the whole updates_per_step inner loop as one XLA program
# ---------------------------------------------------------------------------

def sample_minibatch_indices(key: jax.Array, num_updates: int, batch_size: int,
                             size: jnp.ndarray) -> jnp.ndarray:
    """[num_updates, batch_size] uniform-with-replacement indices in [0, size).

    On-device replacement for the host-side ``rng.integers`` loop; ``size`` is
    a dynamic operand so a growing buffer never retriggers compilation.

    Precondition: ``size >= 1``. An empty buffer has nothing to sample, and
    there is deliberately no silent clamp here (an earlier ``maximum(size, 1)``
    made an empty buffer sample slot 0 — all-zero garbage transitions — with
    no error). The host entry points (``ddpg_learn_scan``,
    ``fleet_learn_scan``) raise on a concrete ``size == 0``; in-graph callers
    must guarantee the invariant structurally, as the episode engine does by
    writing the step's transition to the FIFO *before* learning
    (``core.episode``).
    """
    return jax.random.randint(key, (num_updates, batch_size), 0, size)


def gather_minibatches(data: tuple, idx: jnp.ndarray) -> tuple:
    """Gather every update's minibatch in ONE take per buffer array.

    ``idx`` is ``[num_updates, batch_size]``; returns (s, a, r, s2) with
    shape ``[num_updates, batch_size, ...]``. Flattening the index matrix
    turns ``num_updates`` (96) per-update gathers into a single contiguous
    pass over the replay storage per environment step. Gathers are exact, so
    the batches — and everything the learner computes from them — are
    bitwise-identical to the per-update ``s[ix]`` path (pinned by
    tests/test_ddpg_fused.py).
    """
    flat = idx.reshape(-1)
    return tuple(x[flat].reshape(idx.shape + x.shape[1:]) for x in data)


def _packable(state: "DDPGState", cfg: "DDPGConfig") -> bool:
    """True when the learner state fits the fused kernel's packed layout:
    two hidden layers (the paper's MLPs) and stock ``optim.adam`` transforms
    (state ``(ScaleByAdamState, ())``).

    CONTRACT: the kernel path derives its optimizer math entirely from
    ``cfg`` — ``cfg.actor_lr``/``cfg.critic_lr`` plus adam's default
    b1/b2/eps — because transforms are opaque closures that cannot be
    introspected. Every core construction path (``ddpg_init``,
    ``fleet_init``, the agents) builds the transforms from exactly those
    cfg fields, so the two are never out of sync there; callers that hand
    ``ddpg_learn_scan`` hand-built transforms disagreeing with ``cfg`` must
    not enable ``REPRO_KERNELS=pallas|interpret`` (the XLA path honors the
    transforms, the kernel path honors ``cfg``)."""
    if len(cfg.hidden) != 2:
        return False
    for opt in (state.actor_opt, state.critic_opt):
        if not (isinstance(opt, tuple) and len(opt) == 2
                and hasattr(opt[0], "mu") and hasattr(opt[0], "nu")
                and hasattr(opt[0], "count")):
            return False
    return True


def _learn_packed(state, batches, cfg, num_updates, mode="pallas"):
    """Route one session's pre-gathered inner loop through the fused-kernel
    dispatch (``kernels.ops.ddpg_inner_loop``), packing the learner state
    into the [P, P]-blocked VMEM layout and back. vmap-safe: under the fleet
    vmap the kernel's session grid batches automatically."""
    from repro.kernels import ddpg_fused as fused
    from repro.kernels import ops
    from repro.optim.transform import ScaleByAdamState

    dims = fused.packed_dims(cfg.state_dim, cfg.action_dim, cfg.hidden)
    a_adam, a_rest = state.actor_opt[0], state.actor_opt[1:]
    c_adam, c_rest = state.critic_opt[0], state.critic_opt[1:]
    packed = fused.pack_params(
        state.actor, state.critic, state.actor_targ, state.critic_targ,
        a_adam.mu, a_adam.nu, c_adam.mu, c_adam.nu,
        a_adam.count, c_adam.count, dims)
    kb = fused.pack_minibatches(batches, dims)
    packed = jax.tree_util.tree_map(lambda x: x[None], packed)
    kb = jax.tree_util.tree_map(lambda x: x[None], kb)
    packed, metrics = ops.ddpg_inner_loop(
        packed, kb, dims=dims, gamma=cfg.gamma, tau=cfg.tau,
        actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr, mode=mode)
    parts = fused.unpack_params(*jax.tree_util.tree_map(lambda x: x[0],
                                                        packed), dims)
    new_state = DDPGState(
        actor=parts["actor"],
        critic=parts["critic"],
        actor_targ=parts["actor_targ"],
        critic_targ=parts["critic_targ"],
        actor_opt=(ScaleByAdamState(count=parts["actor_count"],
                                    mu=parts["actor_mu"],
                                    nu=parts["actor_nu"]), *a_rest),
        critic_opt=(ScaleByAdamState(count=parts["critic_count"],
                                     mu=parts["critic_mu"],
                                     nu=parts["critic_nu"]), *c_rest),
        step=state.step + num_updates,
    )
    return new_state, jax.tree_util.tree_map(lambda x: x[0], metrics)


def _learn_scan(state, data, size, key, cfg, actor_tx, critic_tx, num_updates,
                kernel_mode=None):
    """Shared inner-loop body. ``kernel_mode`` ('pallas' / 'interpret' /
    ``None``) is a STATIC operand resolved by the host-level entry points
    (``ddpg_learn_scan``, ``fleet_learn_scan``, the episode-engine compile
    cache) — never read from the environment inside a trace, where a cached
    compilation would silently ignore a later mode change."""
    idx = sample_minibatch_indices(key, num_updates, cfg.batch_size, size)
    batches = gather_minibatches(data, idx)
    # f32 compute at gather: replay storage may be bf16 (opt-in compact
    # mode); minibatches are widened right after the gather so every
    # gradient step runs in float32. A same-dtype astype is the identity,
    # so the default f32 path is untouched (bitwise).
    batches = tuple(b.astype(jnp.float32) for b in batches)
    if kernel_mode is not None and _packable(state, cfg):
        return _learn_packed(state, batches, cfg, num_updates,
                             mode=kernel_mode)

    def body(st, batch):
        return _ddpg_step(st, batch, cfg, actor_tx, critic_tx)

    return jax.lax.scan(body, state, batches)


def _require_nonempty(size) -> None:
    """Host-path guard: raise on a concrete empty buffer instead of letting
    index sampling hit undefined maxval-0 behaviour (the silent-zero-index
    hazard). Traced sizes pass through — in-graph callers own the invariant
    (see ``sample_minibatch_indices``)."""
    if isinstance(size, jax.core.Tracer):
        return
    if int(np.min(np.asarray(size))) <= 0:
        raise ValueError(
            "cannot learn from an empty replay buffer: minibatch sampling "
            "needs size >= 1 valid rows (observe at least one transition "
            "before calling the fused learner)")


_ddpg_learn_scan_jit = functools.partial(
    jax.jit, static_argnames=("cfg", "actor_tx", "critic_tx", "num_updates",
                              "kernel_mode")
)(_learn_scan)


def ddpg_learn_scan(
    state: DDPGState,
    data: tuple,       # (s, a, r, s2), each [capacity, ...] — full buffer storage
    size: jnp.ndarray,  # number of valid rows in ``data`` (dynamic)
    key: jax.Array,
    cfg: DDPGConfig,
    actor_tx: optim.GradientTransformation,
    critic_tx: optim.GradientTransformation,
    num_updates: int,
) -> tuple:
    """``num_updates`` minibatch gradient steps as ONE jitted program.

    Equivalent to sampling ``num_updates`` batches with
    ``sample_minibatch_indices(key, ...)`` and applying ``ddpg_update`` to each
    in sequence, but with minibatch sampling on-device, all ``num_updates x
    batch_size`` rows gathered in one pre-pass (``gather_minibatches``), and
    the whole inner loop fused into a single ``jax.lax.scan`` — one dispatch
    per ``learn()`` instead of ``updates_per_step`` (96, Table III) dispatches
    plus a host round-trip per minibatch. Under ``REPRO_KERNELS=pallas`` /
    ``interpret`` the loop runs as the fused Pallas kernel instead
    (``kernels/ddpg_fused.py``) — on that path the optimizer hyperparameters
    come from ``cfg``, not from introspecting ``actor_tx``/``critic_tx``
    (see ``_packable``), matching how every core caller builds them.
    Raises ``ValueError`` on an empty buffer. Returns (state, metrics
    stacked over updates).
    """
    from repro.kernels import ops

    _require_nonempty(size)
    return _ddpg_learn_scan_jit(state, data, size, key, cfg, actor_tx,
                                critic_tx, num_updates,
                                kernel_mode=ops.ddpg_kernel_mode())


# ---------------------------------------------------------------------------
# Fleet: N independent DDPG learners batched over a leading session axis
# ---------------------------------------------------------------------------

def fleet_init(keys: jax.Array, cfg: DDPGConfig) -> tuple:
    """Initialize N independent learners from ``keys`` [N, key] in one shot.

    Returns (stacked DDPGState with leading session axis, (actor_tx,
    critic_tx)). Session i's parameters are identical to
    ``ddpg_init(keys[i], cfg)`` — JAX RNG is deterministic per key, so a fleet
    of one reproduces the single-agent init exactly.
    """
    actor_tx = optim.adam(cfg.actor_lr)
    critic_tx = optim.adam(cfg.critic_lr)
    init_one = functools.partial(_init_state, cfg=cfg, actor_tx=actor_tx,
                                 critic_tx=critic_tx)
    return jax.vmap(init_one)(keys), (actor_tx, critic_tx)


@jax.jit
def fleet_act(actors, states: jnp.ndarray) -> jnp.ndarray:
    """Deterministic policy actions for all sessions: [N, k] -> [N, m]."""
    return jax.vmap(actor_apply)(actors, states)


@functools.partial(
    jax.jit, static_argnames=("cfg", "actor_tx", "critic_tx", "num_updates",
                              "kernel_mode"))
def _fleet_learn_scan_jit(states, data, sizes, keys, cfg, actor_tx,
                          critic_tx, num_updates, kernel_mode):
    f = functools.partial(_learn_scan, cfg=cfg, actor_tx=actor_tx,
                          critic_tx=critic_tx, num_updates=num_updates,
                          kernel_mode=kernel_mode)
    return jax.vmap(f)(states, data, sizes, keys)


def fleet_learn_scan(
    states: DDPGState,  # stacked over sessions
    data: tuple,        # (s, a, r, s2), each [N, capacity, ...]
    sizes: jnp.ndarray,  # [N]
    keys: jax.Array,     # [N, key]
    cfg: DDPGConfig,
    actor_tx: optim.GradientTransformation,
    critic_tx: optim.GradientTransformation,
    num_updates: int,
) -> tuple:
    """vmap of ``ddpg_learn_scan`` over the session axis: the entire fleet's
    ``N x num_updates`` gradient steps are one XLA computation (or, under
    ``REPRO_KERNELS=pallas``/``interpret``, one Pallas kernel launch whose
    grid is the session axis). Raises ``ValueError`` if any session's buffer
    is empty (the fleet steps in lockstep, so sizes agree)."""
    from repro.kernels import ops

    _require_nonempty(sizes)
    return _fleet_learn_scan_jit(states, data, sizes, keys, cfg, actor_tx,
                                 critic_tx, num_updates,
                                 kernel_mode=ops.ddpg_kernel_mode())


# ---------------------------------------------------------------------------
# Exploration noise
# ---------------------------------------------------------------------------

class OUNoise:
    """Ornstein-Uhlenbeck process (standard DDPG exploration), with linear
    sigma decay so late tuning steps fine-tune rather than explore (§III-E:
    'Magpie ... then uses additional tuning steps for parameter fine-tuning')."""

    def __init__(self, dim: int, sigma: float = 0.40, theta: float = 0.15,
                 sigma_min: float = 0.05, decay_steps: int = 50, seed: int = 0):
        self.dim = dim
        self.sigma0 = sigma
        self.sigma_min = sigma_min
        self.theta = theta
        self.decay_steps = decay_steps
        self._rng = np.random.default_rng(seed)
        self._x = np.zeros(dim, np.float32)
        self._t = 0

    def reset(self) -> None:
        self._x[...] = 0.0

    def __call__(self) -> np.ndarray:
        frac = min(1.0, self._t / max(1, self.decay_steps))
        sigma = self.sigma0 + frac * (self.sigma_min - self.sigma0)
        self._x += -self.theta * self._x + sigma * self._rng.standard_normal(self.dim)
        self._t += 1
        return self._x.astype(np.float32)

    def state_dict(self) -> dict:
        return {"x": self._x.copy(), "t": self._t,
                "bitgen": self._rng.bit_generator.state}

    def load_state_dict(self, d: dict) -> None:
        self._x[...] = d["x"]
        self._t = int(d["t"])
        self._rng.bit_generator.state = d["bitgen"]
