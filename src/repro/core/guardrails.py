"""Safe rollout: shadow/canary deployment guardrails with rollback.

The paper's whole premise — static parameters, every apply costs a restart —
is exactly why a raw RL tuner cannot be pointed at a production file system.
This module adds the deployment layer that makes the tuner's recommendations
*adoptable*: a ``DeploymentPolicy`` evaluated INSIDE the fused episode scan
(``core.episode``), so every proposal is scored in shadow before the live
configuration moves.

Per guarded step:

  shadow    the actor's proposal is scored with an ``eval_run=True`` probe on
            the current env state — the ``evaluate_config`` semantics (lower
            measurement variance), and the probed state is DISCARDED, so the
            live system never runs the proposal. The learner trains on this
            shadow transition, so the policy keeps improving even while the
            gate holds the live config still.
  gate      promotion needs (a) shadow gain >= ``min_gain`` relative to the
            live objective and (b) the proposal's restart cost to fit the
            remaining ``max_restart_seconds`` budget (``gate_decision``).
  canary    if the gate passes, the proposal is committed to the live system
            and the displaced incumbent becomes the rollback fallback; the
            regression watch (``rollback_window`` steps) arms.
  rollback  while the watch is armed, a live objective more than
            ``rollback_threshold`` below the pre-promotion anchor restores
            the fallback configuration immediately (``rollback_decision``).
            Rollbacks are always allowed — the budget gates promotions, never
            the path back to a known-good config; the fallback re-apply's
            restart is charged to the budget at the next committed step.

All of it is branch-free ``jnp.where`` selection over three env-step islands
(shadow probe, canary branch, keep branch), so the guarded body stays
scan/vmap/shard_map-safe and rides the same chunked fleet runtime. The three
islands split the SAME env key (the committed branch's advanced key carries
forward), so shadow and live draws are correlated within a step — by design:
the shadow score measures the config, not a fresh noise draw.

Guardrails default OFF. ``policy=None`` never touches this module: the
episode builder compiles the exact pre-guardrail program (same cache key,
same program object), pinned bitwise by tests/test_guardrails.py.

Decision trail: every step emits a uint8 event bitmask and the shadow
objective into the compact trace (``GuardedEpisodeTrace``), from which
``guardrail_counters`` derives the per-session OTEL-ish counters surfaced by
``Tuner``/``FleetTuner``/``FleetService.advance()`` stats.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_mapping import ParamSpace, jax_coord_maps
from repro.core.ddpg import DDPGConfig, actor_apply, _learn_scan

# guard_events bitmask (uint8): one trace byte records the whole decision
EVENT_PROMOTED = 1        # proposal passed the gate and was committed
EVENT_REJECTED_GAIN = 2   # shadow gain below min_gain
EVENT_REJECTED_BUDGET = 4  # restart budget could not absorb the apply
EVENT_ROLLBACK = 8        # live regression -> incumbent restored


class DeploymentPolicy(NamedTuple):
    """Static promotion/rollback policy, baked into the compiled episode.

    Hashable on purpose: the policy joins the episode program's cache key,
    so two tuners sharing a policy share one executable and ``policy=None``
    compiles the exact unguarded program.

    ``min_gain``            minimum relative shadow gain vs the live
                            objective for a proposal to be promoted.
    ``max_restart_seconds`` total committed restart downtime the guarded
                            session may spend; a promotion whose restart
                            would exceed the remainder is rejected.
    ``rollback_window``     steps after a promotion during which a live
                            regression restores the incumbent (0 disables
                            rollback).
    ``rollback_threshold``  relative drop vs the pre-promotion anchor that
                            counts as a regression.
    """

    min_gain: float = 0.0
    max_restart_seconds: float = float("inf")
    rollback_window: int = 0
    rollback_threshold: float = 0.05


class GuardState(NamedTuple):
    """Per-session guard carry (numpy between chunks, like all fleet state).

    ``live_action`` is the unit action of the configuration the live system
    currently runs; ``fallback_action``/``fallback_obj`` anchor the rollback
    target (the incumbent displaced by the last promotion and its objective
    at promotion time). ``budget_spent`` accumulates every committed restart
    second; ``watch_left`` counts the remaining regression-watch steps."""

    live_action: Any       # [m] f32 unit action
    fallback_action: Any   # [m] f32 unit action
    fallback_obj: Any      # f32 scalar
    budget_spent: Any      # f32 scalar
    watch_left: Any        # i32 scalar
    promotions: Any        # i32 scalar, lifetime count
    rollbacks: Any         # i32 scalar, lifetime count


class GuardedCarry(NamedTuple):
    base: Any    # core.episode.EpisodeCarry
    guard: GuardState


class GuardedEpisodeTrace(NamedTuple):
    """``EpisodeTrace`` plus the shadow-vs-live decision trail.

    Field names (not positions) are the contract: the first five fields
    mirror ``EpisodeTrace`` exactly, so every trace consumer
    (``replay_compact_trace``, the tuner history reconstruction) reads a
    guarded trace unchanged. ``guard_events`` is the uint8 bitmask above;
    ``shadow_objectives`` the f32 shadow score of each step's proposal."""

    action_idx: Any
    metrics: Any
    rewards: Any
    objectives: Any
    restarts: Any
    guard_events: Any       # [T] uint8
    shadow_objectives: Any  # [T] f32


# ---------------------------------------------------------------------------
# Pure decision functions (numpy AND jnp operands — the property tests run
# them on host scalars; the scan body runs them on traced arrays)
# ---------------------------------------------------------------------------

def gate_decision(shadow_gain, restart_cost, budget_spent,
                  policy: DeploymentPolicy):
    """Canary promotion gate. Returns ``(promote, gain_ok, budget_ok)``.

    Monotone in both thresholds: lowering ``min_gain`` or raising
    ``max_restart_seconds`` can only turn rejections into promotions on the
    same inputs, never the reverse (pinned by the hypothesis suite)."""
    gain_ok = shadow_gain >= policy.min_gain
    budget_ok = (budget_spent + restart_cost) <= policy.max_restart_seconds
    return gain_ok & budget_ok, gain_ok, budget_ok


def rollback_decision(live_obj, anchor_obj, watch_left,
                      policy: DeploymentPolicy):
    """Regression check against the pre-promotion anchor objective.

    Fires only while the watch is armed (``watch_left > 0``) and the live
    objective sits more than ``rollback_threshold`` (relative) below the
    anchor. Monotone in the threshold: raising it can only suppress
    rollbacks."""
    rel_drop = (live_obj - anchor_obj) / jnp.maximum(
        anchor_obj, jnp.float32(1e-6))
    return (watch_left > 0) & (rel_drop < -jnp.float32(
        policy.rollback_threshold))


# ---------------------------------------------------------------------------
# Guard-state construction
# ---------------------------------------------------------------------------

def init_guard_state(space: ParamSpace, live_config: dict,
                     live_objective: float) -> GuardState:
    """Guard state for a session whose live system runs ``live_config``."""
    a = np.asarray(space.to_action(live_config), np.float32)
    return GuardState(
        live_action=a, fallback_action=a.copy(),
        fallback_obj=np.float32(live_objective),
        budget_spent=np.float32(0.0), watch_left=np.int32(0),
        promotions=np.int32(0), rollbacks=np.int32(0))


def init_fleet_guard_state(space: ParamSpace, live_configs, live_objectives
                           ) -> GuardState:
    """Stacked [N, ...] guard state for a fleet (host numpy leaves)."""
    singles = [init_guard_state(space, c, o)
               for c, o in zip(live_configs, live_objectives)]
    return jax.tree_util.tree_map(lambda *xs: np.stack(xs), *singles)


# ---------------------------------------------------------------------------
# The guarded episode step (the scan body `core.episode` builds when a
# policy is set)
# ---------------------------------------------------------------------------

def build_guarded_step(step_fn, space: ParamSpace, cfg: DDPGConfig, actor_tx,
                       critic_tx, learn: bool, num_updates: int, kernel_mode,
                       policy: DeploymentPolicy):
    """one_step(params, w_vec, lo, span, GuardedCarry, x) ->
    (GuardedCarry, GuardedEpisodeTrace-row).

    Mirrors ``core.episode._build_episode``'s body (same fusion islands,
    same f32 fixed-order arithmetic) with the shadow/gate/canary/rollback
    layer threaded around the env transition. The replay buffer stores the
    SHADOW transition (proposal, shadow reward/next-state): learning follows
    what the tuner explored; the trace follows what the live system ran."""
    from repro.core.episode import (  # lazy: episode imports us lazily too
        BufferState, EpisodeCarry, _encode_restart)
    from repro.envs.base import barriered_step, fusion_barrier

    do_updates = learn and num_updates > 0
    coord_maps = jax_coord_maps(space)
    idx_dtype = space.index_dtype()

    def norm_obj(metrics_vec, w_vec, lo, span):
        # normalization + serial f32 fold, bit-aligned with the unguarded
        # body and Scalarizer.objective (zero-weight terms are exact no-ops)
        norm = jnp.where(span > 0,
                         jnp.clip((metrics_vec - lo) / span, 0.0, 1.0), 0.0)
        obj = jnp.float32(0.0)
        for j in range(norm.shape[0]):
            obj = obj + w_vec[j] * norm[j]
        return norm, obj

    def one_step(params, w_vec, lo, span, carry, x):
        base, guard = carry.base, carry.guard
        use_warmup, warmup_a, noise = x

        # propose: identical act to the unguarded body
        actor, state_vec = fusion_barrier(
            (base.ddpg.actor, base.state_vec))
        policy_a = fusion_barrier(actor_apply(actor, state_vec))
        explored = jnp.clip(policy_a + noise, 0.0, 1.0)
        proposal = jnp.where(use_warmup, jnp.clip(warmup_a, 0.0, 1.0),
                             explored)

        # shadow: evaluate_config semantics in-graph — an eval_run probe on
        # the CURRENT state; the probed state is discarded (live system
        # untouched)
        _, shadow_metrics, _ = barriered_step(
            step_fn, params, base.env_state, proposal, True)
        shadow_norm, shadow_obj = norm_obj(shadow_metrics, w_vec, lo, span)
        shadow_gain = (shadow_obj - base.objective) / jnp.maximum(
            base.objective, jnp.float32(1e-6))

        # canary and keep branches both execute (branch-free vmap-safe
        # select); both split the same env key, the committed branch's
        # advanced state carries forward
        p_state, p_metrics, p_restart = barriered_step(
            step_fn, params, base.env_state, proposal, False)
        k_state, k_metrics, k_restart = barriered_step(
            step_fn, params, base.env_state, guard.live_action, False)

        promote, gain_ok, budget_ok = gate_decision(
            shadow_gain, p_restart, guard.budget_spent, policy)

        def sel(p, k):
            return jnp.where(promote, p, k)

        env_state = jax.tree_util.tree_map(sel, p_state, k_state)
        committed = sel(proposal, guard.live_action)
        metrics_vec = sel(p_metrics, k_metrics)
        restart = sel(p_restart, k_restart)
        norm, obj = norm_obj(metrics_vec, w_vec, lo, span)
        reward = (obj - base.objective) / jnp.maximum(
            base.objective, jnp.float32(1e-6))

        # promotion bookkeeping: the displaced incumbent becomes the
        # rollback anchor; every committed restart draws on the budget
        # (the keep branch's restart is 0 unless it re-applies a rolled-back
        # fallback — that re-apply is charged here, one step after the
        # rollback decision)
        fallback_action = sel(guard.live_action, guard.fallback_action)
        fallback_obj = sel(base.objective, guard.fallback_obj)
        watch = jnp.where(promote, jnp.int32(policy.rollback_window),
                          jnp.maximum(guard.watch_left - 1, 0))
        budget = guard.budget_spent + restart

        rollback = rollback_decision(obj, fallback_obj, watch, policy)
        live_action = jnp.where(rollback, fallback_action, committed)
        watch = jnp.where(rollback, jnp.int32(0), watch)

        event = (promote.astype(jnp.uint8) * EVENT_PROMOTED
                 + jnp.logical_not(gain_ok).astype(jnp.uint8)
                 * EVENT_REJECTED_GAIN
                 + jnp.logical_not(budget_ok).astype(jnp.uint8)
                 * EVENT_REJECTED_BUDGET
                 + rollback.astype(jnp.uint8) * EVENT_ROLLBACK)
        guard = GuardState(
            live_action=live_action, fallback_action=fallback_action,
            fallback_obj=fallback_obj, budget_spent=budget,
            watch_left=watch,
            promotions=guard.promotions + promote.astype(jnp.int32),
            rollbacks=guard.rollbacks + rollback.astype(jnp.int32))

        # compact trace: the knob indices of the COMMITTED config (what the
        # live system ran; decode-aligned with the env dynamics)
        action_idx = jnp.stack(
            [coord_maps[j](committed[j])["idx"] for j in range(space.dim)]
        ).astype(idx_dtype)

        if learn:  # shadow transition: the proposal and its shadow outcome
            buf = base.buffer
            capacity = buf.s.shape[0]
            i = buf.next_slot
            buf = BufferState(
                s=buf.s.at[i].set(base.state_vec.astype(buf.s.dtype)),
                a=buf.a.at[i].set(proposal.astype(buf.a.dtype)),
                r=buf.r.at[i].set(shadow_gain.astype(buf.r.dtype)),
                s2=buf.s2.at[i].set(shadow_norm.astype(buf.s2.dtype)),
                next_slot=(i + 1) % capacity,
                size=jnp.minimum(buf.size + 1, capacity))
        else:
            buf = base.buffer
        if do_updates:
            learn_key, k = jax.random.split(base.learn_key)
            learn_in = fusion_barrier((base.ddpg, buf, k))
            ddpg, _ = fusion_barrier(_learn_scan(
                learn_in[0],
                (learn_in[1].s, learn_in[1].a, learn_in[1].r,
                 learn_in[1].s2),
                learn_in[1].size, learn_in[2],
                cfg, actor_tx, critic_tx, num_updates,
                kernel_mode=kernel_mode))
        else:
            learn_key, ddpg = base.learn_key, base.ddpg

        carry = GuardedCarry(
            base=EpisodeCarry(env_state, ddpg, buf, learn_key, norm, obj),
            guard=guard)
        return carry, GuardedEpisodeTrace(
            action_idx, metrics_vec, reward, obj, _encode_restart(restart),
            event, shadow_obj)

    return one_step


# ---------------------------------------------------------------------------
# Host-side counter export (OTEL-ish, derived from the compact trace)
# ---------------------------------------------------------------------------

COUNTER_KEYS = ("proposals", "promotions", "rejected_min_gain",
                "rejected_budget", "rollbacks", "restart_seconds")


def guardrail_counters(events: np.ndarray,
                       restarts: np.ndarray = None) -> dict:
    """Structured counters from a session's event trace ([T] uint8).

    ``restarts`` (decoded f32 seconds, same length) adds the committed
    guarded downtime. Pure accounting — safe to accumulate across runs by
    summing dicts (``merge_counters``)."""
    ev = np.asarray(events)
    d = {
        "proposals": int(ev.size),
        "promotions": int(((ev & EVENT_PROMOTED) != 0).sum()),
        "rejected_min_gain": int(((ev & EVENT_REJECTED_GAIN) != 0).sum()),
        "rejected_budget": int(((ev & EVENT_REJECTED_BUDGET) != 0).sum()),
        "rollbacks": int(((ev & EVENT_ROLLBACK) != 0).sum()),
        "restart_seconds": 0.0,
    }
    if restarts is not None:
        d["restart_seconds"] = float(np.asarray(restarts,
                                                np.float64).sum())
    return d


def merge_counters(a: dict, b: dict) -> dict:
    """Sum two counter dicts (missing keys count as zero)."""
    return {k: a.get(k, 0) + b.get(k, 0)
            for k in dict.fromkeys((*a, *b))}


def guardrail_stats(policy: DeploymentPolicy, guard: GuardState,
                    counters: dict, space: ParamSpace = None) -> dict:
    """One session's exported guardrail record: policy + cumulative counters
    + the authoritative guard-state totals (in-graph f32/i32 accumulators,
    cross-checked against the trace-derived counters by the tests)."""
    spent = float(np.float32(guard.budget_spent)) if guard is not None else 0.0
    d = dict(counters)
    d.update(
        policy=dict(policy._asdict()),
        restart_budget_spent=spent,
        budget_remaining=max(0.0, float(policy.max_restart_seconds) - spent),
        watch_left=int(guard.watch_left) if guard is not None else 0,
        promotions_total=int(guard.promotions) if guard is not None else 0,
        rollbacks_total=int(guard.rollbacks) if guard is not None else 0)
    if space is not None and guard is not None:
        d["live_config"] = space.to_config(
            np.asarray(guard.live_action, np.float32))
    return d


@functools.lru_cache(maxsize=None)
def _empty_counters() -> tuple:
    return tuple((k, 0 if k != "restart_seconds" else 0.0)
                 for k in COUNTER_KEYS)


def empty_counters() -> dict:
    return dict(_empty_counters())
