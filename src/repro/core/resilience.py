"""Self-healing episode engine: in-scan divergence quarantine + chunk retry.

Guardrails (PR 7) protect the *tuned system* from bad configurations; this
module protects the *tuner* from its own failures, at two layers:

In-graph (``ResiliencePolicy``): the resilient scan body keeps a last-good
snapshot of the learner (params + targets + opt state) in the carry, detects
non-finite params/losses/metrics after each learn scan, and branch-free
(``jnp.where``) resets a diverged session to the snapshot. Every step emits a
uint8 ``health_events`` bitmask (NONFINITE / RESET / DEGRADED) into the
compact trace. Once a session has spent ``max_resets`` resets (or crossed
``degrade_after`` total non-finite detections), it DEGRADES to
frozen-incumbent mode: its learner pins to the snapshot so the env keeps
serving the incumbent config while cellmates keep training — and
shared-replay cells mask a corrupted or degraded member's contributions
(FIFO writes and the parameter-averaging mean), so one NaN cannot poison a
merged window.

Host supervisor (``ChunkSupervisor``): ``core.episode.stream_chunks`` gains
retry-with-exponential-backoff on transient chunk failures and a wall-clock
watchdog per chunk. Host numpy between chunks is the source of truth, so a
failed chunk re-stages and re-runs deterministically — retries are bitwise
invisible on success. After ``max_retries`` the chunk either raises
``ChunkFailure`` (``on_failure="raise"``) or is skipped so the fleet
survives (``on_failure="skip"`` — ``FleetService.advance`` quarantines the
chunk's sessions through the existing leave path, bit-neutral for
survivors).

Resilience defaults OFF. ``resilience=None`` never touches this module: the
episode builder compiles the exact pre-resilience program (same cache key,
same program object), pinned bitwise by tests/test_resilience.py — the same
off-by-executable-identity precedent as guardrails and sharing.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_mapping import ParamSpace, jax_coord_maps
from repro.core.ddpg import DDPGConfig, actor_apply, _learn_scan

# health_events bitmask (uint8): one trace byte records the step's health
EVENT_NONFINITE = 1  # non-finite detected in metrics/losses/params this step
EVENT_RESET = 2      # learner restored to the last-good snapshot
EVENT_DEGRADED = 4   # session is in frozen-incumbent (degraded) mode


class ResiliencePolicy(NamedTuple):
    """Static divergence-recovery policy, baked into the compiled episode.

    Hashable on purpose: the policy joins the episode program's cache key,
    so ``resilience=None`` compiles the exact pre-resilience program.

    ``nonfinite_check``  master switch; ``False`` normalizes the whole
                         policy to ``None`` (fully off).
    ``max_resets``       snapshot resets a session may spend before the next
                         divergence degrades it (in-graph counter, can never
                         be exceeded).
    ``snapshot_every``   cadence (steps) of the last-good snapshot refresh —
                         a reset rolls the learner back at most this many
                         steps.
    ``degrade_after``    optional cap on TOTAL non-finite detections; once
                         crossed the session degrades even with resets left
                         (``None`` = only the exhausted-resets path).
    """

    nonfinite_check: bool = True
    max_resets: int = 3
    snapshot_every: int = 1
    degrade_after: Optional[int] = None


def normalize_resilience(policy) -> Optional[ResiliencePolicy]:
    """Canonicalize a resilience policy; fully-off collapses to ``None``.

    ``None`` stays ``None``; ``nonfinite_check=False`` IS off (no detector,
    nothing downstream can fire), so it returns ``None`` too — callers and
    the episode cache key therefore agree on one canonical off value."""
    if policy is None:
        return None
    p = ResiliencePolicy(*policy)
    if not p.nonfinite_check:
        return None
    if p.max_resets < 0:
        raise ValueError(f"max_resets must be >= 0, got {p.max_resets}")
    if p.snapshot_every < 1:
        raise ValueError(
            f"snapshot_every must be >= 1, got {p.snapshot_every}")
    if p.degrade_after is not None and p.degrade_after < 1:
        raise ValueError(
            f"degrade_after must be >= 1 (or None), got {p.degrade_after}")
    return ResiliencePolicy(True, int(p.max_resets), int(p.snapshot_every),
                            None if p.degrade_after is None
                            else int(p.degrade_after))


class HealthState(NamedTuple):
    """Per-session health carry (numpy between chunks, like all fleet state).

    ``snapshot`` is the last-good learner state (a full ``DDPGState``
    pytree); ``resets``/``nonfinite`` are lifetime i32 counters;
    ``degraded`` is the sticky frozen-incumbent flag; ``since_snap`` counts
    steps since the snapshot was last refreshed."""

    snapshot: Any     # DDPGState pytree (last-good params/targets/opt)
    resets: Any       # i32 scalar, lifetime count (never exceeds max_resets)
    nonfinite: Any    # i32 scalar, lifetime non-finite detections
    degraded: Any     # bool scalar, sticky
    since_snap: Any   # i32 scalar


class ResilientCarry(NamedTuple):
    base: Any    # core.episode.EpisodeCarry
    health: HealthState


class ResilientEpisodeTrace(NamedTuple):
    """``EpisodeTrace`` plus the per-step health byte.

    Field names (not positions) are the contract: the first five fields
    mirror ``EpisodeTrace`` exactly, so every trace consumer
    (``replay_compact_trace``, the tuner history reconstruction) reads a
    resilient trace unchanged."""

    action_idx: Any
    metrics: Any
    rewards: Any
    objectives: Any
    restarts: Any
    health_events: Any  # [T] uint8


# ---------------------------------------------------------------------------
# Pure decision function (numpy AND jnp operands — the property tests run it
# on host scalars; the scan body runs it on traced arrays)
# ---------------------------------------------------------------------------

def health_decision(bad, resets, nonfinite, degraded,
                    policy: ResiliencePolicy):
    """One step of the health state machine (branch-free).

    ``bad``/``degraded`` are bool arrays (np.bool\\_ or traced), ``resets``/
    ``nonfinite`` i32. Returns ``(do_reset, new_degraded, new_resets,
    new_nonfinite)``. Invariants (pinned by the property suite): resets
    never exceed ``max_resets``; ``degraded`` is sticky; a degraded step
    never resets."""
    nf = nonfinite + bad.astype(nonfinite.dtype)
    new_degraded = degraded | (bad & (resets >= policy.max_resets))
    if policy.degrade_after is not None:
        new_degraded = new_degraded | (nf >= policy.degrade_after)
    do_reset = bad & ~new_degraded
    return do_reset, new_degraded, resets + do_reset.astype(resets.dtype), nf


# ---------------------------------------------------------------------------
# Non-finite detection + branch-free pytree selection
# ---------------------------------------------------------------------------

def tree_nonfinite(tree):
    """Scalar bool: any non-finite value in any float leaf of ``tree``."""
    bad = jnp.zeros((), bool)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            bad = bad | jnp.any(~jnp.isfinite(leaf))
    return bad


def tree_nonfinite_rows(tree):
    """[rows] bool: per-row (leading axis) non-finite flag across all float
    leaves of a row-stacked pytree (the cell body's per-lane detector)."""
    bad = None
    for leaf in jax.tree_util.tree_leaves(tree):
        if not jnp.issubdtype(leaf.dtype, jnp.floating):
            continue
        row_bad = jnp.any(~jnp.isfinite(leaf.reshape(leaf.shape[0], -1)),
                          axis=1)
        bad = row_bad if bad is None else (bad | row_bad)
    if bad is None:
        raise ValueError("tree has no float leaves to health-check")
    return bad


def select_tree(flag, when_true, when_false):
    """Branch-free pytree select: ``flag`` is a scalar bool or a [rows] bool
    matching the leaves' leading axis; it is broadcast across each leaf's
    trailing dims (the ``jnp.where`` reset/freeze primitive)."""
    def sel(a, b):
        f = jnp.reshape(flag, jnp.shape(flag)
                        + (1,) * (a.ndim - jnp.ndim(flag)))
        return jnp.where(f, a, b)
    return jax.tree_util.tree_map(sel, when_true, when_false)


# ---------------------------------------------------------------------------
# Health-state construction
# ---------------------------------------------------------------------------

def _snapshot_tree(states, resilience):
    """The snapshot payload for a policy: the full learner state, or an
    EMPTY pytree for the every-step cadence — ``snapshot_every=1`` resolves
    the revert target in-graph as the step-entry state (see
    ``build_resilient_step``), so staging a second learner copy through the
    scan carry would be pure overhead."""
    if resilience is not None and resilience.snapshot_every == 1:
        return ()
    return jax.tree_util.tree_map(np.array, states)


def init_health_state(ddpg_state, resilience=None) -> HealthState:
    """Fresh health state for one session: the snapshot starts at the
    session's current learner state (host numpy leaves); pass the
    session's ``ResiliencePolicy`` so the every-step cadence can skip the
    snapshot copy entirely."""
    return HealthState(
        snapshot=_snapshot_tree(ddpg_state, resilience),
        resets=np.int32(0), nonfinite=np.int32(0),
        degraded=np.bool_(False), since_snap=np.int32(0))


def init_fleet_health_state(stacked_states, n: int,
                            resilience=None) -> HealthState:
    """Stacked [N, ...] health state for a fleet (host numpy leaves).
    ``stacked_states`` is the agent's session-stacked ``DDPGState``."""
    return HealthState(
        snapshot=_snapshot_tree(stacked_states, resilience),
        resets=np.zeros(n, np.int32), nonfinite=np.zeros(n, np.int32),
        degraded=np.zeros(n, bool), since_snap=np.zeros(n, np.int32))


# ---------------------------------------------------------------------------
# The resilient episode step (the scan body `core.episode` builds when a
# ResiliencePolicy is set)
# ---------------------------------------------------------------------------

def build_resilient_step(step_fn, space: ParamSpace, cfg: DDPGConfig,
                         actor_tx, critic_tx, learn: bool, num_updates: int,
                         kernel_mode, resilience: ResiliencePolicy,
                         obs_mask=None):
    """one_step(params, w_vec, lo, span, ResilientCarry, x) ->
    (ResilientCarry, ResilientEpisodeTrace-row).

    Mirrors ``core.episode._build_episode``'s body (same fusion islands,
    same f32 fixed-order arithmetic) with the health layer threaded around
    the FIFO write and the learn scan:

      * a corrupted observation (non-finite metric reading) is recorded in
        the trace as-is but never enters the carry (the next actor input and
        reward baseline keep the previous finite state) or the replay FIFO
        (the write scatters out of bounds and drops);
      * after the learn scan, non-finite params/losses/metrics trigger a
        branch-free reset to the last-good snapshot — or, past the policy's
        budgets, the sticky degraded freeze (learner pinned to the
        snapshot, env keeps serving the incumbent).
    """
    from repro.core.episode import (  # lazy: episode imports us lazily too
        BufferState, EpisodeCarry, _encode_restart)
    from repro.envs.base import barriered_step, fusion_barrier

    do_updates = learn and num_updates > 0
    coord_maps = jax_coord_maps(space)
    idx_dtype = space.index_dtype()
    mask = None if obs_mask is None else jnp.asarray(obs_mask, jnp.float32)
    rz = resilience

    def one_step(params, w_vec, lo, span, carry, x):
        base, health = carry.base, carry.health
        use_warmup, warmup_a, noise = x

        # act: identical to the unguarded body (the carry's state_vec is
        # finite by induction — see the sanitization below)
        actor, state_vec = fusion_barrier(
            (base.ddpg.actor, base.state_vec))
        obs = state_vec if mask is None else state_vec * mask
        policy = fusion_barrier(actor_apply(actor, obs))
        explored = jnp.clip(policy + noise, 0.0, 1.0)
        action = jnp.where(use_warmup, jnp.clip(warmup_a, 0.0, 1.0), explored)
        action_idx = jnp.stack(
            [coord_maps[j](action[j])["idx"] for j in range(space.dim)]
        ).astype(idx_dtype)

        env_state, metrics_vec, restart = barriered_step(
            step_fn, params, base.env_state, action, False)
        norm = jnp.where(span > 0,
                         jnp.clip((metrics_vec - lo) / span, 0.0, 1.0), 0.0)
        obj = jnp.float32(0.0)
        for j in range(norm.shape[0]):
            obj = obj + w_vec[j] * norm[j]
        reward = (obj - base.objective) / jnp.maximum(
            base.objective, jnp.float32(1e-6))

        # a corrupted reading poisons norm/obj/reward; the trace records the
        # raw observation, everything stateful below is masked on bad_obs
        bad_obs = jnp.any(~jnp.isfinite(metrics_vec))

        if learn:  # FIFO write, dropped entirely when the transition is bad
            buf = base.buffer
            capacity = buf.s.shape[0]
            i = buf.next_slot
            s_row = (base.state_vec if mask is None
                     else base.state_vec * mask)
            s2_row = norm if mask is None else norm * mask
            pos = jnp.where(bad_obs, capacity, i)  # OOB scatter -> drop
            buf = BufferState(
                s=buf.s.at[pos].set(s_row.astype(buf.s.dtype), mode="drop"),
                a=buf.a.at[pos].set(action.astype(buf.a.dtype), mode="drop"),
                r=buf.r.at[pos].set(reward.astype(buf.r.dtype), mode="drop"),
                s2=buf.s2.at[pos].set(s2_row.astype(buf.s2.dtype),
                                      mode="drop"),
                next_slot=jnp.where(bad_obs, i, (i + 1) % capacity),
                size=jnp.where(bad_obs, buf.size,
                               jnp.minimum(buf.size + 1, capacity)))
        else:
            buf = base.buffer
        if do_updates:
            # dropped writes mean the buffer CAN be empty here (a corrupted
            # step 0): clamp the sampled size so the gather stays in bounds
            # and mark the step bad-by-observation. No discard select is
            # needed — ``empty`` implies this step's write dropped
            # (``bad_obs``), ``bad`` always restores the snapshot below, and
            # an all-bad prefix keeps snapshot == base.ddpg by induction. A
            # select here would also pin ``base.ddpg`` live across the learn
            # scan and cost its in-place buffer reuse (~10% step time).
            empty = buf.size == 0
            learn_key, k = jax.random.split(base.learn_key)
            learn_in = fusion_barrier((base.ddpg, buf, k))
            ddpg_new, lmetrics = fusion_barrier(_learn_scan(
                learn_in[0],
                (learn_in[1].s, learn_in[1].a, learn_in[1].r,
                 learn_in[1].s2),
                jnp.maximum(learn_in[1].size, 1), learn_in[2],
                cfg, actor_tx, critic_tx, num_updates,
                kernel_mode=kernel_mode))
            bad_learn = ~empty & (tree_nonfinite(ddpg_new)
                                  | tree_nonfinite(lmetrics))
        else:
            learn_key, ddpg_new = base.learn_key, base.ddpg
            bad_learn = jnp.zeros((), bool)

        bad = bad_obs | bad_learn
        do_reset, degraded, resets, nf_total = health_decision(
            bad, health.resets, health.nonfinite, health.degraded, rz)
        # reset restores the snapshot; degraded pins to it permanently
        # (frozen incumbent — cellmates, and the env, keep running)
        if rz.snapshot_every == 1:
            # the every-step cadence admits an exact algebraic shortcut: a
            # refreshed snapshot is always next step's ENTRY state, so the
            # revert target IS ``base.ddpg`` and no snapshot tree needs to
            # ride the scan carry (``init_health_state`` stages an empty
            # pytree) — this removes a full learner-state copy per step
            # for the default policy, bitwise-identically
            ddpg_out = select_tree(do_reset | degraded, base.ddpg, ddpg_new)
            snapshot = health.snapshot              # () — no leaves
            refresh = ~bad & ~degraded
        else:
            ddpg_out = select_tree(do_reset | degraded, health.snapshot,
                                   ddpg_new)
            due = (health.since_snap + 1) >= rz.snapshot_every
            refresh = due & ~bad & ~degraded
            snapshot = select_tree(refresh, ddpg_out, health.snapshot)
        since = jnp.where(refresh, 0, health.since_snap + 1)

        event = (bad.astype(jnp.uint8) * EVENT_NONFINITE
                 + do_reset.astype(jnp.uint8) * EVENT_RESET
                 + degraded.astype(jnp.uint8) * EVENT_DEGRADED)

        carry = ResilientCarry(
            base=EpisodeCarry(
                env_state, ddpg_out, buf, learn_key,
                jnp.where(bad_obs, base.state_vec, norm),
                jnp.where(bad_obs, base.objective, obj)),
            health=HealthState(snapshot, resets, nf_total, degraded, since))
        return carry, ResilientEpisodeTrace(
            action_idx, metrics_vec, reward, obj, _encode_restart(restart),
            event)

    return one_step


# ---------------------------------------------------------------------------
# Host-side counter export (OTEL-ish, derived from the compact trace)
# ---------------------------------------------------------------------------

HEALTH_COUNTER_KEYS = ("steps", "nonfinite", "resets", "degraded_steps")


def health_counters(events: np.ndarray) -> dict:
    """Structured counters from a session's health trace ([T] uint8). Pure
    accounting — accumulate across runs with ``merge_health_counters``."""
    ev = np.asarray(events)
    return {
        "steps": int(ev.size),
        "nonfinite": int(((ev & EVENT_NONFINITE) != 0).sum()),
        "resets": int(((ev & EVENT_RESET) != 0).sum()),
        "degraded_steps": int(((ev & EVENT_DEGRADED) != 0).sum()),
    }


def merge_health_counters(a: dict, b: dict) -> dict:
    """Sum two counter dicts (missing keys count as zero)."""
    return {k: a.get(k, 0) + b.get(k, 0) for k in dict.fromkeys((*a, *b))}


def empty_health_counters() -> dict:
    return {k: 0 for k in HEALTH_COUNTER_KEYS}


def health_stats(policy: ResiliencePolicy, health: HealthState,
                 counters: dict) -> dict:
    """One session's exported health record: policy + cumulative counters +
    the authoritative in-graph totals (cross-checked against the
    trace-derived counters by the tests)."""
    d = dict(counters)
    d.update(
        policy=dict(policy._asdict()),
        resets_total=int(health.resets) if health is not None else 0,
        nonfinite_total=int(health.nonfinite) if health is not None else 0,
        degraded=bool(health.degraded) if health is not None else False)
    return d


# ---------------------------------------------------------------------------
# Host supervisor: chunk retry / backoff / watchdog configuration
# ---------------------------------------------------------------------------

class ChunkSupervisor(NamedTuple):
    """Host-side chunk supervision for ``core.episode.stream_chunks``.

    ``max_retries``        re-runs of a failed chunk before giving up. Host
                           numpy between chunks is the source of truth, so a
                           retry re-stages the SAME inputs and is bitwise
                           invisible on success.
    ``backoff_seconds``    initial retry delay; grows by
                           ``backoff_multiplier`` per attempt.
    ``watchdog_seconds``   per-chunk wall-clock budget; a chunk exceeding it
                           is counted as a ``watchdog_trips`` stall in the
                           run stats (an in-process chunk cannot be
                           preempted, so detection is post-hoc).
    ``on_failure``         ``"raise"`` propagates ``ChunkFailure`` after
                           retries are exhausted; ``"skip"`` leaves the
                           chunk's host state untouched and continues with
                           the remaining chunks (``FleetService`` then
                           quarantines the chunk's sessions via the leave
                           path).
    """

    max_retries: int = 2
    backoff_seconds: float = 0.05
    backoff_multiplier: float = 2.0
    watchdog_seconds: Optional[float] = None
    on_failure: str = "raise"


class ChunkFailure(RuntimeError):
    """A chunk kept failing after every supervised retry."""

    def __init__(self, chunk_index: int, attempts: int, cause: Exception):
        super().__init__(
            f"chunk {chunk_index} failed after {attempts} attempt(s): "
            f"{cause!r}")
        self.chunk_index = int(chunk_index)
        self.attempts = int(attempts)
        self.cause = cause


@functools.lru_cache(maxsize=None)
def _canon_supervisor(sup: ChunkSupervisor) -> ChunkSupervisor:
    if sup.max_retries < 0:
        raise ValueError(f"max_retries must be >= 0, got {sup.max_retries}")
    if sup.on_failure not in ("raise", "skip"):
        raise ValueError(
            f"on_failure must be 'raise' or 'skip', got {sup.on_failure!r}")
    return sup


def normalize_supervisor(sup) -> Optional[ChunkSupervisor]:
    """Validate a supervisor config; ``None`` stays ``None`` (unsupervised:
    the pristine pipeline with zero added host work)."""
    if sup is None:
        return None
    return _canon_supervisor(ChunkSupervisor(*sup))
