"""Whole-episode engine: the Fig. 1 loop as ONE compiled XLA program.

``core.tuner.Tuner`` steps the loop from Python: every tuning step crosses the
host boundary to act, apply the config, scalarize the reward, store the
transition and learn. This module fuses all of it — act → env step → reward
scalarization → buffer store → ``ddpg_learn_scan`` — into a single jitted
``lax.scan`` over the episode (``run_episode_scan``), and vmaps/shards the same
body over a fleet session axis (``run_fleet_episode_scan``), so a seeds ×
workloads × objectives grid runs as one device computation.

Equivalence contract (pinned by tests/test_episode.py):

  * the scan body performs, step for step, the float32 arithmetic of the
    host loop driving a ``ModelEnv`` adapter — same actor forward, same
    exploration values (warmup plans and OU noise are state-independent, so
    the host shell pre-consumes them from the agent's own numpy streams and
    feeds them in as scan inputs), same env ``step_fn`` on the same key
    chain, same normalization/objective fold (``core.scalarization`` does
    float32 fixed-order arithmetic for exactly this reason), same FIFO write
    and the same fused learner. The decision trajectory — every config, the
    restart accounting, the best configuration — is exactly equal between
    engines; float fields agree to within a few float32 ulps (XLA CPU
    compiles the two engines as different programs, and its context-dependent
    FMA/vectorization choices can move cancellation-prone values by single
    ulps — the per-phase fusion islands below keep it that tight).
  * both entry points mutate the adapter env, the agent and the replay
    buffer exactly as ``steps`` host-loop iterations would, so progressive
    tuning (paper Fig. 7) and the §III-E final recommendation work unchanged
    on top.

Only pure-model environments (``envs.base.ModelEnv``) can run here; real-DFS
or other external environments keep the host loop.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ddpg import DDPGConfig, actor_apply, _learn_scan
from repro.core.scalarization import metric_bounds, normalize_state


class BufferState(NamedTuple):
    """Device-side FIFO replay storage (the in-graph ``ReplayBuffer``)."""

    s: jnp.ndarray
    a: jnp.ndarray
    r: jnp.ndarray
    s2: jnp.ndarray
    next_slot: jnp.ndarray  # i32 write cursor
    size: jnp.ndarray       # i32 valid rows


class EpisodeCarry(NamedTuple):
    env_state: Any
    ddpg: Any
    buffer: BufferState
    learn_key: jax.Array
    state_vec: jnp.ndarray   # current normalized metric state [k]
    objective: jnp.ndarray   # scalarized objective of state_vec (f32)


class EpisodeTrace(NamedTuple):
    """Per-step outputs; leading axis = episode steps (then sessions, for the
    fleet). The host shell reconstructs ``StepRecord`` history from this."""

    actions: jnp.ndarray
    metrics: jnp.ndarray
    rewards: jnp.ndarray
    objectives: jnp.ndarray
    restarts: jnp.ndarray


def _build_episode(step_fn, cfg: DDPGConfig, actor_tx, critic_tx,
                   learn: bool, num_updates: int, kernel_mode=None):
    """episode(params, w_vec, lo, span, carry, xs) -> (carry, EpisodeTrace).

    ``xs`` = (use_warmup [T] bool, warmup_actions [T, m], noise [T, m]).
    ``kernel_mode`` routes the in-episode learner (Pallas kernel vs XLA
    scan); it is resolved on the host by ``_compiled_episode`` and baked
    into this build, never read from the environment inside the trace.
    """
    # lazy: envs.base imports repro.core at its own top level
    from repro.envs.base import barriered_step, fusion_barrier

    do_updates = learn and num_updates > 0

    def one_step(params, w_vec, lo, span, carry, x):
        use_warmup, warmup_a, noise = x

        # act: LHS warmup override, else policy + pre-drawn OU noise. The
        # barrier isolates the actor forward the same way the env step and
        # learner are isolated (see envs.base.barriered_step): each phase of
        # the Fig. 1 loop is its own fusion island, keeping per-phase CPU
        # codegen aligned with the host loop's standalone dispatches.
        actor, state_vec = fusion_barrier(
            (carry.ddpg.actor, carry.state_vec))
        policy = fusion_barrier(actor_apply(actor, state_vec))
        explored = jnp.clip(policy + noise, 0.0, 1.0)
        action = jnp.where(use_warmup, jnp.clip(warmup_a, 0.0, 1.0), explored)

        # env transition (pure model) + state normalization; barriered_step
        # keeps the env subgraph an isolated fusion island with the same
        # scan-body structure the ModelEnv adapter compiles (see
        # envs.base.barriered_step)
        env_state, metrics_vec, restart = barriered_step(
            step_fn, params, carry.env_state, action, False)
        norm = jnp.where(span > 0,
                         jnp.clip((metrics_vec - lo) / span, 0.0, 1.0), 0.0)

        # objective: serial float32 fold in state order (zero-weight terms are
        # exact no-ops) — bit-aligned with Scalarizer.objective
        obj = jnp.float32(0.0)
        for j in range(norm.shape[0]):
            obj = obj + w_vec[j] * norm[j]
        reward = (obj - carry.objective) / jnp.maximum(
            carry.objective, jnp.float32(1e-6))

        if learn:  # observe: FIFO write, exactly ReplayBuffer.add
            buf = carry.buffer
            capacity = buf.s.shape[0]
            i = buf.next_slot
            buf = BufferState(
                s=buf.s.at[i].set(carry.state_vec),
                a=buf.a.at[i].set(action),
                r=buf.r.at[i].set(reward),
                s2=buf.s2.at[i].set(norm),
                next_slot=(i + 1) % capacity,
                size=jnp.minimum(buf.size + 1, capacity))
        else:
            buf = carry.buffer
        if do_updates:
            # size >= 1 here by construction: the FIFO write above ran in
            # this same step (learn=True), so minibatch sampling never sees
            # an empty buffer — the invariant sample_minibatch_indices
            # requires now that the silent zero-index clamp is gone.
            learn_key, k = jax.random.split(carry.learn_key)
            learn_in = fusion_barrier((carry.ddpg, buf, k))
            ddpg, _ = fusion_barrier(_learn_scan(
                learn_in[0],
                (learn_in[1].s, learn_in[1].a, learn_in[1].r, learn_in[1].s2),
                learn_in[1].size, learn_in[2],
                cfg, actor_tx, critic_tx, num_updates,
                kernel_mode=kernel_mode))
        else:
            learn_key, ddpg = carry.learn_key, carry.ddpg

        carry = EpisodeCarry(env_state, ddpg, buf, learn_key, norm, obj)
        return carry, EpisodeTrace(action, metrics_vec, reward, obj, restart)

    def episode(params, w_vec, lo, span, carry, xs):
        body = functools.partial(one_step, params, w_vec, lo, span)
        return jax.lax.scan(body, carry, xs)

    return episode


_EPISODE_CACHE: dict = {}


def _compiled_episode(step_fn, cfg, actor_tx, critic_tx, learn, num_updates,
                      fleet: bool, devices: Optional[tuple]):
    """Jitted (and optionally vmapped + shard_mapped) episode, cached so
    repeated ``run()`` calls and same-space fleets reuse one compilation.
    The learner kernel mode is part of the cache key: flipping
    ``REPRO_KERNELS`` mid-process recompiles instead of silently reusing the
    other path's program."""
    from repro.kernels import ops

    kernel_mode = ops.ddpg_kernel_mode()
    key = (step_fn, cfg, actor_tx, critic_tx, learn, num_updates, fleet,
           devices, kernel_mode)
    if key in _EPISODE_CACHE:
        return _EPISODE_CACHE[key]
    episode = _build_episode(step_fn, cfg, actor_tx, critic_tx, learn,
                             num_updates, kernel_mode=kernel_mode)
    if fleet:
        # session axis: params/w_vec/lo/span/carry stacked; xs shares the
        # warmup schedule (sessions run in lockstep) but not plans/noise
        episode = jax.vmap(episode, in_axes=(0, 0, 0, 0, 0, (None, 0, 0)))
        if devices is not None and len(devices) > 1:
            from jax.sharding import Mesh, PartitionSpec as P
            try:
                from jax.experimental.shard_map import shard_map
            except ImportError:  # newer jax
                from jax import shard_map
            mesh = Mesh(np.array(devices), ("session",))
            episode = shard_map(
                episode, mesh=mesh,
                in_specs=(P("session"), P("session"), P("session"),
                          P("session"), P("session"),
                          (P(), P("session"), P("session"))),
                out_specs=P("session"), check_rep=False)
    # Donating the carry (learner params + opt state + FIFO storage — the
    # bulk of the program's operands) lets XLA reuse those buffers in place
    # instead of defensively copying them across the call boundary. Callers
    # never touch the input carry after the call: both run_*_episode_scan
    # entry points rebuild agent/env/buffer state from the RETURNED carry.
    fn = jax.jit(episode, donate_argnums=(4,))
    _EPISODE_CACHE[key] = fn
    return fn


def _consume_exploration(agent, steps: int, session: Optional[int] = None):
    """Pre-draw the episode's exploration from the agent's own host streams.

    Warmup plans and OU noise are state-independent, so consuming them up
    front leaves the agent's numpy RNG exactly where ``steps`` host-loop
    ``act()`` calls would — the key to host/scan equivalence. Returns
    (use_warmup [T], warmup_actions [T, m], noise [T, m]); advances
    ``steps_taken``."""
    m = agent.cfg.action_dim
    s0 = agent.steps_taken
    if session is None:
        plan, noise_src = agent._warmup_plan, agent.noise
    else:
        plan, noise_src = agent._warmup_plans[session], agent.noises[session]
    use_warmup = np.zeros(steps, bool)
    warmup = np.zeros((steps, m), np.float32)
    noise = np.zeros((steps, m), np.float32)
    for t in range(steps):
        if s0 + t < agent.warmup_steps:
            use_warmup[t] = True
            warmup[t] = plan[s0 + t]
        else:
            noise[t] = noise_src()
    if session is None:  # fleet callers advance the shared counter once
        agent.steps_taken += steps
    return use_warmup, warmup, noise


def run_episode_scan(env, agent, scalarizer, cur_metrics: dict, steps: int,
                 learn: bool = True) -> EpisodeTrace:
    """Run ``steps`` fused tuning iterations for one session.

    ``env`` must be a ``ModelEnv``. Mutates ``env`` (model state, last
    config) and ``agent`` (learner state, buffer, noise stream, steps_taken)
    exactly as the host loop would; returns the per-step trace as numpy.
    """
    model = env.model
    lo, span = metric_bounds(env.metric_specs, env.state_metrics)
    w_vec = scalarizer.weight_vector(env.state_metrics)
    state_vec = normalize_state(cur_metrics, env.metric_specs,
                                env.state_metrics)
    objective = np.float32(scalarizer.objective(cur_metrics))

    (bs, ba, br, bs2), _ = agent.buffer.storage()
    buffer = BufferState(
        s=jnp.asarray(bs), a=jnp.asarray(ba), r=jnp.asarray(br),
        s2=jnp.asarray(bs2),
        next_slot=jnp.asarray(agent.buffer._next, jnp.int32),
        size=jnp.asarray(len(agent.buffer), jnp.int32))
    xs = _consume_exploration(agent, steps)
    carry = EpisodeCarry(env.model_state, agent.state, buffer,
                         agent._learn_key, jnp.asarray(state_vec),
                         jnp.asarray(objective))

    fn = _compiled_episode(model.step_fn, agent.cfg, agent._actor_tx,
                           agent._critic_tx, learn, agent.cfg.updates_per_step,
                           fleet=False, devices=None)
    carry, trace = fn(model.params, jnp.asarray(w_vec), jnp.asarray(lo),
                      jnp.asarray(span), carry, xs)

    env.model_state = carry.env_state
    agent.state = carry.ddpg
    agent._learn_key = carry.learn_key
    if learn:
        agent.buffer.set_storage(
            np.asarray(carry.buffer.s), np.asarray(carry.buffer.a),
            np.asarray(carry.buffer.r), np.asarray(carry.buffer.s2),
            int(carry.buffer.next_slot), int(carry.buffer.size))
    return jax.tree_util.tree_map(np.asarray, trace)


def run_fleet_episode_scan(envs: Sequence, agent, scalarizers: Sequence,
                       cur_metrics: Sequence, steps: int, learn: bool = True,
                       devices: Optional[Sequence] = None) -> EpisodeTrace:
    """Fleet variant: N sessions' episodes as one vmapped (and, with
    ``devices``, shard_mapped) program. Trace leaves are [N, T, ...].

    Sessions are padded up to a multiple of the device count by replicating
    session 0 (results sliced off), so any grid shape shards. Per-session
    behaviour is independent of the device count: every session's PRNG keys
    derive from its own seed, never from its placement.
    """
    models = [e.model for e in envs]
    step_fns = {m.step_fn for m in models}
    if len(step_fns) != 1:
        raise ValueError(
            "fleet sessions must share one env model structure (same space / "
            "model class); mixed fleets need the host engine")
    n = len(envs)

    def stack(trees):  # host-side stack: one transfer per leaf, not N
        return jax.tree_util.tree_map(
            lambda *xs: jnp.asarray(np.stack([np.asarray(x) for x in xs])),
            *trees)

    params = stack([m.params for m in models])
    env_states = stack([e.model_state for e in envs])
    lo, span = metric_bounds(envs[0].metric_specs, envs[0].state_metrics)
    lo = np.broadcast_to(lo, (n, lo.shape[0]))
    span = np.broadcast_to(span, (n, span.shape[0]))
    w_vec = np.stack([sc.weight_vector(e.state_metrics)
                      for sc, e in zip(scalarizers, envs)])
    state_vecs = np.stack([
        normalize_state(mtr, e.metric_specs, e.state_metrics)
        for mtr, e in zip(cur_metrics, envs)])
    objectives = np.array([np.float32(sc.objective(mtr))
                           for sc, mtr in zip(scalarizers, cur_metrics)],
                          np.float32)

    (bs, ba, br, bs2), sizes = agent.buffer.storage()
    buffer = BufferState(
        s=jnp.asarray(bs), a=jnp.asarray(ba), r=jnp.asarray(br),
        s2=jnp.asarray(bs2),
        next_slot=jnp.full((n,), agent.buffer._next, jnp.int32),
        size=jnp.asarray(sizes, jnp.int32))

    s0 = agent.steps_taken
    use_warmup = np.zeros(steps, bool)
    warmup = np.zeros((n, steps, agent.cfg.action_dim), np.float32)
    noise = np.zeros((n, steps, agent.cfg.action_dim), np.float32)
    for t in range(steps):
        if s0 + t < agent.warmup_steps:
            use_warmup[t] = True
            warmup[:, t] = agent._warmup_plans[:, s0 + t]
        else:
            noise[:, t] = np.stack([nz() for nz in agent.noises])
    agent.steps_taken += steps

    carry = EpisodeCarry(env_states, agent.states, buffer, agent._learn_keys,
                         jnp.asarray(state_vecs), jnp.asarray(objectives))
    args = [params, jnp.asarray(w_vec), jnp.asarray(lo), jnp.asarray(span),
            carry]

    devices = tuple(devices) if devices else None
    pad = 0
    if devices and n % len(devices):
        pad = len(devices) - n % len(devices)

        def pad_tree(tree):
            return jax.tree_util.tree_map(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[:1], pad, axis=0)]), tree)

        args = [pad_tree(a) for a in args]
        warmup = np.concatenate([warmup, np.repeat(warmup[:1], pad, axis=0)])
        noise = np.concatenate([noise, np.repeat(noise[:1], pad, axis=0)])

    fn = _compiled_episode(models[0].step_fn, agent.cfg, agent._actor_tx,
                           agent._critic_tx, learn, agent.cfg.updates_per_step,
                           fleet=True, devices=devices)
    carry, trace = fn(*args, (use_warmup, warmup, noise))
    if pad:
        carry, trace = jax.tree_util.tree_map(lambda x: x[:n], (carry, trace))

    for e, st in zip(envs, _unstack(carry.env_state, n)):
        e.model_state = st
    agent.states = carry.ddpg
    agent._learn_keys = carry.learn_key
    if learn:
        agent.buffer.set_storage(
            np.asarray(carry.buffer.s), np.asarray(carry.buffer.a),
            np.asarray(carry.buffer.r), np.asarray(carry.buffer.s2),
            int(carry.buffer.next_slot[0]), int(carry.buffer.size[0]))
    return jax.tree_util.tree_map(np.asarray, trace)


def _unstack(tree, n: int) -> list:
    return [jax.tree_util.tree_map(lambda x: x[i], tree) for i in range(n)]


def default_devices() -> list:
    """All local devices — the default fleet sharding axis."""
    return list(jax.devices())
