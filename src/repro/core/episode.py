"""Whole-episode engine: the Fig. 1 loop as ONE compiled XLA program.

``core.tuner.Tuner`` steps the loop from Python: every tuning step crosses the
host boundary to act, apply the config, scalarize the reward, store the
transition and learn. This module fuses all of it — act → env step → reward
scalarization → buffer store → ``ddpg_learn_scan`` — into a single jitted
``lax.scan`` over the episode (``run_episode_scan``), and vmaps/shards the same
body over a fleet session axis (``run_fleet_episode_scan``), so a seeds ×
workloads × objectives grid runs as one device computation.

Fleet episodes execute as a STREAM of fixed-size chunks:

  * ``run_fleet_episode_scan(..., chunk=C)`` runs the N-session fleet as
    ``ceil(N / C)`` chunks of exactly C sessions through ONE compiled,
    donated episode program. Every chunk of every grid shape reuses the same
    executable (shape bucketing: the compiled shape is ``[C, ...]``, never
    ``[N, ...]``); a ragged last chunk is padded by replicating its own last
    session and the padded rows are sliced off before anything reads them.
  * Between chunks the fleet's state (learner params/opt state, FIFO replay,
    env states) lives in host numpy buffers; each chunk's slice is staged to
    the device, the episode runs, and the returned carry + trace stream back
    into preallocated host buffers. Peak device memory is O(C·T) — one
    chunk's state and trace — instead of O(N·T).
  * The trace is stored compactly: actions as per-knob quantization indices
    (knobs are quantized by construction — ``ParamSpace.index_dtype``,
    usually uint8 instead of float32 per coordinate) and restart seconds as
    int32 fixed point (exact for every cost the env models emit; see
    ``RESTART_FP_SCALE``). Metric/reward/objective floats stay float32.

Equivalence contract (pinned by tests/test_episode.py and
tests/test_chunked_fleet.py):

  * the scan body performs, step for step, the float32 arithmetic of the
    host loop driving a ``ModelEnv`` adapter — same actor forward, same
    exploration values (warmup plans and OU noise are state-independent, so
    the host shell pre-consumes them from the agent's own numpy streams and
    feeds them in as scan inputs), same env ``step_fn`` on the same key
    chain, same normalization/objective fold (``core.scalarization`` does
    float32 fixed-order arithmetic for exactly this reason), same FIFO write
    and the same fused learner. The decision trajectory — every config, the
    restart accounting, the best configuration — is exactly equal between
    engines; float fields agree to within a few float32 ulps (XLA CPU
    compiles the two engines as different programs, and its context-dependent
    FMA/vectorization choices can move cancellation-prone values by single
    ulps — the per-phase fusion islands below keep it that tight).
  * chunking is pure scheduling: per-session trajectories are independent of
    the chunk size (decision trajectory exact, floats within the same few
    ulps — vmap width is part of XLA's codegen context), and padded sessions
    never leak into results.
  * both entry points mutate the adapter env, the agent and the replay
    buffer exactly as ``steps`` host-loop iterations would, so progressive
    tuning (paper Fig. 7) and the §III-E final recommendation work unchanged
    on top.

Only pure-model environments (``envs.base.ModelEnv``) can run here; real-DFS
or other external environments keep the host loop.
"""

from __future__ import annotations

import functools
import math
import os
import time
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_mapping import ParamSpace, jax_coord_maps
from repro.core.ddpg import DDPGConfig, actor_apply, _learn_scan
from repro.core.scalarization import metric_bounds, normalize_state


class BufferState(NamedTuple):
    """Device-side FIFO replay storage (the in-graph ``ReplayBuffer``).

    Arrays carry the replay *storage* dtype — float32 by default, bfloat16
    under the opt-in compact mode (``BatchedReplayBuffer(storage_dtype=...)``).
    Compute is always float32: the fused learner widens minibatches right
    after gathering them (``core.ddpg._learn_scan``)."""

    s: jnp.ndarray
    a: jnp.ndarray
    r: jnp.ndarray
    s2: jnp.ndarray
    next_slot: jnp.ndarray  # i32 write cursor
    size: jnp.ndarray       # i32 valid rows


class EpisodeCarry(NamedTuple):
    env_state: Any
    ddpg: Any
    buffer: BufferState
    learn_key: jax.Array
    state_vec: jnp.ndarray   # current normalized metric state [k]
    objective: jnp.ndarray   # scalarized objective of state_vec (f32)


class EpisodeTrace(NamedTuple):
    """Per-step outputs; leading axis = episode steps (then sessions, for the
    fleet). The host shell reconstructs ``StepRecord`` history from this.

    Compact storage: ``action_idx`` holds per-knob quantization indices
    (``ParamSpace.index_dtype`` — decode with
    ``ParamSpace.configs_from_indices``); ``restarts`` is int32 fixed point
    in-graph and already-decoded float32 seconds once a ``run_*_scan`` entry
    point returns it to the host."""

    action_idx: jnp.ndarray
    metrics: jnp.ndarray
    rewards: jnp.ndarray
    objectives: jnp.ndarray
    restarts: jnp.ndarray


# -- restart fixed-point encoding -------------------------------------------
#
# Restart downtime is a continuous §III-F draw, but every cost the env models
# emit is an f32 in {0} ∪ [4 s, 1024 s) — and any float32 >= 4 has an ulp of
# at least 2^-21, so cost * 2^21 is an exact int32. The trace therefore
# stores restarts as int32 fixed point and the host decode is bit-exact
# (int -> f64 -> /2^21 -> f32 round-trips the original f32). Costs >= 1024 s
# are clamped (no model emits a 17-minute restart); nonzero costs below 4 s
# would decode within 2^-22 s but lose bit-exactness — env models must keep
# restart costs in the exact domain (the repo's all do: 12-20 s workload,
# +30 s DFS, and the synthetic 5-10/+20 s ranges).

RESTART_FP_SCALE = float(2 ** 21)
RESTART_FP_MAX_SECONDS = 1023.0


def _encode_restart(cost: jnp.ndarray) -> jnp.ndarray:
    clipped = jnp.clip(cost, 0.0, jnp.float32(RESTART_FP_MAX_SECONDS))
    return jnp.round(clipped * jnp.float32(RESTART_FP_SCALE)).astype(jnp.int32)


def decode_restarts(fp: np.ndarray) -> np.ndarray:
    """int32 fixed-point restart trace -> float32 seconds (exact; see above)."""
    return (np.asarray(fp).astype(np.float64) / RESTART_FP_SCALE).astype(
        np.float32)


def _build_episode(step_fn, space: ParamSpace, cfg: DDPGConfig, actor_tx,
                   critic_tx, learn: bool, num_updates: int, kernel_mode=None,
                   policy=None, obs_mask=None, resilience=None):
    """episode(params, w_vec, lo, span, carry, xs) -> (carry, EpisodeTrace).

    ``xs`` = (use_warmup [T] bool, warmup_actions [T, m], noise [T, m]).
    ``kernel_mode`` routes the in-episode learner (Pallas kernel vs XLA
    scan); it is resolved on the host by ``_compiled_episode`` and baked
    into this build, never read from the environment inside the trace.
    ``space`` supplies the in-graph quantization maps for the compact
    action-index trace (the same ``jax_coord_maps`` the env model decodes
    with, so trace indices and env dynamics always agree).

    ``policy`` (a ``core.guardrails.DeploymentPolicy``) swaps the scan body
    for the guarded shadow/canary step: carry becomes ``GuardedCarry`` and
    the trace grows the decision trail (``GuardedEpisodeTrace``). With
    ``policy=None`` this function is byte-for-byte the pre-guardrail build —
    the off path never touches ``core.guardrails``.

    ``obs_mask`` (a tuple of 0/1 floats over the k state metrics — see
    ``envs.metrics.scope_mask``) is the DIAL-style local-observation mode:
    the LEARNER's view of the state (actor input and the s/s2 rows stored in
    replay) is masked to the visible metrics, while the env dynamics,
    objective, reward and trace all keep the full state. ``obs_mask=None``
    leaves every line of the build untouched.

    ``resilience`` (a ``core.resilience.ResiliencePolicy``) swaps the body
    for the self-healing step: carry becomes ``ResilientCarry`` and the
    trace grows the uint8 health byte (``ResilientEpisodeTrace``). With
    ``resilience=None`` this function is byte-for-byte the pre-resilience
    build — the off path never touches ``core.resilience``.
    """
    # lazy: envs.base imports repro.core at its own top level
    from repro.envs.base import barriered_step, fusion_barrier

    if resilience is not None:
        if policy is not None:
            raise ValueError(
                "resilience does not compose with DeploymentPolicy "
                "guardrails (the guarded step owns its own learn path)")
        from repro.core.resilience import build_resilient_step
        resilient = build_resilient_step(step_fn, space, cfg, actor_tx,
                                         critic_tx, learn, num_updates,
                                         kernel_mode, resilience, obs_mask)

        def resilient_episode(params, w_vec, lo, span, carry, xs):
            body = functools.partial(resilient, params, w_vec, lo, span)
            return jax.lax.scan(body, carry, xs)

        return resilient_episode

    if policy is not None:
        if obs_mask is not None:
            raise ValueError(
                "observation masking does not compose with DeploymentPolicy "
                "guardrails (the guarded step owns its own observe path)")
        from repro.core.guardrails import build_guarded_step
        guarded = build_guarded_step(step_fn, space, cfg, actor_tx,
                                     critic_tx, learn, num_updates,
                                     kernel_mode, policy)

        def guarded_episode(params, w_vec, lo, span, carry, xs):
            body = functools.partial(guarded, params, w_vec, lo, span)
            return jax.lax.scan(body, carry, xs)

        return guarded_episode

    do_updates = learn and num_updates > 0
    coord_maps = jax_coord_maps(space)
    idx_dtype = space.index_dtype()
    mask = None if obs_mask is None else jnp.asarray(obs_mask, jnp.float32)

    def one_step(params, w_vec, lo, span, carry, x):
        use_warmup, warmup_a, noise = x

        # act: LHS warmup override, else policy + pre-drawn OU noise. The
        # barrier isolates the actor forward the same way the env step and
        # learner are isolated (see envs.base.barriered_step): each phase of
        # the Fig. 1 loop is its own fusion island, keeping per-phase CPU
        # codegen aligned with the host loop's standalone dispatches.
        actor, state_vec = fusion_barrier(
            (carry.ddpg.actor, carry.state_vec))
        obs = state_vec if mask is None else state_vec * mask
        policy = fusion_barrier(actor_apply(actor, obs))
        explored = jnp.clip(policy + noise, 0.0, 1.0)
        action = jnp.where(use_warmup, jnp.clip(warmup_a, 0.0, 1.0), explored)

        # compact trace: the knob indices the env's own quantization lands
        # on (f32 maps — identical to the env dynamics' decode by
        # construction)
        action_idx = jnp.stack(
            [coord_maps[j](action[j])["idx"] for j in range(space.dim)]
        ).astype(idx_dtype)

        # env transition (pure model) + state normalization; barriered_step
        # keeps the env subgraph an isolated fusion island with the same
        # scan-body structure the ModelEnv adapter compiles (see
        # envs.base.barriered_step)
        env_state, metrics_vec, restart = barriered_step(
            step_fn, params, carry.env_state, action, False)
        norm = jnp.where(span > 0,
                         jnp.clip((metrics_vec - lo) / span, 0.0, 1.0), 0.0)

        # objective: serial float32 fold in state order (zero-weight terms are
        # exact no-ops) — bit-aligned with Scalarizer.objective
        obj = jnp.float32(0.0)
        for j in range(norm.shape[0]):
            obj = obj + w_vec[j] * norm[j]
        reward = (obj - carry.objective) / jnp.maximum(
            carry.objective, jnp.float32(1e-6))

        if learn:  # observe: FIFO write, exactly ReplayBuffer.add
            buf = carry.buffer
            capacity = buf.s.shape[0]
            i = buf.next_slot
            # the stored s/s2 rows are what the LEARNER observed: under a
            # local-observation mask the invisible metrics are zeroed, so
            # replayed minibatches match the masked actor inputs
            s_row = (carry.state_vec if mask is None
                     else carry.state_vec * mask)
            s2_row = norm if mask is None else norm * mask
            buf = BufferState(
                s=buf.s.at[i].set(s_row.astype(buf.s.dtype)),
                a=buf.a.at[i].set(action.astype(buf.a.dtype)),
                r=buf.r.at[i].set(reward.astype(buf.r.dtype)),
                s2=buf.s2.at[i].set(s2_row.astype(buf.s2.dtype)),
                next_slot=(i + 1) % capacity,
                size=jnp.minimum(buf.size + 1, capacity))
        else:
            buf = carry.buffer
        if do_updates:
            # size >= 1 here by construction: the FIFO write above ran in
            # this same step (learn=True), so minibatch sampling never sees
            # an empty buffer — the invariant sample_minibatch_indices
            # requires now that the silent zero-index clamp is gone.
            learn_key, k = jax.random.split(carry.learn_key)
            learn_in = fusion_barrier((carry.ddpg, buf, k))
            ddpg, _ = fusion_barrier(_learn_scan(
                learn_in[0],
                (learn_in[1].s, learn_in[1].a, learn_in[1].r, learn_in[1].s2),
                learn_in[1].size, learn_in[2],
                cfg, actor_tx, critic_tx, num_updates,
                kernel_mode=kernel_mode))
        else:
            learn_key, ddpg = carry.learn_key, carry.ddpg

        carry = EpisodeCarry(env_state, ddpg, buf, learn_key, norm, obj)
        return carry, EpisodeTrace(action_idx, metrics_vec, reward, obj,
                                   _encode_restart(restart))

    def episode(params, w_vec, lo, span, carry, xs):
        body = functools.partial(one_step, params, w_vec, lo, span)
        return jax.lax.scan(body, carry, xs)

    return episode


def _build_cell_episode(step_fn, space: ParamSpace, cfg: DDPGConfig,
                        actor_tx, critic_tx, learn: bool, num_updates: int,
                        kernel_mode, sharing, cell_size: int, obs_mask,
                        resilience=None):
    """One CELL's episode: ``cell_size`` member sessions stepping in lockstep
    with shared experience (``core.sharing.SharingConfig``).

    Carry leaves are session-stacked [cs, ...] — except, under shared
    replay, the buffer, which is the cell's single merged FIFO window
    ([capacity, ...] with scalar cursors). ``xs`` grows two inputs over the
    off-path build: ``avg_now`` [T, cs] (host-computed averaging cadence —
    the whole cell agrees, the body reads lane 0) and ``active`` [T, cs]
    (False lanes are padding: their transitions never enter the shared
    window and they carry zero weight in the cell mean).

    Step for step this is the vmapped per-session body of
    ``_build_episode`` — same phase order, same fusion islands, same
    float32 arithmetic per lane — with three cell-level splices: the merged
    FIFO scatter-write, minibatch sampling over the merged window (every
    learner sees cs× transitions per env step), and the post-learn masked
    cell mean of the actor/critic pytrees when ``avg_now`` fires. At
    ``cell_size=1`` every splice is an exact identity (one-element cumsum,
    one-element mean), which is what the sharing-off property tests pin.

    ``resilience`` threads the per-lane health layer through the cell: a
    lane with a corrupted observation or a degraded member contributes
    NOTHING to the merged window or the cell mean (its write mask and
    averaging weight drop), so one NaN cannot poison cellmates; the
    snapshot/reset/degrade lifecycle runs per lane exactly as in the
    single-session resilient body. ``resilience=None`` leaves every line of
    the build untouched.
    """
    from repro.envs.base import barriered_step, fusion_barrier

    do_updates = learn and num_updates > 0
    coord_maps = jax_coord_maps(space)
    idx_dtype = space.index_dtype()
    cs = int(cell_size)
    mask = None if obs_mask is None else jnp.asarray(obs_mask, jnp.float32)
    shared = bool(sharing.shared_replay)
    averaging = sharing.avg_every is not None
    rz = resilience
    if rz is not None:
        from repro.core.resilience import (
            EVENT_DEGRADED, EVENT_NONFINITE, EVENT_RESET, HealthState,
            ResilientCarry, ResilientEpisodeTrace, health_decision,
            select_tree, tree_nonfinite_rows)

    def idx_of(action):  # [m] -> compact per-knob quantization indices
        return jnp.stack([coord_maps[j](action[j])["idx"]
                          for j in range(space.dim)]).astype(idx_dtype)

    def one_step(params, w_vec, lo, span, carry, x):
        use_warmup, warmup_a, noise, avg_now, active = x
        health = None
        if rz is not None:
            health, carry = carry.health, carry.base

        # act (per session, vmapped over the cell)
        actor, state_vec = fusion_barrier(
            (carry.ddpg.actor, carry.state_vec))
        obs = state_vec if mask is None else state_vec * mask
        policy = fusion_barrier(jax.vmap(actor_apply)(actor, obs))
        explored = jnp.clip(policy + noise, 0.0, 1.0)
        action = jnp.where(use_warmup[:, None],
                           jnp.clip(warmup_a, 0.0, 1.0), explored)
        action_idx = jax.vmap(idx_of)(action)

        # env transition + normalization (per session)
        env_state, metrics_vec, restart = jax.vmap(
            lambda p, es, a: barriered_step(step_fn, p, es, a, False)
        )(params, carry.env_state, action)
        norm = jnp.where(span > 0,
                         jnp.clip((metrics_vec - lo) / span, 0.0, 1.0), 0.0)

        # objective: same serial float32 fold per lane as the off path
        obj = jnp.float32(0.0)
        for j in range(norm.shape[1]):
            obj = obj + w_vec[:, j] * norm[:, j]
        reward = (obj - carry.objective) / jnp.maximum(
            carry.objective, jnp.float32(1e-6))

        if rz is not None:
            # per-lane corrupted-observation flag: these lanes are recorded
            # in the trace but contribute nothing stateful this step
            bad_obs = jnp.any(~jnp.isfinite(metrics_vec), axis=1)
            # a corrupted or degraded member's transitions never enter the
            # merged window (the one-NaN-poisons-the-cell hazard)
            contrib = active & ~bad_obs & ~health.degraded
        else:
            contrib = active

        s_row = (carry.state_vec if mask is None
                 else carry.state_vec * mask)
        s2_row = norm if mask is None else norm * mask
        buf = carry.buffer
        if learn and shared:
            # merged cell FIFO: every ACTIVE member appends, in session
            # order, to the one shared window (exactly
            # BatchedReplayBuffer(groups=...).add); inactive (padding)
            # lanes scatter out of bounds and are dropped
            capacity = buf.s.shape[0]
            n_act = contrib.astype(jnp.int32)
            offs = jnp.cumsum(n_act) - 1
            wrote = jnp.sum(n_act)
            pos = jnp.where(contrib, (buf.next_slot + offs) % capacity,
                            capacity)
            buf = BufferState(
                s=buf.s.at[pos].set(s_row.astype(buf.s.dtype), mode="drop"),
                a=buf.a.at[pos].set(action.astype(buf.a.dtype),
                                    mode="drop"),
                r=buf.r.at[pos].set(reward.astype(buf.r.dtype),
                                    mode="drop"),
                s2=buf.s2.at[pos].set(s2_row.astype(buf.s2.dtype),
                                      mode="drop"),
                next_slot=(buf.next_slot + wrote) % capacity,
                size=jnp.minimum(buf.size + wrote, capacity))
        elif learn:
            # independent per-session FIFOs (averaging-only mode), exactly
            # the off path's write vmapped over the cell
            capacity = buf.s.shape[1]
            lane = jnp.arange(cs)
            i = buf.next_slot
            if rz is not None:
                pos = jnp.where(contrib, i, capacity)  # OOB -> drop
                buf = BufferState(
                    s=buf.s.at[lane, pos].set(s_row.astype(buf.s.dtype),
                                              mode="drop"),
                    a=buf.a.at[lane, pos].set(action.astype(buf.a.dtype),
                                              mode="drop"),
                    r=buf.r.at[lane, pos].set(reward.astype(buf.r.dtype),
                                              mode="drop"),
                    s2=buf.s2.at[lane, pos].set(s2_row.astype(buf.s2.dtype),
                                                mode="drop"),
                    next_slot=jnp.where(contrib, (i + 1) % capacity, i),
                    size=jnp.where(contrib,
                                   jnp.minimum(buf.size + 1, capacity),
                                   buf.size))
            else:
                buf = BufferState(
                    s=buf.s.at[lane, i].set(s_row.astype(buf.s.dtype)),
                    a=buf.a.at[lane, i].set(action.astype(buf.a.dtype)),
                    r=buf.r.at[lane, i].set(reward.astype(buf.r.dtype)),
                    s2=buf.s2.at[lane, i].set(s2_row.astype(buf.s2.dtype)),
                    next_slot=(i + 1) % capacity,
                    size=jnp.minimum(buf.size + 1, capacity))

        lmetrics = None
        if do_updates:
            ks = jax.vmap(jax.random.split)(carry.learn_key)
            learn_key, k = ks[:, 0], ks[:, 1]
            learn_in = fusion_barrier((carry.ddpg, buf, k))
            dbuf = learn_in[1]
            # dropped writes mean the window CAN be empty under resilience
            # (every lane corrupted at step 0); clamp the sampled size and
            # discard the no-data update below
            size_of = ((lambda sz: jnp.maximum(sz, 1)) if rz is not None
                       else (lambda sz: sz))
            if shared:
                # every member learner samples its own minibatches from the
                # MERGED window: data broadcast, state/key batched
                data = (dbuf.s, dbuf.a, dbuf.r, dbuf.s2)
                ddpg, lmetrics = fusion_barrier(jax.vmap(
                    lambda st, kk: _learn_scan(
                        st, data, size_of(dbuf.size), kk, cfg, actor_tx,
                        critic_tx, num_updates, kernel_mode=kernel_mode)
                )(learn_in[0], learn_in[2]))
                empty = dbuf.size == 0
            else:
                ddpg, lmetrics = fusion_barrier(jax.vmap(
                    lambda st, d, sz, kk: _learn_scan(
                        st, d, size_of(sz), kk, cfg, actor_tx, critic_tx,
                        num_updates, kernel_mode=kernel_mode)
                )(learn_in[0], (dbuf.s, dbuf.a, dbuf.r, dbuf.s2),
                  dbuf.size, learn_in[2]))
                empty = dbuf.size == 0
            if rz is not None:
                ddpg = select_tree(jnp.broadcast_to(empty, (cs,)),
                                   carry.ddpg, ddpg)
        else:
            learn_key, ddpg = carry.learn_key, carry.ddpg

        if rz is not None:
            if do_updates:
                bad_learn = (~jnp.broadcast_to(empty, (cs,))
                             & (tree_nonfinite_rows(ddpg)
                                | tree_nonfinite_rows(lmetrics)))
            else:
                bad_learn = jnp.zeros((cs,), bool)
            bad = bad_obs | bad_learn
            do_reset, degraded, resets, nf_total = health_decision(
                bad, health.resets, health.nonfinite, health.degraded, rz)
        else:
            bad = degraded = None

        if averaging:
            # masked cell mean, applied when the host-computed cadence
            # fires; active-weighted so padding lanes contribute nothing —
            # and, under resilience, corrupted/degraded lanes neither
            # (their params are pinned to the snapshot right after this)
            w = (contrib if rz is None
                 else (contrib & ~bad)).astype(jnp.float32)
            denom = jnp.maximum(jnp.sum(w), jnp.float32(1.0))
            do_avg = avg_now[0]

            def cell_mean(leaf):
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    return leaf  # Adam step counts etc. stay per-session
                wf = w.reshape((cs,) + (1,) * (leaf.ndim - 1))
                m = jnp.sum(leaf * wf, axis=0) / denom
                return jnp.where(do_avg, jnp.broadcast_to(m, leaf.shape),
                                 leaf)

            def avg_tree(tree):
                return jax.tree_util.tree_map(cell_mean, tree)

            ddpg = ddpg._replace(
                actor=avg_tree(ddpg.actor), critic=avg_tree(ddpg.critic),
                actor_targ=avg_tree(ddpg.actor_targ),
                critic_targ=avg_tree(ddpg.critic_targ))
            if sharing.avg_opt_state:
                ddpg = ddpg._replace(actor_opt=avg_tree(ddpg.actor_opt),
                                     critic_opt=avg_tree(ddpg.critic_opt))

        if rz is not None:
            # per-lane reset/freeze + snapshot cadence, exactly the
            # single-session resilient body's lifecycle (including the
            # snapshot_every=1 shortcut: the revert target is the lane's
            # step-entry state — pre-learn, pre-averaging — which IS what
            # an every-step snapshot refresh would have stored)
            if rz.snapshot_every == 1:
                ddpg = select_tree(do_reset | degraded, carry.ddpg, ddpg)
                snapshot = health.snapshot          # () — no leaves
                refresh = ~bad & ~degraded
            else:
                ddpg = select_tree(do_reset | degraded, health.snapshot,
                                   ddpg)
                due = (health.since_snap + 1) >= rz.snapshot_every
                refresh = due & ~bad & ~degraded
                snapshot = select_tree(refresh, ddpg, health.snapshot)
            since = jnp.where(refresh, 0, health.since_snap + 1)
            event = (bad.astype(jnp.uint8) * EVENT_NONFINITE
                     + do_reset.astype(jnp.uint8) * EVENT_RESET
                     + degraded.astype(jnp.uint8) * EVENT_DEGRADED)
            carry = ResilientCarry(
                base=EpisodeCarry(
                    env_state, ddpg, buf, learn_key,
                    jnp.where(bad_obs[:, None], carry.state_vec, norm),
                    jnp.where(bad_obs, carry.objective, obj)),
                health=HealthState(snapshot, resets, nf_total, degraded,
                                   since))
            return carry, ResilientEpisodeTrace(
                action_idx, metrics_vec, reward, obj,
                _encode_restart(restart), event)

        carry = EpisodeCarry(env_state, ddpg, buf, learn_key, norm, obj)
        return carry, EpisodeTrace(action_idx, metrics_vec, reward, obj,
                                   _encode_restart(restart))

    def cell_episode(params, w_vec, lo, span, carry, xs):
        body = functools.partial(one_step, params, w_vec, lo, span)
        return jax.lax.scan(body, carry, xs)

    return cell_episode


def _build_cell_fleet_episode(step_fn, space, cfg, actor_tx, critic_tx,
                              learn, num_updates, kernel_mode, sharing,
                              cell_size: int, obs_mask, devices,
                              resilience=None):
    """The sharing fleet program: cells vmapped over the group axis, wrapped
    so callers keep the session-leading calling convention.

    The wrapper takes the SAME operand layout as the off-path fleet program
    — every leaf session-leading [C, ...] — except the replay buffer, which
    under shared replay is cell-granular ([C/cs, capacity, ...] with [C/cs]
    cursors). Sharding (when requested) partitions the GROUP axis, so a
    cell never spans devices and the cell mean needs no cross-device
    collective."""
    cs = int(cell_size)
    shared = bool(sharing.shared_replay)
    cell = _build_cell_episode(step_fn, space, cfg, actor_tx, critic_tx,
                               learn, num_updates, kernel_mode, sharing,
                               cs, obs_mask, resilience=resilience)
    gmapped = jax.vmap(cell, in_axes=(0, 0, 0, 0, 0, (0, 0, 0, 0, 0)))
    if devices is not None and len(devices) > 1:
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:  # newer jax
            from jax import shard_map
        mesh = Mesh(np.array(devices), ("session",))
        gmapped = shard_map(
            gmapped, mesh=mesh,
            in_specs=(P("session"), P("session"), P("session"),
                      P("session"), P("session"),
                      (P("session"), P("session"), P("session"),
                       P("session"), P("session"))),
            out_specs=P("session"), check_rep=False)

    def episode(params, w_vec, lo, span, carry, xs):
        health = None
        if resilience is not None:
            from repro.core.resilience import ResilientCarry
            health, carry = carry.health, carry.base
        n = carry.state_vec.shape[0]
        assert n % cs == 0, (n, cs)
        g = n // cs
        gt = jax.tree_util.tree_map

        def group(x):  # [n, ...] -> [g, cs, ...]
            return x.reshape((g, cs) + x.shape[1:])

        def group_xs(x):  # [n, T, ...] -> [g, T, cs, ...]
            return jnp.swapaxes(group(x), 1, 2)

        def ungroup(x):
            return x.reshape((n,) + x.shape[2:])

        buf = carry.buffer if shared else gt(group, carry.buffer)
        gcarry = EpisodeCarry(
            env_state=gt(group, carry.env_state),
            ddpg=gt(group, carry.ddpg), buffer=buf,
            learn_key=group(carry.learn_key),
            state_vec=group(carry.state_vec),
            objective=group(carry.objective))
        if resilience is not None:
            gcarry = ResilientCarry(base=gcarry, health=gt(group, health))
        out_carry, trace = gmapped(gt(group, params), group(w_vec),
                                   group(lo), group(span), gcarry,
                                   gt(group_xs, xs))
        out_health = None
        if resilience is not None:
            out_health, out_carry = out_carry.health, out_carry.base
        obuf = (out_carry.buffer if shared
                else gt(ungroup, out_carry.buffer))
        out_carry = EpisodeCarry(
            env_state=gt(ungroup, out_carry.env_state),
            ddpg=gt(ungroup, out_carry.ddpg), buffer=obuf,
            learn_key=ungroup(out_carry.learn_key),
            state_vec=ungroup(out_carry.state_vec),
            objective=ungroup(out_carry.objective))
        if resilience is not None:
            out_carry = ResilientCarry(base=out_carry,
                                       health=gt(ungroup, out_health))

        def ungroup_trace(x):  # [g, T, cs, ...] -> [n, T, ...]
            y = jnp.swapaxes(x, 1, 2)
            return y.reshape((n,) + y.shape[2:])

        return out_carry, gt(ungroup_trace, trace)

    return episode


def _build_mega_episode(step_fn, space: ParamSpace, cfg: DDPGConfig,
                        learn: bool, num_updates: int, mega_mode: str,
                        fleet: bool):
    """The whole-episode megakernel wrapped in the standard episode calling
    convention: ``episode(params, w_vec, lo, span, carry, xs)`` with the
    fleet layout (every leaf session-leading), so the chunked runtime,
    ``FleetService`` staging and both ``run_*_episode_scan`` entry points
    drive it UNCHANGED.

    Per chunk this dispatches ONE fused program
    (``kernels.ops.episode_inner_loop``): under ``pallas``/``interpret`` a
    single Pallas kernel whose grid is the session axis runs all T env
    steps — act, env transition, reward scalarization, FIFO store and the
    full inner loop — with the packed learner state, replay window and env
    state VMEM-resident across the episode; ``xla`` runs the identical
    per-session body vmapped. The learner stays in the packed layout
    ACROSS steps (pack∘unpack is the identity on the real regions and the
    padded regions are a zero fixed point), so the decision trajectory is
    exact vs the scan engine whenever the scan engine runs the same packed
    learner (``REPRO_KERNELS=interpret``/``pallas``); see
    tests/test_megakernel.py for the pinned ladder.

    ``mega_mode`` is host-resolved by ``_compiled_episode`` (from
    ``REPRO_MEGAKERNEL``) and baked into the build, like ``kernel_mode``.
    """
    from repro.kernels import episode_fused as _ef
    from repro.kernels import ops as _ops
    from repro.kernels.ddpg_fused import (pack_params, packed_dims,
                                          unpack_params)

    dims = packed_dims(cfg.state_dim, cfg.action_dim, cfg.hidden)
    idx_dtype = space.index_dtype()

    def _pack_one(ddpg):
        a_adam, c_adam = ddpg.actor_opt[0], ddpg.critic_opt[0]
        return pack_params(
            ddpg.actor, ddpg.critic, ddpg.actor_targ, ddpg.critic_targ,
            a_adam.mu, a_adam.nu, c_adam.mu, c_adam.nu,
            a_adam.count, c_adam.count, dims)

    def episode(params, w_vec, lo, span, carry, xs):
        from repro.core.ddpg import DDPGState, _packable
        from repro.optim.transform import ScaleByAdamState

        if not fleet:
            one = jax.tree_util.tree_map(lambda x: x[None],
                                         (params, w_vec, lo, span, carry, xs))
            params, w_vec, lo, span, carry, xs = one
        if not _packable(jax.tree_util.tree_map(lambda x: x[0], carry.ddpg),
                         cfg):
            raise ValueError(
                "the whole-episode megakernel needs the packed learner "
                "layout (two hidden layers, stock optim.adam transforms); "
                "run this configuration with REPRO_MEGAKERNEL=off")
        use_warmup, warmup, noise = xs
        packed = jax.vmap(_pack_one)(carry.ddpg)
        param_leaves, param_treedef = jax.tree_util.tree_flatten(params)
        env_leaves, env_treedef = jax.tree_util.tree_flatten(carry.env_state)
        spec = _ef.EpisodeKernelSpec(
            step_fn=step_fn, space=space, cfg=cfg, learn=learn,
            num_updates=num_updates, dims=dims,
            param_treedef=param_treedef, env_treedef=env_treedef)
        buf = carry.buffer
        operands = _ef.EpisodeOperands(
            use_warmup=use_warmup, warmup=warmup, noise=noise,
            w_vec=w_vec, lo=lo, span=span,
            params=tuple(param_leaves), env=tuple(env_leaves),
            packed=tuple(packed),
            buffer=(buf.s, buf.a, buf.r, buf.s2, buf.next_slot, buf.size),
            learn_key=carry.learn_key, state_vec=carry.state_vec,
            objective=carry.objective)
        outs = _ops.episode_inner_loop(operands, spec=spec, mode=mega_mode)

        T = use_warmup.shape[1]
        do_updates = learn and num_updates > 0

        def _unpack_one(packed_one, ddpg):
            parts = unpack_params(*packed_one, dims)
            a_rest = ddpg.actor_opt[1:]
            c_rest = ddpg.critic_opt[1:]
            return DDPGState(
                actor=parts["actor"], critic=parts["critic"],
                actor_targ=parts["actor_targ"],
                critic_targ=parts["critic_targ"],
                actor_opt=(ScaleByAdamState(count=parts["actor_count"],
                                            mu=parts["actor_mu"],
                                            nu=parts["actor_nu"]), *a_rest),
                critic_opt=(ScaleByAdamState(count=parts["critic_count"],
                                             mu=parts["critic_mu"],
                                             nu=parts["critic_nu"]),
                            *c_rest),
                step=ddpg.step + (T * num_updates if do_updates else 0))

        ddpg = jax.vmap(_unpack_one)(tuple(outs.packed), carry.ddpg)
        out_carry = EpisodeCarry(
            env_state=jax.tree_util.tree_unflatten(env_treedef,
                                                   list(outs.env)),
            ddpg=ddpg,
            buffer=BufferState(*outs.buffer),
            learn_key=outs.learn_key, state_vec=outs.state_vec,
            objective=outs.objective)
        trace = EpisodeTrace(
            action_idx=outs.action_idx.astype(idx_dtype),
            metrics=outs.metrics, rewards=outs.rewards,
            objectives=outs.objectives, restarts=outs.restarts)
        if not fleet:
            out_carry, trace = jax.tree_util.tree_map(
                lambda x: x[0], (out_carry, trace))
        return out_carry, trace

    return episode


_EPISODE_CACHE: dict = {}


def _compiled_episode(step_fn, space, cfg, actor_tx, critic_tx, learn,
                      num_updates, fleet: bool, devices: Optional[tuple],
                      policy=None, sharing=None, cell_size: int = 1,
                      obs_mask=None, resilience=None):
    """Jitted (and optionally vmapped + shard_mapped) episode, cached so
    repeated ``run()`` calls and same-space fleets reuse one compilation.
    The learner kernel mode is part of the cache key: flipping
    ``REPRO_KERNELS`` mid-process recompiles instead of silently reusing the
    other path's program. One cache entry serves EVERY chunk of EVERY grid
    shape: the chunked fleet runner always calls it at the fixed chunk shape
    ``[C, ...]``, so the underlying jit cache holds a single executable per
    (chunk, steps) bucket — ``fn._cache_size()`` counts them."""
    from repro.core.sharing import normalize_sharing
    from repro.kernels import ops

    kernel_mode = ops.ddpg_kernel_mode()
    mega_mode = ops.episode_kernel_mode()
    sharing = normalize_sharing(sharing)
    if resilience is not None:
        from repro.core.resilience import normalize_resilience
        resilience = normalize_resilience(resilience)
    cell = sharing is not None and (sharing.shared_replay
                                    or sharing.averaging)
    if not cell:
        cell_size = 1
    obs_mask = None if obs_mask is None else tuple(
        float(v) for v in obs_mask)
    # policy joins the key: a DeploymentPolicy is hashable and baked into the
    # guarded build; policy=None keys (and builds) the exact unguarded
    # program, so guardrails-off tuners share one executable with pre-PR
    # code. sharing/cell_size/obs_mask normalize to (None, 1, None) when
    # every sharing mode is off, so sharing-off keys — and IS, by executable
    # identity — the exact same cached program. resilience follows the same
    # precedent: a ResiliencePolicy is hashable and baked into the resilient
    # build; resilience=None (the canonical off value) keys the exact
    # pre-resilience program.
    # mega_mode joins the key on the same precedent: None (REPRO_MEGAKERNEL
    # unset/off) keys — and IS, by cached-object identity — the exact
    # pre-megakernel program; any active mode compiles the fused-episode
    # formulation instead.
    key = (step_fn, space, cfg, actor_tx, critic_tx, learn, num_updates,
           fleet, devices, kernel_mode, mega_mode, policy, sharing, cell_size,
           obs_mask, resilience)
    if key in _EPISODE_CACHE:
        return _EPISODE_CACHE[key]
    if policy is not None and sharing is not None:
        raise ValueError(
            "experience sharing does not compose with DeploymentPolicy "
            "guardrails (the guarded step owns its own observe/learn path); "
            "run guarded fleets with sharing off")
    if policy is not None and resilience is not None:
        raise ValueError(
            "resilience does not compose with DeploymentPolicy guardrails "
            "(the guarded step owns its own learn path); run guarded "
            "fleets with resilience off")
    if cell and not fleet:
        raise ValueError("cell experience sharing requires the fleet engine")
    if mega_mode is not None:
        # the megakernel refuses (rather than silently degrades) every
        # policy layer that rewrites the scan body: those compose with the
        # SCAN engine, and composition pins live in tests/test_megakernel.py
        if policy is not None:
            raise ValueError(
                "the whole-episode megakernel does not compose with "
                "DeploymentPolicy guardrails (the guarded step owns its own "
                "observe/learn path); run guarded fleets with "
                "REPRO_MEGAKERNEL=off")
        if resilience is not None:
            raise ValueError(
                "the whole-episode megakernel does not compose with "
                "ResiliencePolicy self-healing (health runs in the scan "
                "body); run resilient fleets with REPRO_MEGAKERNEL=off")
        if cell:
            raise ValueError(
                "the whole-episode megakernel does not compose with cell "
                "experience sharing (the merged-FIFO cell body is a scan "
                "program); run sharing fleets with REPRO_MEGAKERNEL=off")
        if obs_mask is not None:
            raise ValueError(
                "the whole-episode megakernel does not support observation "
                "masking yet; run scoped-observation fleets with "
                "REPRO_MEGAKERNEL=off")
        if devices is not None and len(devices) > 1:
            raise ValueError(
                "the whole-episode megakernel runs single-device (its grid "
                "is the session axis); drop `devices` or set "
                "REPRO_MEGAKERNEL=off")
        episode = _build_mega_episode(step_fn, space, cfg, learn,
                                      num_updates, mega_mode, fleet)
        fn = jax.jit(episode, donate_argnums=(4,))
        _EPISODE_CACHE[key] = fn
        return fn
    if cell:
        episode = _build_cell_fleet_episode(
            step_fn, space, cfg, actor_tx, critic_tx, learn, num_updates,
            kernel_mode, sharing, cell_size, obs_mask, devices,
            resilience=resilience)
        fn = jax.jit(episode, donate_argnums=(4,))
        _EPISODE_CACHE[key] = fn
        return fn
    episode = _build_episode(step_fn, space, cfg, actor_tx, critic_tx, learn,
                             num_updates, kernel_mode=kernel_mode,
                             policy=policy, obs_mask=obs_mask,
                             resilience=resilience)
    if fleet:
        # session axis: params/w_vec/lo/span/carry stacked; xs — including
        # the warmup mask — are per-session so sessions of DIFFERENT ages
        # (FleetService join/leave churn) can ride one chunk program
        episode = jax.vmap(episode, in_axes=(0, 0, 0, 0, 0, (0, 0, 0)))
        if devices is not None and len(devices) > 1:
            from jax.sharding import Mesh, PartitionSpec as P
            try:
                from jax.experimental.shard_map import shard_map
            except ImportError:  # newer jax
                from jax import shard_map
            mesh = Mesh(np.array(devices), ("session",))
            episode = shard_map(
                episode, mesh=mesh,
                in_specs=(P("session"), P("session"), P("session"),
                          P("session"), P("session"),
                          (P("session"), P("session"), P("session"))),
                out_specs=P("session"), check_rep=False)
    # Donating the carry (learner params + opt state + FIFO storage — the
    # bulk of the program's operands) lets XLA reuse those buffers in place
    # instead of defensively copying them across the call boundary. Callers
    # never touch the input carry after the call: both run_*_episode_scan
    # entry points rebuild agent/env/buffer state from the RETURNED carry.
    fn = jax.jit(episode, donate_argnums=(4,))
    _EPISODE_CACHE[key] = fn
    return fn


def _consume_exploration(agent, steps: int, session: Optional[int] = None):
    """Pre-draw the episode's exploration from the agent's own host streams.

    Warmup plans and OU noise are state-independent, so consuming them up
    front leaves the agent's numpy RNG exactly where ``steps`` host-loop
    ``act()`` calls would — the key to host/scan equivalence. Returns
    (use_warmup [T], warmup_actions [T, m], noise [T, m]); advances
    ``steps_taken``."""
    m = agent.cfg.action_dim
    s0 = agent.steps_taken
    if session is None:
        plan, noise_src = agent._warmup_plan, agent.noise
    else:
        plan, noise_src = agent._warmup_plans[session], agent.noises[session]
    use_warmup = np.zeros(steps, bool)
    warmup = np.zeros((steps, m), np.float32)
    noise = np.zeros((steps, m), np.float32)
    for t in range(steps):
        if s0 + t < agent.warmup_steps:
            use_warmup[t] = True
            warmup[t] = plan[s0 + t]
        else:
            noise[t] = noise_src()
    if session is None:  # fleet callers advance the shared counter once
        agent.steps_taken += steps
    return use_warmup, warmup, noise


def _decode_trace(trace) -> EpisodeTrace:
    """Device trace -> host numpy, restart fixed point decoded to seconds."""
    trace = jax.tree_util.tree_map(np.asarray, trace)
    return trace._replace(restarts=decode_restarts(trace.restarts))


def run_episode_scan(env, agent, scalarizer, cur_metrics: dict, steps: int,
                 learn: bool = True, policy=None, guard=None, obs_mask=None,
                 resilience=None, health=None):
    """Run ``steps`` fused tuning iterations for one session.

    ``env`` must be a ``ModelEnv``. Mutates ``env`` (model state, last
    config) and ``agent`` (learner state, buffer, noise stream, steps_taken)
    exactly as the host loop would; returns the per-step trace as numpy
    (action indices + decoded restart seconds — see ``EpisodeTrace``).

    ``policy`` (``core.guardrails.DeploymentPolicy``) runs the guarded
    shadow/canary body instead; ``guard`` must then be the session's
    ``GuardState`` (``init_guard_state`` for a fresh session) and the return
    value becomes ``(GuardedEpisodeTrace, GuardState)`` — the updated guard
    carries to the next progressive run.

    ``resilience`` (``core.resilience.ResiliencePolicy``) runs the
    self-healing body instead; ``health`` must then be the session's
    ``HealthState`` (``init_health_state`` for a fresh session) and the
    return value becomes ``(ResilientEpisodeTrace, HealthState)``. An
    all-off policy normalizes to ``None`` (plain trace returned).
    """
    if resilience is not None:
        from repro.core.resilience import normalize_resilience
        resilience = normalize_resilience(resilience)
    model = env.model
    lo, span = metric_bounds(env.metric_specs, env.state_metrics)
    w_vec = scalarizer.weight_vector(env.state_metrics)
    state_vec = normalize_state(cur_metrics, env.metric_specs,
                                env.state_metrics)
    objective = np.float32(scalarizer.objective(cur_metrics))

    (bs, ba, br, bs2), _ = agent.buffer.storage()
    buffer = BufferState(
        s=jnp.asarray(bs), a=jnp.asarray(ba), r=jnp.asarray(br),
        s2=jnp.asarray(bs2),
        next_slot=jnp.asarray(agent.buffer._next, jnp.int32),
        size=jnp.asarray(len(agent.buffer), jnp.int32))
    xs = _consume_exploration(agent, steps)
    carry = EpisodeCarry(env.model_state, agent.state, buffer,
                         agent._learn_key, jnp.asarray(state_vec),
                         jnp.asarray(objective))
    if policy is not None:
        from repro.core.guardrails import GuardedCarry
        if guard is None:
            raise ValueError(
                "guarded runs need a GuardState (core.guardrails."
                "init_guard_state seeded from the live config)")
        carry = GuardedCarry(
            base=carry, guard=jax.tree_util.tree_map(jnp.asarray, guard))
    if resilience is not None:
        from repro.core.resilience import ResilientCarry
        if health is None:
            raise ValueError(
                "resilient runs need a HealthState (core.resilience."
                "init_health_state seeded from the learner state)")
        carry = ResilientCarry(
            base=carry, health=jax.tree_util.tree_map(jnp.asarray, health))

    fn = _compiled_episode(model.step_fn, env.param_space, agent.cfg,
                           agent._actor_tx, agent._critic_tx, learn,
                           agent.cfg.updates_per_step,
                           fleet=False, devices=None, policy=policy,
                           obs_mask=obs_mask, resilience=resilience)
    carry, trace = fn(model.params, jnp.asarray(w_vec), jnp.asarray(lo),
                      jnp.asarray(span), carry, xs)

    guard_out = health_out = None
    if resilience is not None:
        health_out = jax.tree_util.tree_map(np.asarray, carry.health)
        carry = carry.base
    if policy is not None:
        guard_out = jax.tree_util.tree_map(np.asarray, carry.guard)
        carry = carry.base
    env.model_state = carry.env_state
    agent.state = carry.ddpg
    agent._learn_key = carry.learn_key
    if learn:
        agent.buffer.set_storage(
            np.asarray(carry.buffer.s), np.asarray(carry.buffer.a),
            np.asarray(carry.buffer.r), np.asarray(carry.buffer.s2),
            int(carry.buffer.next_slot), int(carry.buffer.size))
    if policy is not None:
        return _decode_trace(trace), guard_out
    if resilience is not None:
        return _decode_trace(trace), health_out
    return _decode_trace(trace)


# ---------------------------------------------------------------------------
# Streaming chunked fleet runtime
# ---------------------------------------------------------------------------

#: stats recorded by the most recent ``run_fleet_episode_scan`` call — the
#: scaling benchmark and the compile-count regression tests read these.
_LAST_FLEET_STATS: dict = {}


def last_fleet_run_stats() -> dict:
    """Measurement record of the most recent fleet episode run.

    Keys: ``sessions``, ``chunk``, ``num_chunks``, ``overlap`` (whether the
    double-buffered chunk schedule was used), ``padded_sessions``,
    ``peak_device_bytes`` (resident jax-array bytes sampled at every chunk
    boundary while that chunk's carry and trace are still live — a measured
    lower bound that captures the persistent footprint the chunked runtime
    controls), ``executable_cache_size`` (compiled shape buckets held by the
    episode program) and ``program`` (the jitted callable itself, so tests
    can pin that two grid shapes shared one executable). ``staging`` holds
    the transfer-stream measurements from ``stream_chunks`` (``async``,
    ``stage_seconds``, ``stage_wait_seconds``, ``drain_seconds``,
    ``overlap_efficiency``)."""
    return dict(_LAST_FLEET_STATS)


def live_device_bytes() -> int:
    """Total bytes of all live jax arrays in the process (measured, via
    ``jax.live_arrays``). Process-wide: callers who want a clean reading
    should not hold unrelated device arrays."""
    return sum(int(getattr(x, "nbytes", 0)) for x in jax.live_arrays())


def resolve_chunk(n: int, chunk: Optional[int], num_devices: int = 1) -> int:
    """Effective chunk size: ``chunk`` (default: the whole fleet), capped at
    ``n`` and rounded up to a device-count multiple so ``shard_map`` always
    sees equal shards. The ragged remainder of the fleet — and the device
    remainder — are padded inside the LAST chunk only (never more than one
    chunk of padded work; asserted by the runner)."""
    c = int(chunk) if chunk is not None else int(n)
    if c <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    c = min(c, int(n))
    if num_devices > 1:
        c = int(math.ceil(c / num_devices) * num_devices)
    return c


def _pad_rows(x: np.ndarray, pad: int) -> np.ndarray:
    """Pad a [rows, ...] array by replicating its own last row ``pad`` times
    (the ragged-chunk filler: real session data, so the padded lanes run the
    same well-defined compute and are sliced off afterwards)."""
    if pad == 0:
        return x
    return np.concatenate([x, np.repeat(x[-1:], pad, axis=0)])


_STAGE_EXECUTOR = None


def _stage_executor():
    """Lazy singleton single-worker pool: the dedicated transfer stream.

    One worker by construction — staged chunks are consumed in submission
    order, so a single thread preserves the serial schedule's staging order
    while letting ``jax.device_put`` (which releases the GIL inside the
    runtime) overlap with the main thread's compute dispatch and drain."""
    global _STAGE_EXECUTOR
    if _STAGE_EXECUTOR is None:
        import concurrent.futures
        _STAGE_EXECUTOR = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-stage")
    return _STAGE_EXECUTOR


def _start_host_copy(tree):
    """Enqueue device->host copies for every leaf that supports it.

    ``copy_to_host_async`` schedules the D2H transfer to start the moment
    the producing computation finishes, so by the time ``drain`` calls
    ``np.asarray`` the bytes are already on the host (or in flight) instead
    of being fetched synchronously. Purely a prefetch hint: values are
    unchanged."""
    for x in jax.tree_util.tree_leaves(tree):
        cp = getattr(x, "copy_to_host_async", None)
        if cp is not None:
            cp()


def stream_chunks(call, stage, drain, num_chunks: int,
                  overlap: bool = True, supervisor=None, chaos=None,
                  staging: Optional[dict] = None):
    """Drive the chunked episode pipeline, optionally double-buffered.

    ``stage(ci)`` builds chunk ``ci``'s device operands (host -> device),
    ``call(args)`` dispatches the compiled episode program (returns device
    futures immediately), and ``drain(ci, out)`` blocks on chunk ``ci``'s
    results, copies them to host and decodes the compact trace.

    ``overlap=False`` is the strictly serial schedule: stage -> compute ->
    drain, one chunk at a time (the pre-overlap behaviour; one chunk of
    device state resident).

    ``overlap=True`` double-buffers with a dedicated transfer stream: while
    chunk k computes on device, chunk k+1's operands are staged host ->
    device on a single background worker thread (``_stage_executor``) and
    chunk k-1's results — whose device->host copies were enqueued via
    ``copy_to_host_async`` right after dispatch — are drained and decoded
    on the main thread. Transfer and host decode hide under compute, at the
    cost of at most TWO chunks of state in flight plus the staged chunk
    (still O(chunk)). Chunks cover disjoint sessions and staging produces
    the same arrays on any thread, so the schedule change cannot affect any
    session's results: outputs are bitwise identical to the serial
    schedule, which is pinned by tests/test_chunked_fleet.py and
    tests/test_megakernel.py.

    ``staging`` (optional dict) receives the transfer-stream measurements:
    ``async`` (whether the background stream ran), ``stage_seconds`` (time
    the worker spent building + staging operands), ``stage_wait_seconds``
    (time the main thread blocked waiting for a staged chunk),
    ``drain_seconds`` and ``overlap_efficiency`` (fraction of staging time
    hidden under compute: ``1 - wait / stage``).

    ``supervisor`` (a ``core.resilience.ChunkSupervisor``) runs the stream
    under host supervision: strictly serial (chunking/overlap are pure
    scheduling, so results are unchanged), each chunk wrapped in
    retry-with-exponential-backoff. The caller's host state is only mutated
    by ``drain`` — and each drain materializes device results BEFORE its
    first host write — so a failed attempt left the chunk's inputs intact
    and ``stage(ci)`` re-stages them deterministically: retries are bitwise
    invisible on success. A chunk exceeding ``watchdog_seconds`` wall clock
    counts as a stall in the returned stats. After ``max_retries`` the chunk
    raises ``ChunkFailure`` (``on_failure="raise"``) or is skipped with its
    host state untouched (``on_failure="skip"`` — the quarantine path).
    Returns a stats dict when supervised, else ``None``. ``chaos`` (an
    object with ``before_chunk(ci, attempt)``, e.g.
    ``envs.faults.HostChaos``) injects deterministic failures/stalls ahead
    of each staged attempt and requires a supervisor.
    """
    if chaos is not None and supervisor is None:
        raise ValueError("host chaos injection needs a ChunkSupervisor "
                         "(unsupervised streams have no retry path)")
    st = staging if staging is not None else {}
    st.update(**{"async": False, "stage_seconds": 0.0,
                 "stage_wait_seconds": 0.0, "drain_seconds": 0.0,
                 "overlap_efficiency": 0.0})
    if num_chunks <= 0:
        return None if supervisor is None else _empty_stream_stats()
    if supervisor is not None:
        return _stream_supervised(call, stage, drain, num_chunks,
                                  supervisor, chaos)

    def timed_stage(ci):
        t0 = time.perf_counter()
        args = stage(ci)
        return args, time.perf_counter() - t0

    def timed_drain(ci, out):
        t0 = time.perf_counter()
        drain(ci, out)
        st["drain_seconds"] += time.perf_counter() - t0

    if overlap:
        st["async"] = True
        ex = _stage_executor()
        inflight = None
        fut = ex.submit(timed_stage, 0)
        for ci in range(num_chunks):
            t0 = time.perf_counter()
            staged, sdt = fut.result()  # block until chunk ci is on device
            st["stage_wait_seconds"] += time.perf_counter() - t0
            st["stage_seconds"] += sdt
            out = call(staged)
            staged = None  # drop our handle; donation invalidated the carry
            _start_host_copy(out)  # D2H drains the moment compute finishes
            if ci + 1 < num_chunks:
                # host->device of chunk ci+1 on the transfer stream, under
                # chunk ci's compute and chunk ci-1's drain
                fut = ex.submit(timed_stage, ci + 1)
            if inflight is not None:
                timed_drain(*inflight)  # blocks on chunk ci-1, ci still runs
            inflight = (ci, out)
        if inflight is not None:
            timed_drain(*inflight)
    else:
        staged, sdt = timed_stage(0)
        st["stage_seconds"] += sdt
        for ci in range(num_chunks):
            out = call(staged)
            staged = None
            timed_drain(ci, out)
            if ci + 1 < num_chunks:
                staged, sdt = timed_stage(ci + 1)
                st["stage_seconds"] += sdt
        st["stage_wait_seconds"] = st["stage_seconds"]  # nothing hidden
    if st["stage_seconds"] > 0.0:
        st["overlap_efficiency"] = max(
            0.0, 1.0 - st["stage_wait_seconds"] / st["stage_seconds"])
    return None


def _empty_stream_stats() -> dict:
    return {"retries": 0, "watchdog_trips": 0, "failed_chunks": [],
            "chunk_seconds": []}


def _stream_supervised(call, stage, drain, num_chunks, supervisor, chaos):
    """Serial chunk schedule with per-chunk retry/backoff/watchdog (see
    ``stream_chunks``)."""
    from repro.core.resilience import ChunkFailure, normalize_supervisor

    sup = normalize_supervisor(supervisor)
    stats = _empty_stream_stats()
    for ci in range(num_chunks):
        attempt = 0
        while True:
            t0 = time.perf_counter()
            try:
                if chaos is not None:
                    chaos.before_chunk(ci, attempt)
                out = call(stage(ci))
                drain(ci, out)
            except Exception as err:  # noqa: BLE001 — retry any chunk fault
                if attempt >= sup.max_retries:
                    stats["failed_chunks"].append(ci)
                    if sup.on_failure == "skip":
                        break  # quarantine: host state untouched, continue
                    raise ChunkFailure(ci, attempt + 1, err) from err
                time.sleep(sup.backoff_seconds
                           * sup.backoff_multiplier ** attempt)
                attempt += 1
                stats["retries"] += 1
                continue
            elapsed = time.perf_counter() - t0
            stats["chunk_seconds"].append(elapsed)
            if (sup.watchdog_seconds is not None
                    and elapsed > sup.watchdog_seconds):
                stats["watchdog_trips"] += 1
            break
    return stats


def run_fleet_episode_scan(envs: Sequence, agent, scalarizers: Sequence,
                       cur_metrics: Sequence, steps: int, learn: bool = True,
                       devices: Optional[Sequence] = None,
                       chunk: Optional[int] = None,
                       overlap: bool = True, policy=None, guard=None,
                       sharing=None, cell_size: int = 1, obs_mask=None,
                       resilience=None, health=None, supervisor=None,
                       chaos=None):
    """Fleet variant: N sessions' episodes streamed through one compiled
    chunk program. Trace leaves are [N, T, ...] host numpy arrays.

    ``chunk=C`` executes the fleet as ``ceil(N / C)`` chunks of exactly C
    sessions (default: one chunk of all N — the monolithic schedule). All
    chunks — including every other grid shape run at the same C — share ONE
    compiled, donated episode executable; the fleet's state lives in host
    numpy between chunks, so peak device memory is O(C·T). A ragged last
    chunk (and, with ``devices``, the device remainder) is padded by
    replicating the chunk's own last session; padded work never exceeds one
    chunk and padded results are sliced off. Per-session behaviour is
    independent of both the chunk size and the device count: every session's
    PRNG keys derive from its own seed, never from its placement.

    ``overlap=True`` (default) double-buffers the chunk stream: while chunk
    k computes, chunk k+1's state is staged host -> device and chunk k-1's
    trace is decoded on the host (``stream_chunks``). Pure scheduling — the
    compiled program and its inputs are unchanged, so results are bitwise
    the serial schedule's; peak device residency is at most two chunks.

    ``policy``/``guard`` run the guarded shadow/canary body: ``guard`` is a
    stacked [N, ...] ``GuardState`` (``init_fleet_guard_state``); the guard
    rides the chunk carry like all fleet state and the return value becomes
    ``(GuardedEpisodeTrace, GuardState)``.

    ``sharing``/``cell_size``/``obs_mask`` enable cross-session experience
    sharing (``core.sharing``): sessions [i*cs, (i+1)*cs) form cell i.
    Cells never span chunks — the chunk size is rounded up to a cell
    multiple — so the cell program's state is self-contained per chunk and
    chunking stays pure scheduling. With shared replay the agent's buffer
    must be grouped (``BatchedReplayBuffer(groups=...)``); its cell-level
    storage is staged and drained at group granularity.

    ``resilience``/``health`` run the self-healing body
    (``core.resilience``): ``health`` is a stacked [N, ...] ``HealthState``
    (``init_fleet_health_state``); it rides the chunk carry like all fleet
    state and the return value becomes ``(ResilientEpisodeTrace,
    HealthState)``. Composes with sharing (per-lane health in the cell
    body), never with guardrails.

    ``supervisor``/``chaos`` put the chunk stream under host supervision
    (retry/backoff/watchdog — see ``stream_chunks``); the supervised run's
    stats land in ``last_fleet_run_stats()["supervisor"]``.
    """
    from repro.core.sharing import normalize_sharing

    sharing = normalize_sharing(sharing)
    if resilience is not None:
        from repro.core.resilience import normalize_resilience
        resilience = normalize_resilience(resilience)
    cell = sharing is not None and (sharing.shared_replay
                                    or sharing.averaging)
    cs = int(cell_size) if cell else 1
    shared_replay = cell and sharing.shared_replay
    models = [e.model for e in envs]
    step_fns = {m.step_fn for m in models}
    if len(step_fns) != 1:
        raise ValueError(
            "fleet sessions must share one env model structure (same space / "
            "model class); mixed fleets need the host engine")
    n = len(envs)
    space = envs[0].param_space
    devices = tuple(devices) if devices else None
    ndev = len(devices) if devices else 1
    c = resolve_chunk(n, chunk, ndev)
    if cell:
        if n % cs != 0:
            raise ValueError(
                f"experience sharing needs whole cells: {n} sessions is not "
                f"a multiple of cell_size={cs}")
        # cells never span chunks: round the chunk up to a cell multiple
        # (and keep the device-count multiple resolve_chunk established)
        step_mult = cs * ndev if ndev > 1 else cs
        c = int(math.ceil(c / step_mult) * step_mult)
        c = min(c, int(math.ceil(n / step_mult) * step_mult))
    num_chunks = -(-n // c)
    pad_total = num_chunks * c - n
    # no padded session's work exceeds one chunk: padding exists only to
    # square off the LAST chunk (and the device remainder inside it)
    assert pad_total < c, (pad_total, c, n)

    def stack_np(trees):  # host-side stack: plain numpy, no device residency
        return jax.tree_util.tree_map(
            lambda *xs: np.stack([np.asarray(x) for x in xs]), *trees)

    # -- full-fleet host staging (numpy; written back chunk by chunk) -------
    params = stack_np([m.params for m in models])
    env_states = stack_np([e.model_state for e in envs])
    ddpg_states = jax.tree_util.tree_map(np.array, agent.states)
    lo, span = metric_bounds(envs[0].metric_specs, envs[0].state_metrics)
    k = lo.shape[0]
    lo = np.broadcast_to(lo, (n, k))
    span = np.broadcast_to(span, (n, k))
    w_vec = np.stack([sc.weight_vector(e.state_metrics)
                      for sc, e in zip(scalarizers, envs)])
    state_vecs = np.stack([
        normalize_state(mtr, e.metric_specs, e.state_metrics)
        for mtr, e in zip(cur_metrics, envs)])
    objectives = np.array([np.float32(sc.objective(mtr))
                           for sc, mtr in zip(scalarizers, cur_metrics)],
                          np.float32)

    if shared_replay:
        # cell-level storage: [G, capacity, ...] arrays + per-group cursors,
        # staged/drained at group granularity (cells never span chunks)
        if agent.buffer.groups is None:
            raise ValueError(
                "shared replay needs a grouped BatchedReplayBuffer "
                "(FleetAgent(..., replay_groups=...))")
        (bs, ba, br, bs2), next_slots, sizes = agent.buffer.grouped_storage()
        buf_np = tuple(np.array(x) for x in (bs, ba, br, bs2))
        next_slots = np.asarray(next_slots, np.int32)
        sizes = np.asarray(sizes, np.int32)
    else:
        (bs, ba, br, bs2), sizes = agent.buffer.storage()
        buf_np = tuple(np.array(x) for x in (bs, ba, br, bs2))
        next_slots = np.full((n,), agent.buffer._next, np.int32)
        sizes = np.array(sizes, np.int32)
    learn_keys = np.array(agent._learn_keys)

    s0 = agent.steps_taken
    m_dim = agent.cfg.action_dim
    use_warmup = np.zeros((n, steps), bool)
    warmup = np.zeros((n, steps, m_dim), np.float32)
    noise = np.zeros((n, steps, m_dim), np.float32)
    for t in range(steps):
        if s0 + t < agent.warmup_steps:
            use_warmup[:, t] = True
            warmup[:, t] = agent._warmup_plans[:, s0 + t]
        else:
            noise[:, t] = np.stack([nz() for nz in agent.noises])
    agent.steps_taken += steps

    if cell:
        # host-computed sharing inputs: the averaging cadence fires on the
        # fleet's shared step clock (so it survives chunking and progressive
        # runs), and every real session is active (padding lanes replicate
        # whole cells and are sliced off before anything reads them)
        avg_now = np.zeros((n, steps), bool)
        if sharing.averaging:
            for t in range(steps):
                avg_now[:, t] = ((s0 + t + 1) % sharing.avg_every) == 0
        active = np.ones((n, steps), bool)

    # -- preallocated host trace buffers (the stream targets) ---------------
    base_fields = dict(
        action_idx=np.zeros((n, steps, space.dim), space.index_dtype()),
        metrics=np.zeros((n, steps, k), np.float32),
        rewards=np.zeros((n, steps), np.float32),
        objectives=np.zeros((n, steps), np.float32),
        restarts=np.zeros((n, steps), np.float32))
    if policy is not None:
        from repro.core.guardrails import GuardedCarry, GuardedEpisodeTrace
        if guard is None:
            raise ValueError(
                "guarded fleet runs need a stacked GuardState "
                "(core.guardrails.init_fleet_guard_state)")
        # fresh host arrays: the caller's guard is never mutated in place
        guard = jax.tree_util.tree_map(np.array, guard)
        out = GuardedEpisodeTrace(
            **base_fields,
            guard_events=np.zeros((n, steps), np.uint8),
            shadow_objectives=np.zeros((n, steps), np.float32))
    elif resilience is not None:
        from repro.core.resilience import (ResilientCarry,
                                           ResilientEpisodeTrace)
        if health is None:
            raise ValueError(
                "resilient fleet runs need a stacked HealthState "
                "(core.resilience.init_fleet_health_state)")
        # fresh host arrays: the caller's health is never mutated in place
        health = jax.tree_util.tree_map(np.array, health)
        out = ResilientEpisodeTrace(
            **base_fields, health_events=np.zeros((n, steps), np.uint8))
    else:
        out = EpisodeTrace(**base_fields)

    fn = _compiled_episode(models[0].step_fn, space, agent.cfg,
                           agent._actor_tx, agent._critic_tx, learn,
                           agent.cfg.updates_per_step,
                           fleet=True, devices=devices, policy=policy,
                           sharing=sharing, cell_size=cs, obs_mask=obs_mask,
                           resilience=resilience)

    peak = [live_device_bytes()]

    def stage(ci):
        a, b = ci * c, min(n, (ci + 1) * c)
        pad = c - (b - a)

        def chunk_of(tree):
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(_pad_rows(x[a:b], pad)), tree)

        def group_chunk_of(tree):
            # cell-granular slice: chunk ci covers whole groups
            ga, gb = a // cs, b // cs
            gpad = pad // cs
            return jax.tree_util.tree_map(
                lambda x: jax.device_put(_pad_rows(x[ga:gb], gpad)), tree)

        buf_of = group_chunk_of if shared_replay else chunk_of
        carry = EpisodeCarry(
            env_state=chunk_of(env_states),
            ddpg=chunk_of(ddpg_states),
            buffer=BufferState(
                s=buf_of(buf_np[0]), a=buf_of(buf_np[1]),
                r=buf_of(buf_np[2]), s2=buf_of(buf_np[3]),
                next_slot=buf_of(next_slots), size=buf_of(sizes)),
            learn_key=chunk_of(learn_keys),
            state_vec=chunk_of(state_vecs),
            objective=chunk_of(objectives))
        if cell:
            xs = (chunk_of(use_warmup), chunk_of(warmup), chunk_of(noise),
                  chunk_of(avg_now), chunk_of(active))
        else:
            xs = (chunk_of(use_warmup), chunk_of(warmup), chunk_of(noise))
        if policy is not None:
            carry = GuardedCarry(base=carry, guard=chunk_of(guard))
        elif resilience is not None:
            carry = ResilientCarry(base=carry, health=chunk_of(health))
        args = (chunk_of(params), chunk_of(w_vec), chunk_of(lo),
                chunk_of(span), carry, xs)
        # sample peak while the freshly staged operands are live: under
        # async overlap this is the window where the in-flight transfer
        # buffers coexist with the computing chunk — invisible to the
        # drain-side sample, which runs after they were consumed
        peak[0] = max(peak[0], live_device_bytes())
        return args

    def call(args):
        return fn(*args)

    def drain(ci, out_pair):
        carry, trace = out_pair
        a, b = ci * c, min(n, (ci + 1) * c)
        cnt = b - a

        # peak sampled while this chunk's carry + trace (and, under overlap,
        # the next chunk's staged operands) are still live — the resident
        # footprint the O(chunk) contract is about
        peak[0] = max(peak[0], live_device_bytes())

        # stream the chunk's trace into the host buffers (np.asarray forces
        # the computation and copies off-device)
        out.action_idx[a:b] = np.asarray(trace.action_idx)[:cnt]
        out.metrics[a:b] = np.asarray(trace.metrics)[:cnt]
        out.rewards[a:b] = np.asarray(trace.rewards)[:cnt]
        out.objectives[a:b] = np.asarray(trace.objectives)[:cnt]
        out.restarts[a:b] = decode_restarts(np.asarray(trace.restarts)[:cnt])
        if policy is not None:
            out.guard_events[a:b] = np.asarray(trace.guard_events)[:cnt]
            out.shadow_objectives[a:b] = np.asarray(
                trace.shadow_objectives)[:cnt]
        elif resilience is not None:
            out.health_events[a:b] = np.asarray(trace.health_events)[:cnt]

        # write the chunk's carry back into the fleet's host state
        def write_back(dst_tree, src_tree):
            jax.tree_util.tree_map(
                lambda d, s: d.__setitem__(slice(a, b), np.asarray(s)[:cnt]),
                dst_tree, src_tree)

        if policy is not None:
            write_back(guard, carry.guard)
            carry = carry.base
        elif resilience is not None:
            write_back(health, carry.health)
            carry = carry.base
        write_back(env_states, carry.env_state)
        write_back(ddpg_states, carry.ddpg)
        if shared_replay:
            # cell-granular write-back: the chunk carried whole groups
            ga, gb = a // cs, b // cs
            gcnt = gb - ga
            for dst, src in zip(buf_np, (carry.buffer.s, carry.buffer.a,
                                         carry.buffer.r, carry.buffer.s2)):
                dst[ga:gb] = np.asarray(src)[:gcnt]
            next_slots[ga:gb] = np.asarray(carry.buffer.next_slot)[:gcnt]
            sizes[ga:gb] = np.asarray(carry.buffer.size)[:gcnt]
        else:
            write_back(buf_np[0], carry.buffer.s)
            write_back(buf_np[1], carry.buffer.a)
            write_back(buf_np[2], carry.buffer.r)
            write_back(buf_np[3], carry.buffer.s2)
            next_slots[a:b] = np.asarray(carry.buffer.next_slot)[:cnt]
            sizes[a:b] = np.asarray(carry.buffer.size)[:cnt]
        learn_keys[a:b] = np.asarray(carry.learn_key)[:cnt]

    staging_stats: dict = {}
    stream_stats = stream_chunks(call, stage, drain, num_chunks,
                                 overlap=overlap, supervisor=supervisor,
                                 chaos=chaos, staging=staging_stats)

    _LAST_FLEET_STATS.clear()
    _LAST_FLEET_STATS.update(
        sessions=n, chunk=c, num_chunks=num_chunks, overlap=overlap,
        padded_sessions=pad_total, peak_device_bytes=peak[0],
        executable_cache_size=fn._cache_size(), program=fn,
        cell_size=cs, sharing=sharing, staging=staging_stats)
    if stream_stats is not None:
        _LAST_FLEET_STATS["supervisor"] = stream_stats

    for e, st in zip(envs, _unstack(env_states, n)):
        e.model_state = st
    agent.states = ddpg_states
    agent._learn_keys = jnp.asarray(learn_keys)
    if learn and shared_replay:
        agent.buffer.set_storage(*buf_np, next_slots, sizes)
    elif learn:
        agent.buffer.set_storage(*buf_np, int(next_slots[0]), int(sizes[0]))
    if policy is not None:
        return out, guard
    if resilience is not None:
        return out, health
    return out


def precompile_fleet_episode(env, agent, steps: int, sessions: int,
                             chunk: Optional[int] = None,
                             devices: Optional[Sequence] = None,
                             learn: bool = True, policy=None):
    """Warm the chunked fleet episode executable ahead of ``run()``.

    Executes ONE dummy chunk episode (zero exploration, throwaway copies of
    session 0's state) at exactly the shapes/dtypes the real run will use,
    so the real run's chunks all hit the already-compiled program — and,
    with ``enable_persistent_compilation_cache`` active, later processes
    hit the on-disk cache. Agent, env and every RNG stream are untouched.
    Returns the jitted episode program."""
    model = env.model
    space = env.param_space
    cfg = agent.cfg
    devices = tuple(devices) if devices else None
    ndev = len(devices) if devices else 1
    c = resolve_chunk(sessions, chunk, ndev)

    def tile(x):
        x = np.asarray(x)
        return jnp.asarray(np.broadcast_to(x[None], (c,) + x.shape))

    (bs, ba, br, bs2), _ = agent.buffer.storage()
    lo, span = metric_bounds(env.metric_specs, env.state_metrics)
    k, m = lo.shape[0], cfg.action_dim
    carry = EpisodeCarry(
        env_state=jax.tree_util.tree_map(tile, env.model_state),
        ddpg=jax.tree_util.tree_map(
            lambda x: jnp.asarray(np.repeat(np.asarray(x)[:1], c, axis=0)),
            agent.states),
        buffer=BufferState(
            s=jnp.zeros((c,) + bs.shape[1:], bs.dtype),
            a=jnp.zeros((c,) + ba.shape[1:], ba.dtype),
            r=jnp.zeros((c,) + br.shape[1:], br.dtype),
            s2=jnp.zeros((c,) + bs2.shape[1:], bs2.dtype),
            next_slot=jnp.zeros((c,), jnp.int32),
            size=jnp.zeros((c,), jnp.int32)),
        learn_key=jnp.asarray(
            np.zeros((c,) + np.asarray(agent._learn_keys).shape[1:],
                     np.asarray(agent._learn_keys).dtype)),
        state_vec=jnp.zeros((c, k), jnp.float32),
        objective=jnp.zeros((c,), jnp.float32))
    if policy is not None:
        from repro.core.guardrails import GuardedCarry, GuardState
        carry = GuardedCarry(base=carry, guard=GuardState(
            live_action=jnp.zeros((c, m), jnp.float32),
            fallback_action=jnp.zeros((c, m), jnp.float32),
            fallback_obj=jnp.zeros((c,), jnp.float32),
            budget_spent=jnp.zeros((c,), jnp.float32),
            watch_left=jnp.zeros((c,), jnp.int32),
            promotions=jnp.zeros((c,), jnp.int32),
            rollbacks=jnp.zeros((c,), jnp.int32)))
    xs = (jnp.zeros((c, steps), bool), jnp.zeros((c, steps, m), jnp.float32),
          jnp.zeros((c, steps, m), jnp.float32))

    fn = _compiled_episode(model.step_fn, space, cfg, agent._actor_tx,
                           agent._critic_tx, learn, cfg.updates_per_step,
                           fleet=True, devices=devices, policy=policy)
    outs = fn(jax.tree_util.tree_map(tile, model.params),
              tile(np.zeros(k, np.float32)), tile(lo), tile(span), carry, xs)
    jax.block_until_ready(outs)
    return fn


def episode_cache_stats() -> dict:
    """Compile-reuse accounting for the episode engine: how many distinct
    episode programs exist (one per (space, cfg, engine-shape) build) and
    how many compiled shape buckets they hold in total."""
    return {
        "programs": len(_EPISODE_CACHE),
        "executables": sum(fn._cache_size()
                           for fn in _EPISODE_CACHE.values()),
    }


def enable_persistent_compilation_cache(path: Optional[str] = None) -> str:
    """Point JAX's persistent compilation cache at ``path`` (default:
    ``$REPRO_COMPILE_CACHE_DIR`` or ``~/.cache/repro-jax-cache``).

    Repeated processes — grid sweeps, back-to-back example runs, CI lanes —
    then deserialize the episode executable instead of recompiling it.
    Call BEFORE the first compilation of the process (compiles that already
    happened are not retro-cached). Returns the cache directory."""
    path = (path or os.environ.get("REPRO_COMPILE_CACHE_DIR")
            or os.path.join(os.path.expanduser("~"), ".cache",
                            "repro-jax-cache"))
    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # cache everything: the episode program is worth persisting no matter
    # how quickly this particular box compiled it
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    return path


def _unstack(tree, n: int) -> list:
    return [jax.tree_util.tree_map(lambda x: x[i], tree) for i in range(n)]


def default_devices() -> list:
    """All local devices — the default fleet sharding axis."""
    return list(jax.devices())
