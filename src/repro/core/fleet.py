"""Fleet tuning: many Magpie sessions as one fused JAX program.

The paper's headline numbers (91.8% average throughput gain, Fig. 4/5) come
from repeating whole tuning sessions across workloads, objectives and seeds.
This module makes that axis first-class:

  * ``FleetAgent`` — N independent DDPG learners (different seeds) stacked on
    a leading session axis. Init, acting and the entire
    ``updates_per_step``-deep learning loop are vmapped, so one ``learn()``
    call is ONE XLA computation for the whole fleet (``fleet_learn_scan``)
    instead of N x 96 separate dispatches.
  * ``FleetTuner`` — runs a seeds x workloads x objectives grid of tuning
    sessions concurrently against per-session environments, with a vectorized
    response-surface fast path for ``LustreSimEnv`` fleets
    (``batch_mean_performance``). Returns one ``TuningResult`` per session
    plus aggregate gain statistics mirroring the paper's reporting.

Sessions are fully independent: a fleet of one reproduces the single
``Tuner``/``MagpieAgent`` pair exactly (same seed, same trajectory) — the
fleet axis buys throughput, never changes the algorithm.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import lhs_warmup_plan
from repro.core.ddpg import (
    DDPGConfig,
    OUNoise,
    fleet_act,
    fleet_init,
    fleet_learn_scan,
)
from repro.core.replay_buffer import BatchedReplayBuffer
from repro.core.scalarization import Scalarizer, normalize_state
from repro.core.tuner import (
    StepRecord,
    TuningResult,
    evaluate_config,
    recommend_final,
)


class FleetAgent:
    """N ``MagpieAgent``-equivalent learners batched over a session axis.

    Session i is seeded exactly like ``MagpieAgent(cfg, seed=seeds[i])``: the
    same network init key, warmup plan, OU-noise stream and on-device
    minibatch-sampling key — so per-session behaviour is independent of the
    fleet it runs in.

    ``store="host"`` keeps the stacked learner state and replay storage in
    host numpy (initialized in device chunks of ``init_chunk`` sessions) —
    the streaming chunked episode runtime stages one chunk at a time, so a
    1024-session fleet never owns O(N) device memory. Per-session values are
    identical to the device store: JAX RNG is deterministic per key and the
    vmap width never changes what a key produces. ``replay_dtype``
    (default float32) is the replay *storage* precision — see
    ``BatchedReplayBuffer``; bf16 is opt-in and changes learning
    trajectories, so fleet-of-1 parity holds only at the default.
    """

    def __init__(self, cfg: DDPGConfig, seeds: Sequence[int],
                 buffer_capacity: int = 64, warmup_steps: int = 8,
                 store: str = "device", replay_dtype=jnp.float32,
                 init_chunk: Optional[int] = None, replay_groups=None):
        if not seeds:
            raise ValueError("need at least one session seed")
        if store not in ("device", "host"):
            raise ValueError(f"unknown store {store!r}; use 'device' or 'host'")
        self.cfg = cfg
        self.seeds = list(seeds)
        self.num_sessions = len(self.seeds)
        self.warmup_steps = warmup_steps
        self.store = store
        keys = [jax.random.PRNGKey(s) for s in self.seeds]
        if store == "host":
            # init in device chunks, stream to host: peak device memory for
            # init is O(init_chunk), matching the chunked episode runtime
            ic = int(init_chunk) if init_chunk else min(64, self.num_sessions)
            parts = []
            for i0 in range(0, self.num_sessions, ic):
                states, txs = fleet_init(jnp.stack(keys[i0:i0 + ic]), cfg)
                parts.append(jax.tree_util.tree_map(np.asarray, states))
            self.states = jax.tree_util.tree_map(
                lambda *xs: np.concatenate(xs), *parts)
            self._actor_tx, self._critic_tx = txs
        else:
            self.states, (self._actor_tx, self._critic_tx) = fleet_init(
                jnp.stack(keys), cfg)
        # replay_groups (one cell id per session) merges each cell's replay
        # into a single shared FIFO window — see core.sharing / the grouped
        # BatchedReplayBuffer. None keeps N independent buffers (default).
        self.buffer = BatchedReplayBuffer(
            self.num_sessions, buffer_capacity, cfg.state_dim, cfg.action_dim,
            storage_dtype=replay_dtype,
            storage_backend="host" if store == "host" else "device",
            groups=replay_groups)
        self.noises = [OUNoise(cfg.action_dim, seed=s + 1) for s in self.seeds]
        self._learn_keys = jnp.stack(
            [jax.random.PRNGKey(s + 3) for s in self.seeds])
        self.steps_taken = 0
        self.last_metrics: dict = {}
        # Per-session Latin-hypercube warmup plans (MagpieAgent's, per seed).
        self._warmup_plans = np.stack([
            lhs_warmup_plan(np.random.default_rng(s + 2), warmup_steps,
                            cfg.action_dim)
            for s in self.seeds])  # [N, warmup_steps, action_dim]

    # -- acting -------------------------------------------------------------

    def act(self, states: np.ndarray, explore: bool = True) -> np.ndarray:
        """Actions [N, m] for per-session states [N, k] (lockstep fleet step)."""
        if explore and self.steps_taken < self.warmup_steps:
            a = self._warmup_plans[:, self.steps_taken].copy()
        else:
            a = np.asarray(fleet_act(
                self.states.actor, jnp.asarray(states, jnp.float32)))
            if explore:
                a = a + np.stack([noise() for noise in self.noises])
        self.steps_taken += 1
        return np.clip(a, 0.0, 1.0).astype(np.float32)

    # -- learning -----------------------------------------------------------

    def observe(self, states, actions, rewards, next_states) -> None:
        """One transition per session; each argument has a leading [N] axis."""
        self.buffer.add(states, actions, rewards, next_states)

    def learn(self, updates: Optional[int] = None) -> dict:
        """All sessions' ``updates`` gradient steps in one jitted dispatch.

        Returns {metric: [N] array} — each session's value from its last
        minibatch update.
        """
        if len(self.buffer) == 0:
            # host-path guard (see MagpieAgent.learn): never hand size == 0
            # to minibatch sampling; fleet_learn_scan raises on direct misuse
            return {}
        n = self.cfg.updates_per_step if updates is None else updates
        if n <= 0:
            return {}
        split = jax.vmap(jax.random.split)(self._learn_keys)  # [N, 2, key]
        self._learn_keys, keys = split[:, 0], split[:, 1]
        data, sizes = self.buffer.storage()
        self.states, metrics = fleet_learn_scan(
            self.states, data, sizes, keys, self.cfg,
            self._actor_tx, self._critic_tx, n,
        )
        self.last_metrics = {k: np.asarray(v[:, -1]) for k, v in metrics.items()}
        return self.last_metrics


@dataclasses.dataclass
class FleetResult:
    """Per-session results + the paper's aggregate reporting (Fig. 4/5)."""

    results: list   # TuningResult per session
    labels: list    # human-readable session labels, parallel to ``results``
    wall_seconds: float

    def gains(self, metric: str) -> np.ndarray:
        """Proportional best-vs-default gain per session for ``metric``."""
        return np.array([r.gain(metric) for r in self.results])

    def summary(self, metric: str = "throughput") -> dict:
        """Aggregate gain statistics across sessions (mean/percentiles)."""
        g = self.gains(metric)
        return {
            "sessions": len(g),
            "mean": float(g.mean()),
            "std": float(g.std()),
            "min": float(g.min()),
            "p25": float(np.percentile(g, 25)),
            "p50": float(np.percentile(g, 50)),
            "p75": float(np.percentile(g, 75)),
            "max": float(g.max()),
        }

    def by_label(self, label: str) -> TuningResult:
        return self.results[self.labels.index(label)]


def replay_compact_trace(env, trace, i: int, *, start: int, per_step: float,
                         prev_config: dict, best_objective: float,
                         restart_seconds: float = 0.0,
                         finite_baseline: bool = False) -> dict:
    """Reconstruct session ``i``'s decision history from a compact trace.

    The scan engine returns action INDICES and fixed-point restarts; this
    decodes them into the exact ``StepRecord`` stream the host engine would
    have produced — shared by ``FleetTuner._run_scan`` and the persistent
    ``FleetService`` so both replay one trace the same way, bit for bit.
    Mutates ``env`` exactly like the host loop: appends ``restart_events``
    and sets ``_last_config``.

    Returns a dict: ``records`` (list of StepRecord), ``cur_config`` /
    ``cur_metrics`` (the post-episode session state; ``cur_metrics`` is None
    for an empty trace), ``best`` (None, or the new best
    config/metrics/objective beating ``best_objective``) and
    ``restart_seconds`` (the running total, accumulated step-by-step from
    the passed-in value so the float addition order matches the host loop).

    ``finite_baseline=True`` (the resilient engines) mirrors the in-graph
    carry's sanitization: ``cur_metrics`` is the LAST all-finite metrics row
    — a corrupted reading stays raw in the records but never becomes the
    session's observation baseline — and ``None`` when the whole trace is
    corrupted (the caller keeps its previous finite metrics).
    """
    steps = trace.rewards.shape[1]
    configs = env.param_space.configs_from_indices(trace.action_idx[i])
    names = env.state_metrics
    records, best = [], None
    for t in range(steps):
        metrics = {n: float(v) for n, v in zip(names, trace.metrics[i, t])}
        objective = float(trace.objectives[i, t])
        restart = float(trace.restarts[i, t])
        restart_seconds += restart
        if restart > 0:
            env.restart_events.append(
                (env._scope(configs[t], prev_config), restart))
        if objective > (best["objective"] if best else best_objective):
            best = {"objective": objective, "config": dict(configs[t]),
                    "metrics": dict(metrics)}
        records.append(StepRecord(
            step=start + t, config=configs[t], metrics=metrics,
            objective=objective, reward=float(trace.rewards[i, t]),
            restart_seconds=restart, action_seconds=per_step,
            learn_seconds=0.0,
        ))
        prev_config = configs[t]
    cur_config = configs[-1] if steps else prev_config
    cur_metrics = None
    if steps:
        last = steps - 1
        if finite_baseline:
            finite = np.isfinite(trace.metrics[i]).all(axis=1)
            last = int(np.nonzero(finite)[0][-1]) if finite.any() else None
        if last is not None:
            cur_metrics = {n: float(v)
                           for n, v in zip(names, trace.metrics[i, last])}
    env._last_config = dict(cur_config)
    return {"records": records, "cur_config": cur_config,
            "cur_metrics": cur_metrics, "best": best,
            "restart_seconds": restart_seconds}


class FleetTuner:
    """N concurrent Magpie tuning sessions sharing one fused learner.

    Each session owns its environment and scalarizer (workloads and objectives
    may differ across the fleet); the agent is a ``FleetAgent`` whose session i
    mirrors ``MagpieAgent(cfg, seed=seeds[i])``. The loop is the Fig. 1 loop
    of ``core.tuner.Tuner``, executed in lockstep across sessions, with all
    N x ``updates_per_step`` gradient steps per fleet step issued as a single
    XLA computation.
    """

    def __init__(self, envs: Sequence, scalarizers: Sequence[Scalarizer],
                 agent: FleetAgent, eval_runs: int = 3, labels=None,
                 vectorized: Optional[bool] = None, engine: str = "host",
                 devices: Optional[Sequence] = None,
                 chunk: Optional[int] = None, overlap: bool = True,
                 policy=None, sharing=None, cell_size: int = 1,
                 resilience=None, supervisor=None, chaos=None):
        from repro.core.sharing import normalize_sharing
        if not (len(envs) == len(scalarizers) == agent.num_sessions):
            raise ValueError("envs, scalarizers and agent sessions must align")
        if engine not in ("host", "scan"):
            raise ValueError(f"unknown engine {engine!r}; use 'host' or 'scan'")
        if policy is not None and engine != "scan":
            raise ValueError(
                "DeploymentPolicy guardrails run inside the episode scan; "
                "use engine='scan' (the host loop has no shadow/canary body)")
        sharing = normalize_sharing(sharing)
        if sharing is not None and engine != "scan":
            raise ValueError(
                "experience sharing runs inside the episode scan; use "
                "engine='scan' (the host loop keeps sessions independent)")
        if sharing is not None and policy is not None:
            raise ValueError(
                "experience sharing does not compose with DeploymentPolicy "
                "guardrails; run guarded fleets with sharing off")
        if resilience is not None:
            from repro.core.resilience import normalize_resilience
            resilience = normalize_resilience(resilience)
        if resilience is not None and engine != "scan":
            raise ValueError(
                "ResiliencePolicy runs inside the episode scan; use "
                "engine='scan' (the host loop has no snapshot/reset body)")
        if resilience is not None and policy is not None:
            raise ValueError(
                "resilience does not compose with DeploymentPolicy "
                "guardrails; run guarded fleets without a ResiliencePolicy")
        if supervisor is not None:
            from repro.core.resilience import normalize_supervisor
            supervisor = normalize_supervisor(supervisor)
        if (supervisor is not None or chaos is not None) and engine != "scan":
            raise ValueError(
                "chunk supervision is a scan-engine feature (the host loop "
                "has no chunk stream to supervise)")
        cell_modes = sharing is not None and (sharing.shared_replay
                                              or sharing.averaging)
        self.cell_size = int(cell_size) if cell_modes else 1
        if cell_modes and len(envs) % self.cell_size != 0:
            raise ValueError(
                f"experience sharing needs whole cells: {len(envs)} sessions "
                f"is not a multiple of cell_size={self.cell_size}")
        if (sharing is not None and sharing.shared_replay
                and agent.buffer.groups is None):
            raise ValueError(
                "shared replay needs a grouped replay buffer — build the "
                "fleet with from_grid(sharing=...) or pass "
                "FleetAgent(..., replay_groups=...)")
        self.sharing = sharing
        if engine == "scan" and any(getattr(e, "model", None) is None
                                    for e in envs):
            raise ValueError(
                "engine='scan' needs pure-model environments (ModelEnv); "
                "build the fleet with from_grid(engine='scan') or pass "
                "ModelEnv instances")
        if devices is not None and engine != "scan":
            raise ValueError("devices= sharding is a scan-engine feature")
        if chunk is not None and engine != "scan":
            raise ValueError("chunk= streaming is a scan-engine feature")
        if chunk is not None and chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        self.engine = engine
        self.devices = list(devices) if devices else None
        self.chunk = chunk
        self.overlap = overlap  # double-buffered chunk schedule (scan engine)
        self.policy = policy
        self._guard = None  # stacked GuardState, persists across run() calls
        self.guard_events = np.zeros((len(envs), 0), np.uint8)
        self.shadow_objectives = np.zeros((len(envs), 0), np.float32)
        self._guard_counters: Optional[list] = None  # one dict per session
        self.resilience = resilience
        self.supervisor = supervisor
        self.chaos = chaos
        self._health = None  # stacked HealthState, persists across run()
        self.health_events = np.zeros((len(envs), 0), np.uint8)
        self._health_counters: Optional[list] = None  # one dict per session
        self.envs = list(envs)
        self.scalarizers = list(scalarizers)
        self.agent = agent
        from repro.core.sharing import resolve_obs_mask
        self._obs_mask = resolve_obs_mask(
            self.sharing, self.envs[0].metric_specs,
            self.envs[0].state_metrics)
        self.eval_runs = eval_runs
        self.labels = list(labels) if labels else [
            f"session{i}" for i in range(len(self.envs))]
        if vectorized is None:
            from repro.envs.lustre_sim import LustreSimEnv
            vectorized = (engine == "host" and
                          all(isinstance(e, LustreSimEnv) for e in self.envs))
        self.vectorized = vectorized
        self.histories: list = [[] for _ in self.envs]
        self.simulated_restart_seconds = np.zeros(len(self.envs))
        self.default_configs = [e.param_space.default_config() for e in self.envs]
        self.default_metrics = [
            self._evaluate(i, c, runs=eval_runs)
            for i, c in enumerate(self.default_configs)]
        self._cur_configs = [dict(c) for c in self.default_configs]
        self._cur_metrics = [dict(m) for m in self.default_metrics]
        self.best_configs = [dict(c) for c in self.default_configs]
        self.best_metrics = [dict(m) for m in self.default_metrics]
        self.best_objectives = [
            sc.objective(m) for sc, m in zip(self.scalarizers, self.default_metrics)]

    # ------------------------------------------------------------------

    @classmethod
    def from_grid(cls, workloads: Sequence[str],
                  objectives: Sequence[Mapping[str, float]],
                  seeds: Sequence[int], *, env_factory=None, env_cls=None,
                  ddpg_config: Optional[DDPGConfig] = None,
                  buffer_capacity: int = 64, warmup_steps: int = 8,
                  eval_runs: int = 3, extended: bool = False,
                  engine: str = "host",
                  devices: Optional[Sequence] = None,
                  chunk: Optional[int] = None, overlap: bool = True,
                  replay_dtype=jnp.float32, policy=None,
                  sharing=None, resilience=None, supervisor=None,
                  chaos=None) -> "FleetTuner":
        """Build a fleet for the full seeds x workloads x objectives grid.

        ``env_factory(workload, seed)`` defaults to ``env_cls(workload,
        seed=seed)`` with ``env_cls=LustreSimEnv`` — the paper's evaluation
        environment; pass ``env_cls=LustreSimV2`` for the 8-knob space. The
        agent's dims come from the environments' ``ParamSpace``
        (``DDPGConfig.for_env``), so the same grid code drives any space.
        Every grid cell is an independent tuning session; session seeds are
        offset per cell so no two sessions share an RNG stream even under the
        same base seed.

        ``engine="scan"`` builds each cell as a pure-model environment
        (``env.to_model_env()``) and runs whole fleet episodes as the
        streaming chunked runtime (``core.episode``): ``chunk=C`` executes
        the grid as chunks of C sessions through one compiled, donated
        episode program with the fleet's state held in host numpy between
        chunks — peak device memory O(C·T) — while ``chunk=None`` runs one
        chunk of the whole grid. The scan agent stores its state host-side
        for the same reason. ``devices`` (default: all local devices) shards
        the chunk's session axis with ``shard_map``; any grid shape runs via
        last-chunk padding. Per-session keys come from the cell seed alone,
        so results are invariant to the device count AND the chunk size.
        ``replay_dtype=jnp.bfloat16`` opts into compact replay storage
        (f32 compute at gather; changes learning trajectories — see
        ``BatchedReplayBuffer``). ``overlap`` (default on) double-buffers
        the chunk stream — staging and trace decode hide under device
        compute; bitwise the serial schedule (pure scheduling).

        ``policy`` (``core.guardrails.DeploymentPolicy``) turns on the
        shadow/canary guardrails for every session (scan engine only;
        default off — bitwise the unguarded fleet).

        ``resilience`` (``core.resilience.ResiliencePolicy``) turns on the
        self-healing scan body for every session: snapshot/reset on
        non-finite detection, degrade-to-frozen past the reset budget (scan
        engine only; default off — bitwise the plain fleet, same compiled
        program). ``supervisor`` (``core.resilience.ChunkSupervisor``) adds
        host-side chunk retry/backoff + a wall-clock watchdog to the chunk
        stream; ``chaos`` (``envs.faults.HostChaos``) injects deterministic
        transient staging failures for testing (needs a supervisor).

        ``sharing`` (``core.sharing.SharingConfig``) turns on cross-session
        experience sharing within each workload×objective CELL — the
        ``len(seeds)`` contiguous sessions that tune the same surface under
        different seeds (scan engine only; default off — bitwise the
        independent fleet, same compiled program). ``shared_replay`` merges
        each cell's replay into one window (the agent's buffer is built
        grouped), ``avg_every`` averages the cell's learner parameters
        periodically, ``observation_scopes`` masks the learners'
        observations to the named metric scopes.
        """
        from repro.core.sharing import normalize_sharing
        sharing = normalize_sharing(sharing)
        if env_factory is not None and env_cls is not None:
            raise ValueError(
                "pass env_factory OR env_cls, not both — env_cls would be "
                "silently ignored")
        if env_factory is None:
            from repro.envs.lustre_sim import LustreSimEnv
            env_cls = env_cls or LustreSimEnv

            if env_cls is LustreSimEnv:
                def base_factory(workload, seed):
                    return LustreSimEnv(workload, seed=seed, extended=extended)
            else:
                if extended:
                    raise ValueError(
                        "extended=True only applies to LustreSimEnv; "
                        f"{env_cls.__name__} defines its own space")

                def base_factory(workload, seed):
                    return env_cls(workload, seed=seed)

            if engine == "scan":
                def env_factory(workload, seed):
                    return base_factory(workload, seed).to_model_env()
            else:
                env_factory = base_factory
        if devices is not None and engine == "scan" and len(devices) == 0:
            raise ValueError("devices must be non-empty")
        if devices is None and engine == "scan":
            import jax as _jax
            devices = _jax.devices()

        envs, scals, labels, cell_seeds = [], [], [], []
        cell = 0
        for workload in workloads:
            for weights in objectives:
                for seed in seeds:
                    env = env_factory(workload, seed + 1000 * cell)
                    envs.append(env)
                    scals.append(Scalarizer(weights=dict(weights),
                                            specs=env.metric_specs))
                    obj_name = "+".join(sorted(weights))
                    labels.append(f"{workload}|{obj_name}|seed{seed}")
                    cell_seeds.append(seed + 1000 * cell)
                    cell += 1
        if not envs:
            raise ValueError(
                "empty grid: need at least one workload, objective and seed")
        cfg = ddpg_config or DDPGConfig.for_env(envs[0])
        # seeds iterate innermost, so a workload×objective cell is exactly
        # len(seeds) contiguous sessions — the sharing cell topology
        cell_size = len(list(seeds))
        cell_modes = sharing is not None and (sharing.shared_replay
                                              or sharing.averaging)
        replay_groups = None
        if sharing is not None and sharing.shared_replay:
            replay_groups = [i // cell_size for i in range(len(envs))]
        agent = FleetAgent(cfg, cell_seeds, buffer_capacity=buffer_capacity,
                           warmup_steps=warmup_steps,
                           store="host" if engine == "scan" else "device",
                           replay_dtype=replay_dtype,
                           init_chunk=chunk, replay_groups=replay_groups)
        return cls(envs, scals, agent, eval_runs=eval_runs, labels=labels,
                   engine=engine, devices=devices if engine == "scan" else None,
                   chunk=chunk if engine == "scan" else None, overlap=overlap,
                   policy=policy, sharing=sharing,
                   cell_size=cell_size if cell_modes else 1,
                   resilience=resilience, supervisor=supervisor, chaos=chaos)

    # ------------------------------------------------------------------

    def memory_plan(self, steps: int = 30) -> dict:
        """Capacity accounting for this fleet (see module-level
        ``memory_plan``), validated against the LIVE buffers: the predicted
        per-session learner and replay bytes are checked against the actual
        array sizes held by ``agent.states`` and ``agent.buffer``, and the
        live numbers are reported alongside (``live`` /
        ``matches_live``)."""
        n = len(self.envs)
        env_state_bytes = 0
        if getattr(self.envs[0], "model", None) is not None:
            env_state_bytes = sum(
                int(np.asarray(leaf).nbytes) for leaf in
                jax.tree_util.tree_leaves(self.envs[0].model_state))
        shared_cell = (self.cell_size
                       if self.agent.buffer.groups is not None else 1)
        plan = memory_plan(
            self.agent.cfg, self.envs[0].param_space, sessions=n,
            steps=steps, chunk=self.chunk,
            capacity=self.agent.buffer.capacity,
            replay_dtype=self.agent.buffer.storage_dtype,
            num_devices=len(self.devices) if self.devices else 1,
            env_state_bytes_per_session=env_state_bytes,
            cell_size=shared_cell)
        live_learner = sum(
            int(np.asarray(leaf).nbytes) for leaf in
            jax.tree_util.tree_leaves(self.agent.states)) // n
        live_replay = self.agent.buffer.nbytes // n
        plan["live"] = {"learner_bytes_per_session": live_learner,
                        "replay_bytes_per_session": live_replay}
        plan["matches_live"] = (
            plan["per_session"]["learner_bytes"] == live_learner
            and plan["per_session"]["replay_bytes"] == live_replay)
        return plan

    def precompile(self, steps: int):
        """Compile the chunked episode executable ahead of ``run(steps)``
        (and persist it, if ``enable_persistent_compilation_cache`` is
        active) without touching tuning state. Scan engine only."""
        if self.engine != "scan":
            raise ValueError("precompile() applies to the scan engine")
        from repro.core.episode import precompile_fleet_episode
        return precompile_fleet_episode(
            self.envs[0], self.agent, steps, sessions=len(self.envs),
            chunk=self.chunk, devices=self.devices)

    # ------------------------------------------------------------------

    def _evaluate(self, i: int, config: dict, runs: int) -> dict:
        """Session i's metrics averaged over ``runs`` long evaluation runs."""
        return evaluate_config(self.envs[i], config, runs)

    def _states(self) -> np.ndarray:
        return np.stack([
            normalize_state(m, e.metric_specs, e.state_metrics)
            for m, e in zip(self._cur_metrics, self.envs)])

    def _apply_all(self, configs: list) -> list:
        """Run every session's workload under its config for one fleet step."""
        if self.vectorized:
            from repro.envs.lustre_sim import batch_mean_performance
            perfs = batch_mean_performance(self.envs, configs)
            return [e._run_with_perf(p, c)
                    for e, p, c in zip(self.envs, perfs, configs)]
        return [e.apply(c) for e, c in zip(self.envs, configs)]

    # ------------------------------------------------------------------

    def run(self, steps: int) -> FleetResult:
        """Run ``steps`` lockstep tuning iterations across the fleet.

        Callable repeatedly — agent, buffers and noise state persist across
        calls (progressive tuning, paper Fig. 7).

        Timing fields (``StepRecord.action_seconds``/``learn_seconds``,
        ``TuningResult.wall_seconds``) measure the FLEET's shared step — all
        sessions act/learn in one fused computation — so they are identical
        across sessions and not comparable with single-``Tuner`` per-session
        timings. With ``engine="scan"`` the whole episode is one program and
        per-step timings are the episode average.
        """
        t_wall = time.perf_counter()
        if self.engine == "scan":
            self._run_scan(steps)
        else:
            self._run_host(steps)
        return self._finish(t_wall)

    def _run_scan(self, steps: int) -> None:
        """Streaming chunked fleet episode
        (``core.episode.run_fleet_episode_scan``), history reconstructed from
        the compact trace."""
        from repro.core.episode import run_fleet_episode_scan
        n_sessions = len(self.envs)
        start = len(self.histories[0])
        t0 = time.perf_counter()
        if self.policy is not None:
            from repro.core.guardrails import (
                empty_counters, guardrail_counters, init_fleet_guard_state,
                merge_counters)
            if self._guard is None:
                self._guard = init_fleet_guard_state(
                    self.envs[0].param_space, self._cur_configs,
                    [sc.objective(m) for sc, m in
                     zip(self.scalarizers, self._cur_metrics)])
            trace, self._guard = run_fleet_episode_scan(
                self.envs, self.agent, self.scalarizers, self._cur_metrics,
                steps, learn=True, devices=self.devices, chunk=self.chunk,
                overlap=self.overlap, policy=self.policy, guard=self._guard)
            self.guard_events = np.concatenate(
                [self.guard_events, trace.guard_events], axis=1)
            self.shadow_objectives = np.concatenate(
                [self.shadow_objectives, trace.shadow_objectives], axis=1)
            if self._guard_counters is None:
                self._guard_counters = [empty_counters()
                                        for _ in range(n_sessions)]
            self._guard_counters = [
                merge_counters(c, guardrail_counters(trace.guard_events[i],
                                                     trace.restarts[i]))
                for i, c in enumerate(self._guard_counters)]
        elif self.resilience is not None:
            from repro.core.resilience import (
                empty_health_counters, health_counters,
                init_fleet_health_state, merge_health_counters)
            if self._health is None:
                self._health = init_fleet_health_state(
                    self.agent.states, n_sessions, self.resilience)
            trace, self._health = run_fleet_episode_scan(
                self.envs, self.agent, self.scalarizers, self._cur_metrics,
                steps, learn=True, devices=self.devices, chunk=self.chunk,
                overlap=self.overlap, sharing=self.sharing,
                cell_size=self.cell_size, obs_mask=self._obs_mask,
                resilience=self.resilience, health=self._health,
                supervisor=self.supervisor, chaos=self.chaos)
            self.health_events = np.concatenate(
                [self.health_events, trace.health_events], axis=1)
            if self._health_counters is None:
                self._health_counters = [empty_health_counters()
                                         for _ in range(n_sessions)]
            self._health_counters = [
                merge_health_counters(c,
                                      health_counters(trace.health_events[i]))
                for i, c in enumerate(self._health_counters)]
        else:
            trace = run_fleet_episode_scan(
                self.envs, self.agent, self.scalarizers, self._cur_metrics,
                steps, learn=True, devices=self.devices, chunk=self.chunk,
                overlap=self.overlap, sharing=self.sharing,
                cell_size=self.cell_size, obs_mask=self._obs_mask,
                supervisor=self.supervisor, chaos=self.chaos)
        per_step = (time.perf_counter() - t0) / max(1, steps)

        for i in range(n_sessions):
            rep = replay_compact_trace(
                self.envs[i], trace, i, start=start, per_step=per_step,
                prev_config=self._cur_configs[i],
                best_objective=self.best_objectives[i],
                restart_seconds=float(self.simulated_restart_seconds[i]),
                finite_baseline=self.resilience is not None)
            self.histories[i].extend(rep["records"])
            self.simulated_restart_seconds[i] = rep["restart_seconds"]
            if rep["best"] is not None:
                self.best_objectives[i] = rep["best"]["objective"]
                self.best_configs[i] = dict(rep["best"]["config"])
                self.best_metrics[i] = dict(rep["best"]["metrics"])
            self._cur_configs[i] = rep["cur_config"]
            if rep["cur_metrics"] is not None:
                self._cur_metrics[i] = rep["cur_metrics"]

    def _run_host(self, steps: int) -> None:
        n_sessions = len(self.envs)
        start = len(self.histories[0])
        for step_i in range(start, start + steps):
            states = self._states()

            t0 = time.perf_counter()
            actions = self.agent.act(states)
            configs = [e.param_space.to_config(a)
                       for e, a in zip(self.envs, actions)]
            metrics = self._apply_all(configs)
            action_seconds = time.perf_counter() - t0

            restarts = np.array([
                e.restart_cost(c, prev) for e, c, prev in
                zip(self.envs, configs, self._cur_configs)])
            self.simulated_restart_seconds += restarts

            next_states = np.stack([
                normalize_state(m, e.metric_specs, e.state_metrics)
                for m, e in zip(metrics, self.envs)])
            # python floats: StepRecord.reward must match Tuner's bitwise; the
            # replay buffer narrows to float32 on add, same as the single path
            rewards = [sc.reward(prev, m) for sc, prev, m in
                       zip(self.scalarizers, self._cur_metrics, metrics)]
            objectives = [sc.objective(m)
                          for sc, m in zip(self.scalarizers, metrics)]

            t0 = time.perf_counter()
            self.agent.observe(states, actions, rewards, next_states)
            self.agent.learn()
            learn_seconds = time.perf_counter() - t0

            for i in range(n_sessions):
                if objectives[i] > self.best_objectives[i]:
                    self.best_objectives[i] = objectives[i]
                    self.best_configs[i] = dict(configs[i])
                    self.best_metrics[i] = dict(metrics[i])
                self.histories[i].append(StepRecord(
                    step=step_i, config=configs[i], metrics=metrics[i],
                    objective=objectives[i], reward=float(rewards[i]),
                    restart_seconds=float(restarts[i]),
                    action_seconds=action_seconds,
                    learn_seconds=learn_seconds,
                ))
            self._cur_configs = configs
            self._cur_metrics = metrics

    def guardrail_stats(self, i: int) -> Optional[dict]:
        """Session ``i``'s exported guardrail record (None when off)."""
        if self.policy is None:
            return None
        from repro.core.guardrails import empty_counters, guardrail_stats
        guard_i = (jax.tree_util.tree_map(lambda x: x[i], self._guard)
                   if self._guard is not None else None)
        counters = (self._guard_counters[i] if self._guard_counters
                    else empty_counters())
        return guardrail_stats(self.policy, guard_i, counters,
                               space=self.envs[i].param_space)

    def health_stats(self, i: int) -> Optional[dict]:
        """Session ``i``'s exported health record (None when off)."""
        if self.resilience is None:
            return None
        from repro.core.resilience import empty_health_counters, health_stats
        health_i = (jax.tree_util.tree_map(lambda x: x[i], self._health)
                    if self._health is not None else None)
        counters = (self._health_counters[i] if self._health_counters
                    else empty_health_counters())
        return health_stats(self.resilience, health_i, counters)

    def _finish(self, t_wall: float) -> FleetResult:
        # Final recommendation per session (the same §III-E rule as Tuner.run,
        # via the shared recommend_final helper).
        n_sessions = len(self.envs)
        policy_actions = self.agent.act(self._states(), explore=False)
        finals = []
        for i in range(n_sessions):
            policy_config = self.envs[i].param_space.to_config(policy_actions[i])
            config, best_metrics, replaced = recommend_final(
                self.scalarizers[i], self.best_configs[i], policy_config,
                lambda c, i=i: self._evaluate(i, c, runs=self.eval_runs))
            if replaced:
                self.best_configs[i] = config
                self.best_metrics[i] = dict(best_metrics)
                self.best_objectives[i] = self.scalarizers[i].objective(
                    best_metrics)
            finals.append(best_metrics)
        wall = time.perf_counter() - t_wall  # includes final evaluations,
        results = []                         # matching Tuner.run's clock
        for i in range(n_sessions):
            results.append(TuningResult(
                best_config=dict(self.best_configs[i]),
                best_objective=self.scalarizers[i].objective(finals[i]),
                best_metrics=finals[i],
                default_config=dict(self.default_configs[i]),
                default_metrics=dict(self.default_metrics[i]),
                history=list(self.histories[i]),
                simulated_restart_seconds=float(
                    self.simulated_restart_seconds[i]),
                wall_seconds=wall,
                guardrail_stats=self.guardrail_stats(i),
                health_stats=self.health_stats(i),
            ))
        return FleetResult(results=results, labels=list(self.labels),
                           wall_seconds=wall)


def memory_plan(cfg: DDPGConfig, space, *, sessions: int, steps: int,
                chunk: Optional[int] = None, capacity: int = 64,
                replay_dtype=np.float32, num_devices: int = 1,
                env_state_bytes_per_session: int = 0,
                cell_size: int = 1) -> dict:
    """Bytes-per-session capacity accounting for the chunked fleet runtime.

    Everything is derived from the shapes the runtime actually allocates:

      * ``learner_bytes`` — one session's DDPG state: online + target
        actor/critic (2× each) and both Adam moment sets, i.e. 4× the
        actor + critic parameter floats, plus the step/Adam counters;
      * ``replay_bytes`` — ``capacity × (2·state_dim + action_dim + 1)``
        entries at the replay storage dtype (f32 default, bf16 opt-in);
        ``cell_size > 1`` models MERGED cell buffers (shared replay — see
        ``core.sharing``): a cell of k sessions keeps one window, so bytes
        per session divide by k, multiplying the bf16 win;
      * ``trace_bytes_per_step`` — the compact trace: per-knob index ints
        (``ParamSpace.index_dtype``), the float32 metric vector,
        reward/objective floats and the int32 fixed-point restart;
      * ``chunk_device_bytes`` — what one chunk keeps resident on device
        (state + replay + env state + exploration inputs + the chunk's
        trace): the streaming runtime's peak, O(chunk·steps);
      * ``overlap_device_bytes`` — the async double-buffered schedule's
        bound: up to THREE chunks of device state coexist (chunk k
        computing, chunk k+1's operands in flight on the transfer stream,
        chunk k-1's results draining to host), still O(chunk·steps);
      * ``fleet_host_bytes`` — the whole fleet's host-side state and trace
        buffers, O(sessions·steps).

    ``FleetTuner.memory_plan`` validates the learner/replay rows against the
    live arrays (tests pin that the prediction IS the allocation).
    """
    from repro.core.episode import resolve_chunk

    k, m = cfg.state_dim, cfg.action_dim

    def mlp_floats(sizes):
        return sum(i * o + o for i, o in zip(sizes[:-1], sizes[1:]))

    actor = mlp_floats((k, *cfg.hidden, m))
    critic = mlp_floats((k + m, *cfg.hidden, 1))
    # online + target + Adam mu + Adam nu (4 copies of each net), f32,
    # plus the learner step counter and one Adam count per optimizer (i32)
    learner_bytes = 4 * (actor + critic) * 4 + 3 * 4
    itemsize = np.dtype(replay_dtype).itemsize
    if cell_size > 1 and sessions % cell_size != 0:
        raise ValueError(
            f"merged cell buffers need whole cells: {sessions} sessions is "
            f"not a multiple of cell_size={cell_size}")
    # a cell's single merged window, amortized over its members; floor
    # division matches the live accounting (buffer.nbytes // sessions)
    replay_bytes = capacity * (2 * k + m + 1) * itemsize // cell_size
    idx_size = space.index_dtype().itemsize
    trace_bytes_per_step = m * idx_size + k * 4 + 4 + 4 + 4
    exploration_bytes_per_step = 2 * m * 4  # warmup + noise rows, f32

    c = resolve_chunk(sessions, chunk, num_devices)
    per_session_resident = (learner_bytes + replay_bytes
                            + env_state_bytes_per_session)
    chunk_device_bytes = c * (
        per_session_resident
        + steps * (trace_bytes_per_step + exploration_bytes_per_step))
    fleet_host_bytes = sessions * (
        per_session_resident
        + steps * (trace_bytes_per_step + exploration_bytes_per_step))
    return {
        "sessions": sessions,
        "chunk": c,
        "steps": steps,
        "capacity": capacity,
        "cell_size": cell_size,
        "replay_dtype": str(np.dtype(replay_dtype)),
        "per_session": {
            "learner_bytes": learner_bytes,
            "replay_bytes": replay_bytes,
            "env_state_bytes": env_state_bytes_per_session,
            "trace_bytes_per_step": trace_bytes_per_step,
        },
        "chunk_device_bytes": chunk_device_bytes,
        # async staging keeps up to three chunks of state alive at once:
        # computing (k), staged-in-flight (k+1), draining (k-1)
        "overlap_device_bytes": 3 * chunk_device_bytes,
        "fleet_host_bytes": fleet_host_bytes,
    }
