"""FIFO replay buffer (paper §II-D).

Limited size; once full, the oldest transition is evicted (FIFO) so the model
neither overfits stale history nor forgets recent experience. Stored on host
(numpy) — tuning trajectories are tiny (30-100 steps) and the agent samples
minibatches into jax arrays at update time.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Transition:
    state: np.ndarray
    action: np.ndarray
    reward: float
    next_state: np.ndarray


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._s = np.zeros((capacity, state_dim), np.float32)
        self._a = np.zeros((capacity, action_dim), np.float32)
        self._r = np.zeros((capacity,), np.float32)
        self._s2 = np.zeros((capacity, state_dim), np.float32)
        self._next = 0  # next write slot
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, state, action, reward, next_state) -> None:
        i = self._next
        self._s[i] = state
        self._a[i] = action
        self._r[i] = reward
        self._s2[i] = next_state
        self._next = (i + 1) % self.capacity  # FIFO eviction once full
        self._size = min(self._size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch_size: int):
        """Uniform sample with replacement (buffer may be smaller than the batch)."""
        if self._size == 0:
            raise ValueError("cannot sample from an empty buffer")
        idx = rng.integers(0, self._size, size=batch_size)
        return self._s[idx], self._a[idx], self._r[idx], self._s2[idx]

    def as_arrays(self):
        return (
            self._s[: self._size].copy(),
            self._a[: self._size].copy(),
            self._r[: self._size].copy(),
            self._s2[: self._size].copy(),
        )

    def state_dict(self) -> dict:
        """For checkpoint/resume of a tuning session (paper §III-E: resume tuning)."""
        return {
            "s": self._s.copy(), "a": self._a.copy(), "r": self._r.copy(),
            "s2": self._s2.copy(), "next": self._next, "size": self._size,
        }

    def load_state_dict(self, d: dict) -> None:
        self._s[...] = d["s"]
        self._a[...] = d["a"]
        self._r[...] = d["r"]
        self._s2[...] = d["s2"]
        self._next = int(d["next"])
        self._size = int(d["size"])
