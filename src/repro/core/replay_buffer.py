"""FIFO replay buffers (paper §II-D).

Limited size; once full, the oldest transition is evicted (FIFO) so the model
neither overfits stale history nor forgets recent experience.

``ReplayBuffer`` is the single-session host-side (numpy) buffer; its
``storage()`` view hands the full fixed-capacity arrays plus the live size to
the fused learner (``ddpg_learn_scan``), which samples minibatches on-device.
``BatchedReplayBuffer`` is the device-resident fleet variant: one buffer per
tuning session stacked on a leading session axis, written in lockstep, with
identical FIFO semantics per session.

Dropped writes (resilience): the in-graph FIFO write these buffers hand
their storage to is branch-free — when ``core.resilience`` flags a step's
transition as corrupted (non-finite metrics), the scan body scatters the row
OUT of bounds with ``mode="drop"`` and freezes ``next_slot``/``size``, so
the poisoned sample never lands and the window's cursor arithmetic stays
exactly the FIFO described here. A merged cell window (``groups=``) gets the
same treatment per contributing lane: a corrupted or degraded member simply
stops contributing; the survivors' interleave order is unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Transition:
    state: np.ndarray
    action: np.ndarray
    reward: float
    next_state: np.ndarray


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._s = np.zeros((capacity, state_dim), np.float32)
        self._a = np.zeros((capacity, action_dim), np.float32)
        self._r = np.zeros((capacity,), np.float32)
        self._s2 = np.zeros((capacity, state_dim), np.float32)
        self._next = 0  # next write slot
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, state, action, reward, next_state) -> None:
        i = self._next
        self._s[i] = state
        self._a[i] = action
        self._r[i] = reward
        self._s2[i] = next_state
        self._next = (i + 1) % self.capacity  # FIFO eviction once full
        self._size = min(self._size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch_size: int):
        """Uniform sample with replacement (buffer may be smaller than the batch)."""
        if self._size == 0:
            raise ValueError("cannot sample from an empty buffer")
        idx = rng.integers(0, self._size, size=batch_size)
        return self._s[idx], self._a[idx], self._r[idx], self._s2[idx]

    def as_arrays(self):
        return (
            self._s[: self._size].copy(),
            self._a[: self._size].copy(),
            self._r[: self._size].copy(),
            self._s2[: self._size].copy(),
        )

    def storage(self):
        """((s, a, r, s2) full-capacity arrays, size) for on-device sampling.

        The arrays keep a fixed [capacity, ...] shape (zeros past ``size``) so
        the fused learner compiles once; the dynamic ``size`` operand restricts
        sampling to valid rows.
        """
        return (self._s, self._a, self._r, self._s2), self._size

    def set_storage(self, s, a, r, s2, next_slot: int, size: int) -> None:
        """Write back storage mutated off-host (the fused episode engine keeps
        the FIFO on-device for the whole episode and syncs it here once)."""
        self._s[...] = s
        self._a[...] = a
        self._r[...] = r
        self._s2[...] = s2
        self._next = int(next_slot)
        self._size = int(size)

    def state_dict(self) -> dict:
        """For checkpoint/resume of a tuning session (paper §III-E: resume tuning)."""
        return {
            "s": self._s.copy(), "a": self._a.copy(), "r": self._r.copy(),
            "s2": self._s2.copy(), "next": self._next, "size": self._size,
        }

    def load_state_dict(self, d: dict) -> None:
        self._s[...] = d["s"]
        self._a[...] = d["a"]
        self._r[...] = d["r"]
        self._s2[...] = d["s2"]
        self._next = int(d["next"])
        self._size = int(d["size"])


class BatchedReplayBuffer:
    """N independent FIFO buffers stacked on a leading session axis.

    Device-resident (jax arrays) by default so the vmapped fleet learner reads
    transitions without a host round-trip; ``storage_backend="host"`` keeps
    the stacked arrays in numpy instead — the streaming chunked episode
    runtime (``core.episode``) slices per-chunk views out of them, so a
    1024-session fleet never materializes its whole replay pool on device.
    Sessions step in lockstep — one ``add`` writes one transition per session
    — so a single write cursor serves the fleet and per-session eviction
    order is exactly ``ReplayBuffer``'s.

    ``storage_dtype`` is the *storage* precision (default float32, which is
    bitwise the single-session path). ``jnp.bfloat16`` halves replay bytes
    per session; compute stays float32 — transitions are cast back to f32 at
    minibatch gather (here in ``sample`` and in the fused learner's
    post-gather cast), never accumulated in bf16. Opt-in because storage
    rounding changes learning trajectories: fleet-of-1 parity with the single
    ``Tuner`` holds only at the f32 default.

    ``groups`` (optional, one group id per session, group-contiguous and
    numbered 0..G-1) merges the member sessions of each group into ONE shared
    FIFO window: storage shrinks from [N, capacity, ...] to [G, capacity,
    ...], each ``add`` appends every member's transition (in session order)
    to its group's window, and each session samples uniformly from the whole
    merged window — a cell of k sessions keeps 1 buffer instead of k and
    every learner sees k× the transitions per env step. Cursors become
    per-group arrays; sampling stays one fused gather per storage array over
    the flattened [G*capacity, ...] view. ``groups=None`` (the default) is
    byte-for-byte the independent-buffer path above.
    """

    def __init__(self, num_sessions: int, capacity: int, state_dim: int,
                 action_dim: int, storage_dtype=jnp.float32,
                 storage_backend: str = "device", groups=None):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if num_sessions <= 0:
            raise ValueError("num_sessions must be positive")
        if storage_backend not in ("device", "host"):
            raise ValueError(f"unknown storage_backend {storage_backend!r}")
        self.num_sessions = num_sessions
        self.capacity = capacity
        self.storage_dtype = np.dtype(storage_dtype)
        self.storage_backend = storage_backend
        self.groups = None if groups is None else tuple(
            int(g) for g in groups)
        if self.groups is None:
            rows = num_sessions
        else:
            if len(self.groups) != num_sessions:
                raise ValueError("groups must name one group per session")
            gids = np.asarray(self.groups, np.int32)
            num_groups = int(gids.max()) + 1 if num_sessions else 0
            if sorted(set(self.groups)) != list(range(num_groups)):
                raise ValueError("group ids must be consecutive from 0")
            if np.any(np.diff(gids) < 0):
                # the chunked scan engine slices cell-aligned session ranges
                # out of storage; sessions of a group must sit together
                raise ValueError("groups must be contiguous session runs")
            self.num_groups = num_groups
            self._gids = gids
            # rank of each session within its group = its append order
            self._grank = np.concatenate(
                [np.arange(c) for c in np.bincount(gids)]).astype(np.int32)
            self._gcounts = np.bincount(gids).astype(np.int32)
            rows = num_groups
        zeros = np.zeros if storage_backend == "host" else jnp.zeros
        dt = self.storage_dtype
        self._s = zeros((rows, capacity, state_dim), dt)
        self._a = zeros((rows, capacity, action_dim), dt)
        self._r = zeros((rows, capacity), dt)
        self._s2 = zeros((rows, capacity, state_dim), dt)
        if self.groups is None:
            self._next = 0
            self._size = 0
        else:
            self._next = np.zeros((rows,), np.int32)
            self._size = np.zeros((rows,), np.int32)

    def __len__(self) -> int:
        if self.groups is not None:
            return int(self._size.max()) if self.num_groups else 0
        return self._size

    @property
    def nbytes(self) -> int:
        """Live storage bytes (all four stacked arrays, whole fleet)."""
        return sum(int(x.nbytes) for x in (self._s, self._a, self._r,
                                           self._s2))

    def add(self, state, action, reward, next_state) -> None:
        """Add one transition per session; each argument is [N, ...]."""
        dt = self.storage_dtype
        if self.groups is not None:
            # each member appends to its group's merged window, in session
            # order: session with rank j lands at slot (next[g] + j) % cap
            slots = (self._next[self._gids] + self._grank) % self.capacity
            vals = tuple(
                np.asarray(x, jnp.float32).astype(dt)
                if self.storage_backend == "host"
                else jnp.asarray(x, jnp.float32).astype(dt)
                for x in (state, action, reward, next_state))
            if self.storage_backend == "host":
                for buf, v in zip((self._s, self._a, self._r, self._s2),
                                  vals):
                    buf[self._gids, slots] = v
            else:
                self._s = self._s.at[self._gids, slots].set(vals[0])
                self._a = self._a.at[self._gids, slots].set(vals[1])
                self._r = self._r.at[self._gids, slots].set(vals[2])
                self._s2 = self._s2.at[self._gids, slots].set(vals[3])
            self._next = (self._next + self._gcounts) % self.capacity
            self._size = np.minimum(self._size + self._gcounts,
                                    self.capacity).astype(np.int32)
            return
        i = self._next
        if self.storage_backend == "host":
            self._s[:, i] = np.asarray(state, jnp.float32).astype(dt)
            self._a[:, i] = np.asarray(action, jnp.float32).astype(dt)
            self._r[:, i] = np.asarray(reward, jnp.float32).astype(dt)
            self._s2[:, i] = np.asarray(next_state, jnp.float32).astype(dt)
        else:
            # both backends narrow through f32 first (the transition's wire
            # precision), so host and device storage round identically
            self._s = self._s.at[:, i].set(
                jnp.asarray(state, jnp.float32).astype(dt))
            self._a = self._a.at[:, i].set(
                jnp.asarray(action, jnp.float32).astype(dt))
            self._r = self._r.at[:, i].set(
                jnp.asarray(reward, jnp.float32).astype(dt))
            self._s2 = self._s2.at[:, i].set(
                jnp.asarray(next_state, jnp.float32).astype(dt))
        self._next = (i + 1) % self.capacity  # FIFO eviction once full
        self._size = min(self._size + 1, self.capacity)

    def storage(self):
        """((s, a, r, s2) stacked [N, capacity, ...] arrays, sizes [N]).

        Arrays come back in the storage dtype and backend (bf16 stays bf16;
        host mode returns numpy views) — the fused learner casts minibatches
        to f32 after gathering them. Grouped buffers hand each session a view
        of its group's MERGED window (the per-session expansion ``x[gids]``),
        so the vmapped learner transparently samples shared experience."""
        if self.groups is not None:
            gids = self._gids
            arrays = tuple(x[gids] for x in (self._s, self._a, self._r,
                                             self._s2))
            if self.storage_backend == "host":
                sizes = self._size[gids].copy()
            else:
                sizes = jnp.asarray(self._size[gids], jnp.int32)
            return arrays, sizes
        full = np.full if self.storage_backend == "host" else jnp.full
        sizes = full((self.num_sessions,), self._size, jnp.int32)
        return (self._s, self._a, self._r, self._s2), sizes

    def grouped_storage(self):
        """((s, a, r, s2) [G, capacity, ...] arrays, next [G], size [G]).

        The un-expanded cell-level view the chunked scan engine stages from
        and drains back to (cells never span chunks, so a chunk's slice is a
        whole number of groups). Only valid on grouped buffers."""
        if self.groups is None:
            raise ValueError("grouped_storage() requires groups=")
        return ((self._s, self._a, self._r, self._s2),
                self._next.copy(), self._size.copy())

    def set_storage(self, s, a, r, s2, next_slot, size) -> None:
        """Write back storage mutated off-host (fused fleet episodes advance
        the lockstep FIFO on-device and sync the shared cursor here).
        Grouped buffers take [G, ...] storage and per-group cursor arrays."""
        conv = np.asarray if self.storage_backend == "host" else jnp.asarray
        dt = self.storage_dtype
        self._s = conv(s, dt)
        self._a = conv(a, dt)
        self._r = conv(r, dt)
        self._s2 = conv(s2, dt)
        if self.groups is not None:
            self._next = np.asarray(next_slot, np.int32).reshape(
                (self.num_groups,))
            self._size = np.asarray(size, np.int32).reshape(
                (self.num_groups,))
        else:
            self._next = int(next_slot)
            self._size = int(size)

    def sample(self, keys: jax.Array, batch_size: int):
        """Per-session uniform minibatches: keys [N, key] -> each [N, B, ...].

        One ``take_along_axis`` per storage array (a single fused gather over
        the whole fleet) instead of a vmapped per-session gather — same index
        draws, bitwise-identical batches. Minibatches are returned float32
        regardless of the storage dtype (f32 compute at gather).
        """
        if len(self) == 0:
            raise ValueError("cannot sample from an empty buffer")
        if self.groups is not None:
            sizes = jnp.asarray(self._size[self._gids], jnp.int32)
            idx = jax.vmap(
                lambda k, sz: jax.random.randint(k, (batch_size,), 0, sz)
            )(keys, sizes)
            # one fused gather over the flattened [G*capacity, ...] window:
            # session n reads rows gids[n]*capacity + idx[n] of its group
            flat_idx = (jnp.asarray(self._gids)[:, None] * self.capacity
                        + idx)

            def gather(x):
                x = jnp.asarray(x)
                flat = x.reshape((self.num_groups * self.capacity,)
                                 + x.shape[2:])
                return jnp.take(flat, flat_idx, axis=0).astype(jnp.float32)

            return (gather(self._s), gather(self._a),
                    gather(self._r), gather(self._s2))
        idx = jax.vmap(
            lambda k: jax.random.randint(k, (batch_size,), 0, self._size)
        )(keys)

        def gather(x):
            x = jnp.asarray(x)
            ix = idx.reshape(idx.shape + (1,) * (x.ndim - 2))
            rows = jnp.take_along_axis(
                x, jnp.broadcast_to(ix, idx.shape + x.shape[2:]), axis=1)
            return rows.astype(jnp.float32)

        return (gather(self._s), gather(self._a),
                gather(self._r), gather(self._s2))

    def as_arrays(self):
        """Valid rows only, as float32 numpy: each [N or G, size, ...]."""
        n = int(self._size.max()) if self.groups is not None else self._size
        return tuple(np.asarray(x[:, :n]).astype(np.float32)
                     for x in (self._s, self._a, self._r, self._s2))

    def state_dict(self) -> dict:
        if self.groups is not None:
            return {
                "s": np.asarray(self._s), "a": np.asarray(self._a),
                "r": np.asarray(self._r), "s2": np.asarray(self._s2),
                "next": self._next.copy(), "size": self._size.copy(),
            }
        return {
            "s": np.asarray(self._s), "a": np.asarray(self._a),
            "r": np.asarray(self._r), "s2": np.asarray(self._s2),
            "next": self._next, "size": self._size,
        }

    def load_state_dict(self, d: dict) -> None:
        conv = np.asarray if self.storage_backend == "host" else jnp.asarray
        dt = self.storage_dtype
        self._s = conv(d["s"], dt)
        self._a = conv(d["a"], dt)
        self._r = conv(d["r"], dt)
        self._s2 = conv(d["s2"], dt)
        if self.groups is not None:
            self._next = np.asarray(d["next"], np.int32).reshape(
                (self.num_groups,))
            self._size = np.asarray(d["size"], np.int32).reshape(
                (self.num_groups,))
        else:
            self._next = int(d["next"])
            self._size = int(d["size"])
