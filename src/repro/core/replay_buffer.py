"""FIFO replay buffers (paper §II-D).

Limited size; once full, the oldest transition is evicted (FIFO) so the model
neither overfits stale history nor forgets recent experience.

``ReplayBuffer`` is the single-session host-side (numpy) buffer; its
``storage()`` view hands the full fixed-capacity arrays plus the live size to
the fused learner (``ddpg_learn_scan``), which samples minibatches on-device.
``BatchedReplayBuffer`` is the device-resident fleet variant: one buffer per
tuning session stacked on a leading session axis, written in lockstep, with
identical FIFO semantics per session.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Transition:
    state: np.ndarray
    action: np.ndarray
    reward: float
    next_state: np.ndarray


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity
        self._s = np.zeros((capacity, state_dim), np.float32)
        self._a = np.zeros((capacity, action_dim), np.float32)
        self._r = np.zeros((capacity,), np.float32)
        self._s2 = np.zeros((capacity, state_dim), np.float32)
        self._next = 0  # next write slot
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, state, action, reward, next_state) -> None:
        i = self._next
        self._s[i] = state
        self._a[i] = action
        self._r[i] = reward
        self._s2[i] = next_state
        self._next = (i + 1) % self.capacity  # FIFO eviction once full
        self._size = min(self._size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch_size: int):
        """Uniform sample with replacement (buffer may be smaller than the batch)."""
        if self._size == 0:
            raise ValueError("cannot sample from an empty buffer")
        idx = rng.integers(0, self._size, size=batch_size)
        return self._s[idx], self._a[idx], self._r[idx], self._s2[idx]

    def as_arrays(self):
        return (
            self._s[: self._size].copy(),
            self._a[: self._size].copy(),
            self._r[: self._size].copy(),
            self._s2[: self._size].copy(),
        )

    def storage(self):
        """((s, a, r, s2) full-capacity arrays, size) for on-device sampling.

        The arrays keep a fixed [capacity, ...] shape (zeros past ``size``) so
        the fused learner compiles once; the dynamic ``size`` operand restricts
        sampling to valid rows.
        """
        return (self._s, self._a, self._r, self._s2), self._size

    def set_storage(self, s, a, r, s2, next_slot: int, size: int) -> None:
        """Write back storage mutated off-host (the fused episode engine keeps
        the FIFO on-device for the whole episode and syncs it here once)."""
        self._s[...] = s
        self._a[...] = a
        self._r[...] = r
        self._s2[...] = s2
        self._next = int(next_slot)
        self._size = int(size)

    def state_dict(self) -> dict:
        """For checkpoint/resume of a tuning session (paper §III-E: resume tuning)."""
        return {
            "s": self._s.copy(), "a": self._a.copy(), "r": self._r.copy(),
            "s2": self._s2.copy(), "next": self._next, "size": self._size,
        }

    def load_state_dict(self, d: dict) -> None:
        self._s[...] = d["s"]
        self._a[...] = d["a"]
        self._r[...] = d["r"]
        self._s2[...] = d["s2"]
        self._next = int(d["next"])
        self._size = int(d["size"])


class BatchedReplayBuffer:
    """N independent FIFO buffers stacked on a leading session axis.

    Device-resident (jax arrays) so the vmapped fleet learner reads transitions
    without a host round-trip. Sessions step in lockstep — one ``add`` writes
    one transition per session — so a single write cursor serves the fleet and
    per-session eviction order is exactly ``ReplayBuffer``'s.
    """

    def __init__(self, num_sessions: int, capacity: int, state_dim: int,
                 action_dim: int):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        if num_sessions <= 0:
            raise ValueError("num_sessions must be positive")
        self.num_sessions = num_sessions
        self.capacity = capacity
        self._s = jnp.zeros((num_sessions, capacity, state_dim), jnp.float32)
        self._a = jnp.zeros((num_sessions, capacity, action_dim), jnp.float32)
        self._r = jnp.zeros((num_sessions, capacity), jnp.float32)
        self._s2 = jnp.zeros((num_sessions, capacity, state_dim), jnp.float32)
        self._next = 0
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, state, action, reward, next_state) -> None:
        """Add one transition per session; each argument is [N, ...]."""
        i = self._next
        self._s = self._s.at[:, i].set(jnp.asarray(state, jnp.float32))
        self._a = self._a.at[:, i].set(jnp.asarray(action, jnp.float32))
        self._r = self._r.at[:, i].set(jnp.asarray(reward, jnp.float32))
        self._s2 = self._s2.at[:, i].set(jnp.asarray(next_state, jnp.float32))
        self._next = (i + 1) % self.capacity  # FIFO eviction once full
        self._size = min(self._size + 1, self.capacity)

    def storage(self):
        """((s, a, r, s2) stacked [N, capacity, ...] arrays, sizes [N])."""
        sizes = jnp.full((self.num_sessions,), self._size, jnp.int32)
        return (self._s, self._a, self._r, self._s2), sizes

    def set_storage(self, s, a, r, s2, next_slot: int, size: int) -> None:
        """Write back storage mutated off-host (fused fleet episodes advance
        the lockstep FIFO on-device and sync the shared cursor here)."""
        self._s = jnp.asarray(s, jnp.float32)
        self._a = jnp.asarray(a, jnp.float32)
        self._r = jnp.asarray(r, jnp.float32)
        self._s2 = jnp.asarray(s2, jnp.float32)
        self._next = int(next_slot)
        self._size = int(size)

    def sample(self, keys: jax.Array, batch_size: int):
        """Per-session uniform minibatches: keys [N, key] -> each [N, B, ...].

        One ``take_along_axis`` per storage array (a single fused gather over
        the whole fleet) instead of a vmapped per-session gather — same index
        draws, bitwise-identical batches.
        """
        if self._size == 0:
            raise ValueError("cannot sample from an empty buffer")
        idx = jax.vmap(
            lambda k: jax.random.randint(k, (batch_size,), 0, self._size)
        )(keys)

        def gather(x):
            ix = idx.reshape(idx.shape + (1,) * (x.ndim - 2))
            return jnp.take_along_axis(
                x, jnp.broadcast_to(ix, idx.shape + x.shape[2:]), axis=1)

        return (gather(self._s), gather(self._a),
                gather(self._r), gather(self._s2))

    def as_arrays(self):
        """Valid rows only, as numpy: each [N, size, ...]."""
        n = self._size
        return (np.asarray(self._s[:, :n]), np.asarray(self._a[:, :n]),
                np.asarray(self._r[:, :n]), np.asarray(self._s2[:, :n]))

    def state_dict(self) -> dict:
        return {
            "s": np.asarray(self._s), "a": np.asarray(self._a),
            "r": np.asarray(self._r), "s2": np.asarray(self._s2),
            "next": self._next, "size": self._size,
        }

    def load_state_dict(self, d: dict) -> None:
        self._s = jnp.asarray(d["s"])
        self._a = jnp.asarray(d["a"])
        self._r = jnp.asarray(d["r"])
        self._s2 = jnp.asarray(d["s2"])
        self._next = int(d["next"])
        self._size = int(d["size"])
