"""Action <-> configuration mapping (paper §II-C-1, "Action Mapping").

The DDPG actor emits actions in [0,1]^m. Each coordinate is inverse-mapped to the
parameter's real range:

  continuous:  lambda_i = a(i) * (max - min) + min
  discrete:    lambda_i = floor(a(i) * (max - min) + min + 0.5)

Discrete parameters may also be defined over an explicit value list (e.g. power-of-two
stripe sizes); then the formula indexes the list. Box constraints (paper §II-A,
C_i := lambda_j ⊕ B_i) are enforced by construction (the map's image is the box) and
validated for externally supplied configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One tunable (static) parameter."""

    name: str
    kind: str  # "continuous" | "discrete" | "choice"
    minimum: float = 0.0
    maximum: float = 1.0
    values: tuple = ()  # for kind == "choice": explicit, ordered value list
    default: Any = None

    def __post_init__(self):
        if self.kind not in ("continuous", "discrete", "choice"):
            raise ValueError(f"unknown parameter kind {self.kind!r}")
        if self.kind == "choice":
            if len(self.values) < 1:
                raise ValueError(f"choice parameter {self.name} needs values")
        elif self.maximum < self.minimum:
            raise ValueError(f"{self.name}: max < min")

    def from_unit(self, a: float):
        """Paper's inverse mapping for a single coordinate a in [0,1]."""
        a = float(min(1.0, max(0.0, a)))
        if self.kind == "continuous":
            return a * (self.maximum - self.minimum) + self.minimum
        if self.kind == "discrete":
            v = int(np.floor(a * (self.maximum - self.minimum) + self.minimum + 0.5))
            return int(min(self.maximum, max(self.minimum, v)))
        # choice: treat the index space [0, len-1] as the discrete range
        idx = int(np.floor(a * (len(self.values) - 1) + 0.5))
        idx = min(len(self.values) - 1, max(0, idx))
        return self.values[idx]

    def to_unit(self, value) -> float:
        """Forward map (used to seed the buffer with known configs)."""
        if self.kind == "choice":
            idx = self.values.index(value)
            return idx / max(1, len(self.values) - 1)
        if self.maximum == self.minimum:
            return 0.0
        return (float(value) - self.minimum) / (self.maximum - self.minimum)

    def validate(self, value) -> bool:
        if self.kind == "choice":
            return value in self.values
        if self.kind == "discrete":
            return float(value).is_integer() and self.minimum <= value <= self.maximum
        return self.minimum <= value <= self.maximum


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """The m-dimensional static-parameter space Lambda (paper §II-A)."""

    specs: tuple

    def __post_init__(self):
        names = [s.name for s in self.specs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate parameter names")

    @property
    def names(self) -> list:
        return [s.name for s in self.specs]

    @property
    def dim(self) -> int:
        return len(self.specs)

    def to_config(self, action: Sequence[float]) -> dict:
        if len(action) != self.dim:
            raise ValueError(f"action dim {len(action)} != param dim {self.dim}")
        return {s.name: s.from_unit(a) for s, a in zip(self.specs, action)}

    def to_action(self, config: dict) -> np.ndarray:
        return np.array([s.to_unit(config[s.name]) for s in self.specs], np.float32)

    def default_config(self) -> dict:
        out = {}
        for s in self.specs:
            if s.default is not None:
                out[s.name] = s.default
            elif s.kind == "choice":
                out[s.name] = s.values[0]
            else:
                out[s.name] = s.from_unit(0.0)
        return out

    def validate(self, config: dict) -> bool:
        return all(s.validate(config[s.name]) for s in self.specs)

    def grid(self, points_per_dim: int) -> list:
        """Cartesian grid of unit actions (used by the grid-search baseline)."""
        axes = [np.linspace(0.0, 1.0, points_per_dim) for _ in self.specs]
        mesh = np.meshgrid(*axes, indexing="ij")
        flat = np.stack([m.reshape(-1) for m in mesh], axis=-1)
        return [self.to_config(a) for a in flat]
