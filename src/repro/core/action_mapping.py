"""Action <-> configuration mapping (paper §II-C-1, "Action Mapping").

The DDPG actor emits actions in [0,1]^m. Each coordinate is inverse-mapped to the
parameter's real range:

  continuous:  lambda_i = a(i) * (max - min) + min
  discrete:    lambda_i = floor(a(i) * (max - min) + min + 0.5)

Beyond the paper's two kinds, realistic DFS parameter spaces (DIAL's client-side
knobs, CARAT's RPC/cache co-tuning) mix several more; all reduce to the paper's
discrete formula over an index space:

  choice / categorical:  index the explicit value list (e.g. power-of-two
                         stripe sizes, service-thread counts)
  boolean:               {False, True} at the 0.5 threshold (e.g. checksums)
  log2_int:              integer powers of two between minimum and maximum,
                         uniform in log2 (e.g. max_rpcs_in_flight 1..256)

Box constraints (paper §II-A, C_i := lambda_j ⊕ B_i) are enforced by
construction (the map's image is the box) and validated for externally supplied
configs. Every kind has a vectorized unit<->value mapping
(``from_unit_batch``/``to_unit_batch``); the scalar maps are the N == 1 case of
the batch maps, so the fleet's vectorized round-trip and the single-session
path agree by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

#: "categorical" is the unordered spelling of "choice" — same index mapping,
#: kept distinct in ``kind`` so spaces document intent (DIAL/CARAT knobs).
_LIST_KINDS = ("choice", "categorical")
KINDS = ("continuous", "discrete", "boolean", "log2_int") + _LIST_KINDS


def _is_pow2(v) -> bool:
    v = int(v)
    return v > 0 and (v & (v - 1)) == 0


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One tunable (static) parameter."""

    name: str
    kind: str  # one of KINDS
    minimum: float = 0.0
    maximum: float = 1.0
    values: tuple = ()  # for list kinds: explicit, ordered value list
    default: Any = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown parameter kind {self.kind!r}")
        if self.kind in _LIST_KINDS:
            if len(self.values) < 1:
                raise ValueError(f"{self.kind} parameter {self.name} needs values")
        elif self.kind == "log2_int":
            if not (_is_pow2(self.minimum) and _is_pow2(self.maximum)):
                raise ValueError(
                    f"{self.name}: log2_int bounds must be powers of two")
            if self.maximum < self.minimum:
                raise ValueError(f"{self.name}: max < min")
        elif self.kind != "boolean" and self.maximum < self.minimum:
            raise ValueError(f"{self.name}: max < min")

    # -- size ----------------------------------------------------------------

    @property
    def cardinality(self) -> Optional[int]:
        """Number of distinct values; None for continuous parameters."""
        if self.kind == "continuous":
            return None
        if self.kind == "discrete":
            return int(self.maximum - self.minimum) + 1
        if self.kind == "boolean":
            return 2
        if self.kind == "log2_int":
            return self._log2_span()[1] - self._log2_span()[0] + 1
        return len(self.values)

    def _log2_span(self) -> tuple:
        return int(np.log2(int(self.minimum))), int(np.log2(int(self.maximum)))

    # -- vectorized unit <-> value maps --------------------------------------

    def from_unit_batch(self, a: np.ndarray) -> list:
        """Paper's inverse mapping, vectorized: [N] unit coords -> N values.

        Returns a plain Python list so config dicts hold native types
        (int/float/bool/whatever ``values`` holds), matching the scalar path.
        """
        a = np.clip(np.asarray(a, dtype=float), 0.0, 1.0)
        if self.kind == "continuous":
            return (a * (self.maximum - self.minimum) + self.minimum).tolist()
        if self.kind == "discrete":
            v = np.floor(a * (self.maximum - self.minimum) + self.minimum + 0.5)
            return np.clip(v, self.minimum, self.maximum).astype(int).tolist()
        if self.kind == "boolean":
            return [bool(x) for x in (a >= 0.5)]
        if self.kind == "log2_int":
            e_lo, e_hi = self._log2_span()
            idx = np.clip(np.floor(a * (e_hi - e_lo) + 0.5), 0, e_hi - e_lo)
            return [int(2 ** (e_lo + int(i))) for i in idx]
        # list kinds: the index space [0, len-1] is the discrete range
        k = len(self.values)
        idx = np.clip(np.floor(a * (k - 1) + 0.5), 0, k - 1).astype(int)
        return [self.values[i] for i in idx]

    def to_unit_batch(self, values: Sequence) -> np.ndarray:
        """Forward map, vectorized: N values -> [N] unit coords."""
        if self.kind in _LIST_KINDS:
            denom = max(1, len(self.values) - 1)
            return np.array([self.values.index(v) / denom for v in values],
                            np.float32)
        if self.kind == "boolean":
            return np.array([1.0 if v else 0.0 for v in values], np.float32)
        if self.kind == "log2_int":
            e_lo, e_hi = self._log2_span()
            if e_hi == e_lo:
                return np.zeros(len(values), np.float32)
            e = np.log2(np.asarray(values, dtype=float))
            return ((e - e_lo) / (e_hi - e_lo)).astype(np.float32)
        if self.maximum == self.minimum:
            return np.zeros(len(values), np.float32)
        v = np.asarray(values, dtype=float)
        return ((v - self.minimum) / (self.maximum - self.minimum)).astype(
            np.float32)

    # -- scalar maps (the N == 1 case of the batch maps) ---------------------

    def from_unit(self, a: float):
        """Paper's inverse mapping for a single coordinate a in [0,1]."""
        return self.from_unit_batch(np.array([a]))[0]

    def to_unit(self, value) -> float:
        """Forward map (used to seed the buffer with known configs)."""
        return float(self.to_unit_batch([value])[0])

    def values_from_indices(self, idx: np.ndarray) -> list:
        """Quantization indices -> native parameter values.

        ``idx[i]`` is the index ``from_unit_batch`` would land on (the same
        value ``jax_coord_maps``' in-graph ``idx`` computes), so the compact
        episode trace can store small ints and reconstruct the exact config
        values — same native types as ``from_unit_batch``. Quantized kinds
        only."""
        idx = np.asarray(idx)
        if self.kind == "continuous":
            raise ValueError(
                f"{self.name}: continuous parameters have no index space")
        if self.kind == "discrete":
            return (idx.astype(int) + int(self.minimum)).tolist()
        if self.kind == "boolean":
            return [bool(i) for i in idx]
        if self.kind == "log2_int":
            e_lo = self._log2_span()[0]
            return [int(2 ** (e_lo + int(i))) for i in idx]
        return [self.values[int(i)] for i in idx]

    # -- validation ----------------------------------------------------------

    def validate(self, value) -> bool:
        if self.kind in _LIST_KINDS:
            return value in self.values
        if self.kind == "boolean":
            return isinstance(value, (bool, np.bool_)) or value in (0, 1)
        if self.kind == "log2_int":
            return (float(value).is_integer() and _is_pow2(value)
                    and self.minimum <= value <= self.maximum)
        if self.kind == "discrete":
            return float(value).is_integer() and self.minimum <= value <= self.maximum
        return self.minimum <= value <= self.maximum


@dataclasses.dataclass(frozen=True)
class ParamSpace:
    """The m-dimensional static-parameter space Lambda (paper §II-A)."""

    specs: tuple

    def __post_init__(self):
        names = [s.name for s in self.specs]
        if len(set(names)) != len(names):
            raise ValueError("duplicate parameter names")

    @property
    def names(self) -> list:
        return [s.name for s in self.specs]

    @property
    def dim(self) -> int:
        return len(self.specs)

    # -- unit <-> config, scalar and vectorized ------------------------------

    def to_config(self, action: Sequence[float]) -> dict:
        if len(action) != self.dim:
            raise ValueError(f"action dim {len(action)} != param dim {self.dim}")
        return self.to_configs(np.asarray(action, dtype=float)[None, :])[0]

    def to_configs(self, actions: np.ndarray) -> list:
        """Vectorized inverse map: [N, m] unit actions -> N config dicts."""
        actions = np.asarray(actions, dtype=float)
        if actions.ndim != 2 or actions.shape[1] != self.dim:
            raise ValueError(
                f"actions shape {actions.shape} != (N, {self.dim})")
        columns = [s.from_unit_batch(actions[:, j])
                   for j, s in enumerate(self.specs)]
        return [dict(zip(self.names, row)) for row in zip(*columns)]

    def to_action(self, config: dict) -> np.ndarray:
        return self.to_actions([config])[0]

    # -- compact (index) trace support ---------------------------------------

    def index_dtype(self) -> np.dtype:
        """Smallest unsigned dtype holding every knob's quantization index.

        The compact episode trace (``core.episode``) stores per-step actions
        as these indices instead of float32 unit coordinates — knobs are
        quantized by construction, so an index round-trips exactly where a
        float action would cost 4 bytes per coordinate.

        Indices are *computed* in float32 inside the episode graph
        (``jax_coord_maps``), where integers are exact only up to 2**24 —
        beyond that the rounded index itself is lossy and the compact trace
        would silently decode to a *neighbouring* level. No real DFS knob
        has 16M levels, so that domain boundary is an error, not a wider
        dtype."""
        if not self.is_quantized:
            raise ValueError("continuous spaces have no index trace encoding")
        top = max(s.cardinality - 1 for s in self.specs)
        if top > 2 ** 24:
            raise ValueError(
                f"knob cardinality {top + 1} exceeds the exact-integer range "
                f"of the float32 index computation (2**24); the compact "
                f"index trace cannot represent this space losslessly")
        for dt in (np.uint8, np.uint16):
            if top <= np.iinfo(dt).max:
                return np.dtype(dt)
        return np.dtype(np.uint32)  # top <= 2**24, so uint32 always fits

    def configs_from_indices(self, idx: np.ndarray) -> list:
        """Vectorized index decode: [N, m] quantization indices -> N configs.

        The inverse of the in-graph quantization (``jax_coord_maps``'s
        ``idx``): for any action ``a``, ``configs_from_indices`` of the
        indices the env graph computed equals ``to_configs(a)`` — same
        native value types, same values (the graph quantizes in float32, the
        host map in float64; they agree away from the ~1-ulp rounding knife
        edges documented on ``jax_coord_maps``, exactly like env dynamics
        already do)."""
        idx = np.asarray(idx)
        if idx.ndim != 2 or idx.shape[1] != self.dim:
            raise ValueError(f"indices shape {idx.shape} != (N, {self.dim})")
        columns = [s.values_from_indices(idx[:, j])
                   for j, s in enumerate(self.specs)]
        return [dict(zip(self.names, row)) for row in zip(*columns)]

    def to_actions(self, configs: Sequence[dict]) -> np.ndarray:
        """Vectorized forward map: N config dicts -> [N, m] unit actions."""
        columns = [s.to_unit_batch([c[s.name] for c in configs])
                   for s in self.specs]
        return np.stack(columns, axis=-1).astype(np.float32)

    # -- defaults / validation / search support ------------------------------

    def default_config(self) -> dict:
        out = {}
        for s in self.specs:
            if s.default is not None:
                out[s.name] = s.default
            elif s.kind in _LIST_KINDS:
                out[s.name] = s.values[0]
            else:
                out[s.name] = s.from_unit(0.0)
        return out

    def validate(self, config: dict) -> bool:
        return all(s.validate(config[s.name]) for s in self.specs)

    def grid_axes(self, points_per_dim: int) -> list:
        """Per-dimension unit grids, capped at each parameter's cardinality.

        A boolean axis contributes 2 points, an 11-value log2_int axis at most
        11 — never ``points_per_dim`` redundant copies — so grids over
        mixed-type spaces enumerate distinct configurations only.
        """
        axes = []
        for s in self.specs:
            n = points_per_dim
            if s.cardinality is not None:
                n = min(n, s.cardinality)
            axes.append(np.linspace(0.0, 1.0, max(2, n)) if n > 1
                        else np.array([0.0]))
        return axes

    def grid_size(self, points_per_dim: int) -> int:
        """Number of grid points ``grid`` would produce (cheap pre-check)."""
        return int(np.prod([len(ax) for ax in self.grid_axes(points_per_dim)]))

    def grid(self, points_per_dim: int) -> list:
        """Cartesian grid of configs (used by the grid-search baseline)."""
        mesh = np.meshgrid(*self.grid_axes(points_per_dim), indexing="ij")
        flat = np.stack([m.reshape(-1) for m in mesh], axis=-1)
        return self.to_configs(flat)

    # -- in-graph (jit/vmap-safe) quantization -------------------------------

    @property
    def is_quantized(self) -> bool:
        """True when every parameter has finitely many values. Pure-JAX env
        models (``envs.base.EnvModel``) require a quantized space: the fused
        episode engine feeds raw unit actions to the env graph while the host
        adapter round-trips them through config dicts, and only quantized kinds
        survive that float32 round trip with the same decoded value."""
        return all(s.cardinality is not None for s in self.specs)


def jax_coord_maps(space: ParamSpace) -> list:
    """Per-coordinate in-graph versions of the paper's inverse action map.

    Returns one ``fn(a_scalar) -> dict`` per parameter (jit/vmap-safe jnp
    scalars in and out), each computing the same quantization as
    ``ParamSpec.from_unit`` — in float32, which agrees with the host float64
    map everywhere except knife-edge actions within ~1 ulp of a rounding
    boundary. Keys:

      value  decoded parameter value as float32 (booleans as 0/1)
      idx    quantization index (float32 integer; quantized kinds only)
      q      canonical unit coordinate of the decoded value (``to_unit`` of
             ``value``) — stable across the host dict round trip, so env
             models should derive dynamics from ``q``/``value``/``idx`` only
      log2   log2(value) where meaningful (log2_int, and list kinds whose
             values are all powers of two); absent otherwise

    Only quantized kinds are supported (see ``ParamSpace.is_quantized``).
    """
    import jax.numpy as jnp

    maps = []
    for spec in space.specs:
        if spec.cardinality is None:
            raise ValueError(
                f"{spec.name}: continuous parameters have no exact in-graph "
                "quantization; use the host tuning engine for this space")

        def make(spec=spec):
            card = spec.cardinality
            if spec.kind == "boolean":
                def fn(a):
                    idx = (a >= 0.5).astype(jnp.float32)
                    return {"value": idx, "idx": idx, "q": idx}
                return fn
            if spec.kind == "discrete":
                lo, hi = float(spec.minimum), float(spec.maximum)

                def fn(a):
                    v = jnp.clip(jnp.floor(a * (hi - lo) + lo + 0.5), lo, hi)
                    idx = v - lo
                    q = idx / max(1.0, hi - lo)
                    return {"value": v, "idx": idx, "q": q}
                return fn
            if spec.kind == "log2_int":
                e_lo, e_hi = spec._log2_span()
                values = jnp.asarray(
                    [float(2 ** e) for e in range(e_lo, e_hi + 1)], jnp.float32)

                def fn(a):
                    idx = jnp.clip(jnp.floor(a * (e_hi - e_lo) + 0.5),
                                   0, e_hi - e_lo)
                    q = idx / max(1, e_hi - e_lo)
                    return {"value": values[idx.astype(jnp.int32)], "idx": idx,
                            "q": q, "log2": idx + e_lo}
                return fn
            # list kinds (choice / categorical): index an explicit value table
            try:
                table = jnp.asarray([float(v) for v in spec.values],
                                    jnp.float32)
            except (TypeError, ValueError) as e:
                raise ValueError(
                    f"{spec.name}: in-graph maps need numeric values") from e
            log2_table = None
            if all(float(v) > 0 and float(v).is_integer() and _is_pow2(v)
                   for v in spec.values):
                log2_table = jnp.asarray(
                    [float(int(v).bit_length() - 1) for v in spec.values],
                    jnp.float32)

            def fn(a):
                idx = jnp.clip(jnp.floor(a * (card - 1) + 0.5), 0, card - 1)
                out = {"value": table[idx.astype(jnp.int32)], "idx": idx,
                       "q": idx / max(1, card - 1)}
                if log2_table is not None:
                    out["log2"] = log2_table[idx.astype(jnp.int32)]
                return out
            return fn

        maps.append(make())
    return maps
