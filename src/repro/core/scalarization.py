"""Multi-objective scalarization + reward (paper §II-A, §II-B-5).

State: each metric is min-max normalized to [0,1] with bounds from the metric specs
(domain knowledge) or inferred from data. Objective: weighted sum of normalized
performance indicators. Reward: proportional change of the weighted sum:

    r_t = (sum_i w_i s_{t+1}(i) - sum_i w_i s_t(i)) / sum_i w_i s_t(i)

All arithmetic here is float32 with a fixed accumulation order (the order the
metric names appear in ``specs``). That is deliberate: the fused episode engine
(``core.episode``) computes the identical normalization/objective/reward inside
one XLA program, and the host-loop tuning path must produce bit-identical
states and rewards so the two engines can be proven equal (the repo's
fleet-of-1 / scan-vs-host parity guarantees). float32 is also what the replay
buffer stores, so no precision reaches the learner either way.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

_F32 = np.float32


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """Normalization bounds for one metric (paper Table I rows + objectives)."""

    name: str
    minimum: float
    maximum: float
    scope: str = ""  # e.g. "OSC", "MDS", informational
    description: str = ""

    def norm(self, value: float) -> float:
        """Min-max normalization in float32 (bit-aligned with the fused engine)."""
        lo, hi = _F32(self.minimum), _F32(self.maximum)
        span = hi - lo
        if span <= 0:
            return 0.0
        return float(np.clip((_F32(value) - lo) / span, _F32(0.0), _F32(1.0)))


def normalize_state(metrics: Mapping[str, float], specs: Mapping[str, MetricSpec], order: list) -> np.ndarray:
    """s_t = [norm(P_1), ..., norm(P_k)] in a fixed metric order."""
    return np.array([specs[name].norm(metrics[name]) for name in order], np.float32)


def metric_bounds(specs: Mapping[str, MetricSpec], order: list) -> tuple:
    """(lo, span) float32 arrays in state order — the fused engine's view of
    the normalization bounds. ``span`` is 0 for degenerate specs (norm -> 0)."""
    lo = np.array([specs[name].minimum for name in order], np.float32)
    hi = np.array([specs[name].maximum for name in order], np.float32)
    return lo, hi - lo


@dataclasses.dataclass(frozen=True)
class Scalarizer:
    """Linear scalarization of the optimization objectives.

    ``weights`` maps objective metric name -> w_i. Objectives are a subset of the
    state metrics (throughput, IOPS, ...).
    """

    weights: Mapping[str, float]
    specs: Mapping[str, MetricSpec]

    def __post_init__(self):
        missing = set(self.weights) - set(self.specs)
        if missing:
            raise KeyError(f"objective weights without metric specs: {missing}")

    def weight_vector(self, order: list) -> np.ndarray:
        """Weights as a float32 vector over the state order (zeros elsewhere) —
        what the fused episode engine folds against the normalized state.
        Raises if a weighted metric is not part of the state order."""
        outside = set(self.weights) - set(order)
        if outside:
            raise KeyError(
                f"objective metrics {outside} are not state metrics; the "
                f"fused engine reads objectives off the state vector")
        return np.array([_F32(self.weights.get(name, 0.0)) for name in order],
                        np.float32)

    def objective(self, metrics: Mapping[str, float]) -> float:
        """G(P) = sum_i w_i * norm(P_i), accumulated in float32 in specs order.

        Terms fold in the order the metric names appear in ``specs`` (the state
        order for every environment in this repo) so the host loop and the
        fused engine — which folds w·s serially over the state vector, where
        zero-weight terms are exact no-ops — agree bitwise.
        """
        acc = _F32(0.0)
        for name in self.specs:
            if name in self.weights:
                acc = acc + _F32(self.weights[name]) * _F32(self.specs[name].norm(metrics[name]))
        return float(acc)

    def reward(self, prev_metrics: Mapping[str, float], new_metrics: Mapping[str, float]) -> float:
        """Proportional performance change (paper's r_t), in float32."""
        prev = _F32(self.objective(prev_metrics))
        new = _F32(self.objective(new_metrics))
        return float((new - prev) / np.maximum(prev, _F32(1e-6)))
