"""Multi-objective scalarization + reward (paper §II-A, §II-B-5).

State: each metric is min-max normalized to [0,1] with bounds from the metric specs
(domain knowledge) or inferred from data. Objective: weighted sum of normalized
performance indicators. Reward: proportional change of the weighted sum:

    r_t = (sum_i w_i s_{t+1}(i) - sum_i w_i s_t(i)) / sum_i w_i s_t(i)
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """Normalization bounds for one metric (paper Table I rows + objectives)."""

    name: str
    minimum: float
    maximum: float
    scope: str = ""  # e.g. "OSC", "MDS", informational
    description: str = ""

    def norm(self, value: float) -> float:
        if self.maximum <= self.minimum:
            return 0.0
        return float(np.clip((value - self.minimum) / (self.maximum - self.minimum), 0.0, 1.0))


def normalize_state(metrics: Mapping[str, float], specs: Mapping[str, MetricSpec], order: list) -> np.ndarray:
    """s_t = [norm(P_1), ..., norm(P_k)] in a fixed metric order."""
    return np.array([specs[name].norm(metrics[name]) for name in order], np.float32)


@dataclasses.dataclass(frozen=True)
class Scalarizer:
    """Linear scalarization of the optimization objectives.

    ``weights`` maps objective metric name -> w_i. Objectives are a subset of the
    state metrics (throughput, IOPS, ...).
    """

    weights: Mapping[str, float]
    specs: Mapping[str, MetricSpec]

    def objective(self, metrics: Mapping[str, float]) -> float:
        """G(P) = sum_i w_i * norm(P_i)."""
        return float(
            sum(w * self.specs[name].norm(metrics[name]) for name, w in self.weights.items())
        )

    def reward(self, prev_metrics: Mapping[str, float], new_metrics: Mapping[str, float]) -> float:
        """Proportional performance change (paper's r_t)."""
        prev = self.objective(prev_metrics)
        new = self.objective(new_metrics)
        return (new - prev) / max(prev, 1e-6)
