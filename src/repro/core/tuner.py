"""The Magpie tuning loop (paper Fig. 1).

Components map onto the paper's architecture:
  Metrics Collector  -> env.apply(config) returning the Table-I metric dict
  Memory Pool        -> agent.buffer (FIFO replay, §II-D)
  RL Model           -> agent (DDPG, §II-C)
  Controller         -> ParamSpace.to_config + env.apply (restart accounting)

Each tuning step: read state -> policy recommends a full configuration (all m
parameters at once, §II-B-4) -> apply (restarting workload/DFS, cost tracked) ->
reward = proportional scalarized performance change -> store -> learn.

``Tuner`` is a host shell over two interchangeable engines:

  engine="host"  the dict-based Python loop — one ``env.apply`` per step.
                 Works for ANY ``TuningEnvironment`` (real DFS, external
                 systems); this is the only engine for envs whose side
                 effects live outside JAX.
  engine="scan"  the fused whole-episode engine (``core.episode``): act, env
                 step, reward, buffer store and the learner compile into ONE
                 ``lax.scan`` program. Requires a pure-model environment
                 (``envs.base.ModelEnv``); bitwise-equal to engine="host" on
                 the same adapter (tests/test_episode.py), with per-step
                 timing amortized over the episode.

The final recommendation is the best configuration *seen* during tuning
(§III-E: 'it recommends the best it has seen so far'), evaluated with
``eval_runs`` repetitions (§III-B: 'evaluated ... with three runs').
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.agent import MagpieAgent
from repro.core.ddpg import DDPGConfig
from repro.core.scalarization import Scalarizer, normalize_state


@dataclasses.dataclass
class StepRecord:
    step: int
    config: dict
    metrics: dict
    objective: float
    reward: float
    restart_seconds: float
    action_seconds: float
    learn_seconds: float


def evaluate_config(env, config: dict, runs: int) -> dict:
    """Average metrics over ``runs`` long evaluation runs (paper: 30 min x3).

    Shared by ``Tuner`` and ``FleetTuner`` so the evaluation protocol has one
    source of truth (fleet-of-one parity depends on it). Sums first and
    divides once — per-run ``v / runs`` accumulation drifts in float and made
    the mean order-dependent."""
    acc: dict = {}
    for _ in range(runs):
        m = env.apply(config, eval_run=True)
        for k, v in m.items():
            acc[k] = acc.get(k, 0.0) + v
    return {k: v / runs for k, v in acc.items()}


def recommend_final(scalarizer: Scalarizer, best_config: dict,
                    policy_config: dict, evaluate) -> tuple:
    """§III-E final recommendation, shared by ``Tuner`` and ``FleetTuner``.

    Re-evaluates the best-seen configuration and — since the policy has been
    fitted to *denoise* observations via the metric state — the policy's own
    exploit-mode candidate, keeping the better. The paper's plateau behaviour
    ('recommends the best it has seen so far') is preserved because the policy
    candidate only replaces best-seen when it truly wins. Returns
    ``(config, evaluated_metrics, replaced)``.
    """
    best_metrics = evaluate(best_config)
    if policy_config != best_config:
        policy_metrics = evaluate(policy_config)
        if (scalarizer.objective(policy_metrics)
                > scalarizer.objective(best_metrics)):
            return dict(policy_config), policy_metrics, True
    return dict(best_config), best_metrics, False


@dataclasses.dataclass
class TuningResult:
    best_config: dict
    best_objective: float
    best_metrics: dict
    default_config: dict
    default_metrics: dict
    history: list
    simulated_restart_seconds: float
    wall_seconds: float
    #: guarded sessions only (core.guardrails): policy + per-session
    #: promotion/rollback counters + restart-budget accounting; None when
    #: guardrails are off
    guardrail_stats: Optional[dict] = None
    #: resilient sessions only (core.resilience): policy + cumulative
    #: non-finite/reset counters + degraded flag; None when resilience is off
    health_stats: Optional[dict] = None

    def gain(self, metric: str) -> float:
        """Proportional raw-metric gain of best vs default (paper's reported %)."""
        base = self.default_metrics[metric]
        return (self.best_metrics[metric] - base) / max(base, 1e-9)


class Tuner:
    def __init__(self, env, scalarizer: Scalarizer,
                 agent: Optional[MagpieAgent] = None,
                 eval_runs: int = 3, seed: int = 0, engine: str = "host",
                 policy=None, observation_scopes=None, resilience=None):
        """``agent=None`` sizes a default DDPG agent from the environment's
        ``ParamSpace`` (``DDPGConfig.for_env``) — the network's action head and
        the search box both follow the space, whether it is the paper's 2-D
        stripe space or an 8-D mixed-type space.

        ``engine``: "host" (dict loop, any environment) or "scan" (fused
        whole-episode ``lax.scan``; needs a ``ModelEnv``).

        ``policy`` (``core.guardrails.DeploymentPolicy``) turns on the
        shadow/canary deployment guardrails: proposals are scored in shadow
        inside the scan, promoted only past the min-gain/restart-budget gate
        and rolled back on regression. Scan engine only — the guarded body
        is an in-graph construct. ``policy=None`` (default) is bitwise the
        unguarded tuner.

        ``resilience`` (``core.resilience.ResiliencePolicy``) turns on the
        self-healing body: a last-good snapshot rides the scan carry, a
        non-finite learner state or observation branch-free resets the
        session to it, and past ``max_resets`` the session degrades to
        frozen-incumbent mode. Scan engine only; does not compose with
        ``policy``. ``resilience=None`` (default) is bitwise the plain
        tuner (program-identity off-path).

        ``observation_scopes`` (tuple of metric scopes, e.g. ``("OSC",)``)
        turns on the DIAL-style local-metric observation mode: the actor
        sees only metrics whose scope is in the tuple (``envs.metrics``
        scopes), modelling a decentralized client-side tuner that cannot
        read server counters. Reward/objective still read the full state —
        only the learner's observation is masked. Scan engine only;
        ``None`` (default) is bitwise the full-state tuner."""
        if engine not in ("host", "scan"):
            raise ValueError(f"unknown engine {engine!r}; use 'host' or 'scan'")
        if engine == "scan" and getattr(env, "model", None) is None:
            raise ValueError(
                "engine='scan' needs a pure-model environment (ModelEnv); "
                "real-DFS/external environments must use engine='host'")
        if policy is not None and engine != "scan":
            raise ValueError(
                "DeploymentPolicy guardrails run inside the episode scan; "
                "use engine='scan' (the host loop has no shadow/canary body)")
        if observation_scopes is not None and engine != "scan":
            raise ValueError(
                "observation_scopes masks the actor input inside the episode "
                "scan; use engine='scan'")
        if observation_scopes is not None and policy is not None:
            raise ValueError(
                "observation_scopes does not compose with DeploymentPolicy "
                "guardrails; run guarded tuners with full-state observation")
        if resilience is not None:
            from repro.core.resilience import normalize_resilience
            resilience = normalize_resilience(resilience)
        if resilience is not None and engine != "scan":
            raise ValueError(
                "ResiliencePolicy runs inside the episode scan; use "
                "engine='scan' (the host loop has no snapshot/reset body)")
        if resilience is not None and policy is not None:
            raise ValueError(
                "resilience does not compose with DeploymentPolicy "
                "guardrails; run guarded tuners without a ResiliencePolicy")
        self.env = env
        self.engine = engine
        self.policy = policy
        self.resilience = resilience
        self._health = None  # HealthState, persists across progressive runs
        self.health_events = np.zeros((0,), np.uint8)
        self._health_counters: Optional[dict] = None
        if observation_scopes is None:
            self._obs_mask = None
        else:
            from repro.core.sharing import SharingConfig, resolve_obs_mask
            self._obs_mask = resolve_obs_mask(
                SharingConfig(observation_scopes=tuple(observation_scopes)),
                env.metric_specs, env.state_metrics)
        self._guard = None  # GuardState, persists across progressive runs
        self.guard_events = np.zeros((0,), np.uint8)
        self.shadow_objectives = np.zeros((0,), np.float32)
        self._guard_counters: Optional[dict] = None
        self.scalarizer = scalarizer
        self.agent = agent or MagpieAgent(DDPGConfig.for_env(env), seed=seed)
        self.eval_runs = eval_runs
        self.history: list = []
        self.simulated_restart_seconds = 0.0
        # Baseline: metrics under the default configuration.
        self.default_config = env.param_space.default_config()
        self.default_metrics = self._evaluate(self.default_config, runs=eval_runs)
        self._cur_config = dict(self.default_config)
        self._cur_metrics = dict(self.default_metrics)
        self.best_config = dict(self.default_config)
        self.best_metrics = dict(self.default_metrics)
        self.best_objective = scalarizer.objective(self.default_metrics)

    # ------------------------------------------------------------------

    def _evaluate(self, config: dict, runs: int) -> dict:
        return evaluate_config(self.env, config, runs)

    def _state(self, metrics: dict) -> np.ndarray:
        return normalize_state(metrics, self.env.metric_specs, self.env.state_metrics)

    def _track_best(self, objective: float, config: dict, metrics: dict) -> None:
        if objective > self.best_objective:
            self.best_objective = objective
            self.best_config = dict(config)
            self.best_metrics = dict(metrics)

    # ------------------------------------------------------------------

    def run(self, steps: int, learn: bool = True) -> TuningResult:
        """Run ``steps`` tuning iterations; callable repeatedly (progressive tuning,
        paper Fig. 7 — the agent, buffer and noise state persist across calls)."""
        t_wall = time.perf_counter()
        if self.engine == "scan":
            self._run_scan(steps, learn)
        else:
            self._run_host(steps, learn)
        return self._finish(t_wall)

    def _run_host(self, steps: int, learn: bool) -> None:
        """The dict-based Fig. 1 loop — one host round trip per step."""
        start = len(self.history)
        for i in range(start, start + steps):
            state = self._state(self._cur_metrics)

            t0 = time.perf_counter()
            action = self.agent.act(state)
            config = self.env.param_space.to_config(action)
            metrics = self.env.apply(config)
            action_seconds = time.perf_counter() - t0

            restart = self.env.restart_cost(config, self._cur_config)
            self.simulated_restart_seconds += restart

            next_state = self._state(metrics)
            reward = self.scalarizer.reward(self._cur_metrics, metrics)
            objective = self.scalarizer.objective(metrics)

            t0 = time.perf_counter()
            if learn:
                self.agent.observe(state, action, reward, next_state)
                self.agent.learn()
            learn_seconds = time.perf_counter() - t0

            self._track_best(objective, config, metrics)
            self.history.append(StepRecord(
                step=i, config=config, metrics=metrics, objective=objective,
                reward=reward, restart_seconds=restart,
                action_seconds=action_seconds, learn_seconds=learn_seconds,
            ))
            self._cur_config = config
            self._cur_metrics = metrics

    def _run_scan(self, steps: int, learn: bool) -> None:
        """The fused engine: one XLA program for the whole episode, then the
        ``StepRecord`` history reconstructed from the scanned trace."""
        from repro.core.episode import run_episode_scan
        start = len(self.history)
        t0 = time.perf_counter()
        if self.policy is not None:
            from repro.core.guardrails import (
                empty_counters, guardrail_counters, init_guard_state,
                merge_counters)
            if self._guard is None:
                self._guard = init_guard_state(
                    self.env.param_space, self._cur_config,
                    self.scalarizer.objective(self._cur_metrics))
            trace, self._guard = run_episode_scan(
                self.env, self.agent, self.scalarizer, self._cur_metrics,
                steps, learn=learn, policy=self.policy, guard=self._guard)
            self.guard_events = np.concatenate(
                [self.guard_events, trace.guard_events])
            self.shadow_objectives = np.concatenate(
                [self.shadow_objectives, trace.shadow_objectives])
            self._guard_counters = merge_counters(
                self._guard_counters or empty_counters(),
                guardrail_counters(trace.guard_events, trace.restarts))
        elif self.resilience is not None:
            from repro.core.resilience import (
                empty_health_counters, health_counters, init_health_state,
                merge_health_counters)
            if self._health is None:
                self._health = init_health_state(self.agent.state,
                                                 self.resilience)
            trace, self._health = run_episode_scan(
                self.env, self.agent, self.scalarizer, self._cur_metrics,
                steps, learn=learn, obs_mask=self._obs_mask,
                resilience=self.resilience, health=self._health)
            self.health_events = np.concatenate(
                [self.health_events, trace.health_events])
            self._health_counters = merge_health_counters(
                self._health_counters or empty_health_counters(),
                health_counters(trace.health_events))
        else:
            trace = run_episode_scan(self.env, self.agent, self.scalarizer,
                                 self._cur_metrics, steps, learn=learn,
                                 obs_mask=self._obs_mask)
        per_step = (time.perf_counter() - t0) / max(1, steps)

        configs = self.env.param_space.configs_from_indices(trace.action_idx)
        names = self.env.state_metrics
        prev_config = self._cur_config
        for t in range(steps):
            metrics = {n: float(v) for n, v in zip(names, trace.metrics[t])}
            objective = float(trace.objectives[t])
            restart = float(trace.restarts[t])
            self.simulated_restart_seconds += restart
            if restart > 0:  # adapter-side restart log (scope bookkeeping)
                self.env.restart_events.append(
                    (self.env._scope(configs[t], prev_config), restart))
            self._track_best(objective, configs[t], metrics)
            self.history.append(StepRecord(
                step=start + t, config=configs[t], metrics=metrics,
                objective=objective, reward=float(trace.rewards[t]),
                restart_seconds=restart, action_seconds=per_step,
                learn_seconds=0.0,
            ))
            prev_config = configs[t]
            self._cur_config = configs[t]
            if (self.resilience is None
                    or bool(np.isfinite(trace.metrics[t]).all())):
                # resilient carry semantics: a corrupted reading is recorded
                # raw in the history but never becomes the next observation
                # baseline (or the final recommendation's actor input)
                self._cur_metrics = metrics
        self.env._last_config = dict(self._cur_config)

    def guardrail_stats(self) -> Optional[dict]:
        """Exported guardrail record (None when guardrails are off): the
        policy, cumulative promotion/rollback/rejection counters, restart
        budget spent/remaining and the current live config."""
        if self.policy is None:
            return None
        from repro.core.guardrails import empty_counters, guardrail_stats
        return guardrail_stats(self.policy, self._guard,
                               self._guard_counters or empty_counters(),
                               space=self.env.param_space)

    def health_stats(self) -> Optional[dict]:
        """Exported health record (None when resilience is off): the policy,
        cumulative non-finite/reset counters, the degraded flag and how many
        steps ran since the last snapshot refresh."""
        if self.resilience is None:
            return None
        from repro.core.resilience import empty_health_counters, health_stats
        return health_stats(self.resilience, self._health,
                            self._health_counters or empty_health_counters())

    def _finish(self, t_wall: float) -> TuningResult:
        """§III-E final recommendation + result assembly (shared by engines)."""
        policy_action = self.agent.act(self._state(self._cur_metrics), explore=False)
        policy_config = self.env.param_space.to_config(policy_action)
        config, best_metrics, replaced = recommend_final(
            self.scalarizer, self.best_config, policy_config,
            lambda c: self._evaluate(c, runs=self.eval_runs))
        if replaced:
            self.best_config = config
            self.best_metrics = dict(best_metrics)
            self.best_objective = self.scalarizer.objective(best_metrics)
        return TuningResult(
            best_config=dict(self.best_config),
            best_objective=self.scalarizer.objective(best_metrics),
            best_metrics=best_metrics,
            default_config=dict(self.default_config),
            default_metrics=dict(self.default_metrics),
            history=list(self.history),
            simulated_restart_seconds=self.simulated_restart_seconds,
            wall_seconds=time.perf_counter() - t_wall,
            guardrail_stats=self.guardrail_stats(),
            health_stats=self.health_stats(),
        )
