"""The Magpie tuning loop (paper Fig. 1).

Components map onto the paper's architecture:
  Metrics Collector  -> env.apply(config) returning the Table-I metric dict
  Memory Pool        -> agent.buffer (FIFO replay, §II-D)
  RL Model           -> agent (DDPG, §II-C)
  Controller         -> ParamSpace.to_config + env.apply (restart accounting)

Each tuning step: read state -> policy recommends a full configuration (all m
parameters at once, §II-B-4) -> apply (restarting workload/DFS, cost tracked) ->
reward = proportional scalarized performance change -> store -> learn.

The final recommendation is the best configuration *seen* during tuning
(§III-E: 'it recommends the best it has seen so far'), evaluated with
``eval_runs`` repetitions (§III-B: 'evaluated ... with three runs').
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np

from repro.core.agent import MagpieAgent
from repro.core.ddpg import DDPGConfig
from repro.core.scalarization import Scalarizer, normalize_state


@dataclasses.dataclass
class StepRecord:
    step: int
    config: dict
    metrics: dict
    objective: float
    reward: float
    restart_seconds: float
    action_seconds: float
    learn_seconds: float


def evaluate_config(env, config: dict, runs: int) -> dict:
    """Average metrics over ``runs`` long evaluation runs (paper: 30 min x3).

    Shared by ``Tuner`` and ``FleetTuner`` so the evaluation protocol has one
    source of truth (fleet-of-one parity depends on it).
    """
    acc: dict = {}
    for _ in range(runs):
        m = env.apply(config, eval_run=True)
        for k, v in m.items():
            acc[k] = acc.get(k, 0.0) + v / runs
    return acc


def recommend_final(scalarizer: Scalarizer, best_config: dict,
                    policy_config: dict, evaluate) -> tuple:
    """§III-E final recommendation, shared by ``Tuner`` and ``FleetTuner``.

    Re-evaluates the best-seen configuration and — since the policy has been
    fitted to *denoise* observations via the metric state — the policy's own
    exploit-mode candidate, keeping the better. The paper's plateau behaviour
    ('recommends the best it has seen so far') is preserved because the policy
    candidate only replaces best-seen when it truly wins. Returns
    ``(config, evaluated_metrics, replaced)``.
    """
    best_metrics = evaluate(best_config)
    if policy_config != best_config:
        policy_metrics = evaluate(policy_config)
        if (scalarizer.objective(policy_metrics)
                > scalarizer.objective(best_metrics)):
            return dict(policy_config), policy_metrics, True
    return dict(best_config), best_metrics, False


@dataclasses.dataclass
class TuningResult:
    best_config: dict
    best_objective: float
    best_metrics: dict
    default_config: dict
    default_metrics: dict
    history: list
    simulated_restart_seconds: float
    wall_seconds: float

    def gain(self, metric: str) -> float:
        """Proportional raw-metric gain of best vs default (paper's reported %)."""
        base = self.default_metrics[metric]
        return (self.best_metrics[metric] - base) / max(base, 1e-9)


class Tuner:
    def __init__(self, env, scalarizer: Scalarizer,
                 agent: Optional[MagpieAgent] = None,
                 eval_runs: int = 3, seed: int = 0):
        """``agent=None`` sizes a default DDPG agent from the environment's
        ``ParamSpace`` (``DDPGConfig.for_env``) — the network's action head and
        the search box both follow the space, whether it is the paper's 2-D
        stripe space or an 8-D mixed-type space."""
        self.env = env
        self.scalarizer = scalarizer
        self.agent = agent or MagpieAgent(DDPGConfig.for_env(env), seed=seed)
        self.eval_runs = eval_runs
        self.history: list = []
        self.simulated_restart_seconds = 0.0
        # Baseline: metrics under the default configuration.
        self.default_config = env.param_space.default_config()
        self.default_metrics = self._evaluate(self.default_config, runs=eval_runs)
        self._cur_config = dict(self.default_config)
        self._cur_metrics = dict(self.default_metrics)
        self.best_config = dict(self.default_config)
        self.best_metrics = dict(self.default_metrics)
        self.best_objective = scalarizer.objective(self.default_metrics)

    # ------------------------------------------------------------------

    def _evaluate(self, config: dict, runs: int) -> dict:
        return evaluate_config(self.env, config, runs)

    def _state(self, metrics: dict) -> np.ndarray:
        return normalize_state(metrics, self.env.metric_specs, self.env.state_metrics)

    # ------------------------------------------------------------------

    def run(self, steps: int, learn: bool = True) -> TuningResult:
        """Run ``steps`` tuning iterations; callable repeatedly (progressive tuning,
        paper Fig. 7 — the agent, buffer and noise state persist across calls)."""
        t_wall = time.perf_counter()
        start = len(self.history)
        for i in range(start, start + steps):
            state = self._state(self._cur_metrics)

            t0 = time.perf_counter()
            action = self.agent.act(state)
            config = self.env.param_space.to_config(action)
            metrics = self.env.apply(config)
            action_seconds = time.perf_counter() - t0

            restart = self.env.restart_cost(config, self._cur_config)
            self.simulated_restart_seconds += restart

            next_state = self._state(metrics)
            reward = self.scalarizer.reward(self._cur_metrics, metrics)
            objective = self.scalarizer.objective(metrics)

            t0 = time.perf_counter()
            if learn:
                self.agent.observe(state, action, reward, next_state)
                self.agent.learn()
            learn_seconds = time.perf_counter() - t0

            if objective > self.best_objective:
                self.best_objective = objective
                self.best_config = dict(config)
                self.best_metrics = dict(metrics)

            self.history.append(StepRecord(
                step=i, config=config, metrics=metrics, objective=objective,
                reward=reward, restart_seconds=restart,
                action_seconds=action_seconds, learn_seconds=learn_seconds,
            ))
            self._cur_config = config
            self._cur_metrics = metrics

        policy_action = self.agent.act(self._state(self._cur_metrics), explore=False)
        policy_config = self.env.param_space.to_config(policy_action)
        config, best_metrics, replaced = recommend_final(
            self.scalarizer, self.best_config, policy_config,
            lambda c: self._evaluate(c, runs=self.eval_runs))
        if replaced:
            self.best_config = config
            self.best_metrics = dict(best_metrics)
            self.best_objective = self.scalarizer.objective(best_metrics)
        return TuningResult(
            best_config=dict(self.best_config),
            best_objective=self.scalarizer.objective(best_metrics),
            best_metrics=best_metrics,
            default_config=dict(self.default_config),
            default_metrics=dict(self.default_metrics),
            history=list(self.history),
            simulated_restart_seconds=self.simulated_restart_seconds,
            wall_seconds=time.perf_counter() - t_wall,
        )
