"""Persistent fleet serving: leased chunk slots, mid-flight join/leave,
checkpointed bit-identical resume.

``FleetTuner`` fixes its roster at ``from_grid`` time — the fleet IS the
grid. Magpie's deployment story (tuning live tenants of a shared file
system) needs the opposite: sessions arrive and depart while the fleet
keeps running. ``FleetService`` lifts the streaming chunked episode runtime
into a persistent serving loop — the worker/serving-loop split of the
ROADMAP's vLLM TPU-worker exemplar:

  * slots are LEASED: the compiled chunk program is fixed at width C for
    the service's whole life; a joining session claims the lowest free
    slot and frees it on leave. Every ``advance`` runs ``ceil(active/C)``
    chunks of exactly C rows (vacant rows padded with a replicated live
    row, padded results discarded), so one donated executable serves any
    population.
  * join/leave are REQUESTS, queued and applied only at ``advance``
    boundaries — membership never changes mid-episode. That, plus vmap row
    independence (a session's whole trajectory derives from its own seed
    streams, never from its row placement or chunk-mates), makes churn
    bit-neutral for surviving sessions: the churn CI lane pins a
    join/leave-every-round service against a static fleet, exactly.
  * per-session progress — learner params + opt state, FIFO replay, env
    model state, exploration streams (LHS plan position, OU-noise RNG),
    on-device learn key, step counter, decision history — checkpoints
    through ``checkpoint/store.py`` (atomic publish, CRC-verified read),
    so a killed service restores and continues bit-identically. A partial
    or corrupt checkpoint RAISES (``KeyError``/``IOError``) rather than
    silently reinitializing a session from scratch.

Sessions of different ages ride one chunk program because the episode
engine's exploration inputs — including the warmup mask — are per-session
(``core.episode``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.agent import lhs_warmup_plan
from repro.core.ddpg import DDPGConfig, OUNoise, actor_apply, fleet_init
from repro.core.episode import (
    BufferState,
    EpisodeCarry,
    EpisodeTrace,
    _compiled_episode,
    _pad_rows,
    decode_restarts,
    live_device_bytes,
    stream_chunks,
)
from repro.core.fleet import replay_compact_trace
from repro.core.scalarization import (
    Scalarizer,
    metric_bounds,
    normalize_state,
)
from repro.core.tuner import (
    StepRecord,
    TuningResult,
    evaluate_config,
    recommend_final,
)
from repro.checkpoint.store import (
    restore_checkpoint,
    restore_into,
    save_checkpoint,
)


@dataclasses.dataclass
class _Session:
    """One tenant's complete tuning state, host-resident between rounds."""

    sid: int
    label: str
    workload: str
    weights: dict
    seed: int
    env: object                # ModelEnv (owns model params + model_state)
    scalarizer: Scalarizer
    ddpg: object               # DDPGState pytree, UNSTACKED numpy leaves
    buf: dict                  # {"s","a","r","s2"} numpy + "next","size" ints
    learn_key: np.ndarray
    noise: OUNoise
    warmup_plan: np.ndarray    # [warmup_steps, m]
    steps_taken: int
    default_config: dict
    default_metrics: dict
    cur_config: dict
    cur_metrics: dict
    best_config: dict
    best_metrics: dict
    best_objective: float
    history: list
    restart_seconds: float
    joined_at: float
    # guardrails (service-wide policy; None when guardrails are off)
    guard: object = None        # core.guardrails.GuardState, numpy leaves
    guard_counters: Optional[dict] = None
    # resilience (service-wide policy; None when resilience is off)
    health: object = None       # core.resilience.HealthState, numpy leaves
    health_counters: Optional[dict] = None


class FleetService:
    """A persistent, elastic fleet of Magpie tuning sessions.

    ``chunk`` is the leased slot width C — the one compiled episode width
    for the service's lifetime. ``request_join``/``request_leave`` enqueue
    membership changes; ``advance(steps)`` applies the queue at its
    boundary and then runs ``steps`` fused tuning iterations for every
    active session. ``advance(0)`` is a membership-only boundary.

    Each session is seeded exactly like ``MagpieAgent(cfg, seed=s)`` /
    ``FleetTuner``'s cells, so a session that joins at round 0 and leaves
    after the same rounds reproduces the static fleet's trajectory.
    ``leave`` finalizes the session with the shared §III-E rule
    (``recommend_final``) and returns its ``TuningResult``.
    """

    def __init__(self, *, chunk: int, env_factory=None, env_cls=None,
                 ddpg_config: Optional[DDPGConfig] = None,
                 buffer_capacity: int = 64, warmup_steps: int = 8,
                 eval_runs: int = 3, overlap: bool = True,
                 checkpoint_dir: Optional[str] = None, keep: int = 3,
                 policy=None, sharing=None, cell_size: int = 1,
                 resilience=None, supervisor=None, chaos=None):
        from repro.core.sharing import normalize_sharing
        if chunk <= 0:
            raise ValueError(f"chunk must be positive, got {chunk}")
        sharing = normalize_sharing(sharing)
        if sharing is not None and policy is not None:
            raise ValueError(
                "experience sharing does not compose with DeploymentPolicy "
                "guardrails; run guarded services with sharing off")
        if resilience is not None:
            from repro.core.resilience import normalize_resilience
            resilience = normalize_resilience(resilience)
        if resilience is not None and policy is not None:
            raise ValueError(
                "resilience does not compose with DeploymentPolicy "
                "guardrails; run guarded services without a ResiliencePolicy")
        if supervisor is not None:
            from repro.core.resilience import normalize_supervisor
            supervisor = normalize_supervisor(supervisor)
        cell_modes = sharing is not None and (sharing.shared_replay
                                              or sharing.averaging)
        cell_size = int(cell_size) if cell_modes else 1
        if cell_modes:
            if cell_size < 1:
                raise ValueError(f"cell_size must be >= 1, got {cell_size}")
            if chunk % cell_size != 0:
                raise ValueError(
                    f"chunk ({chunk}) must be a multiple of cell_size "
                    f"({cell_size}) so cells never span chunk programs")
        if env_factory is not None and env_cls is not None:
            raise ValueError("pass env_factory OR env_cls, not both")
        if env_factory is None:
            from repro.envs.lustre_sim import LustreSimEnv
            cls_ = env_cls or LustreSimEnv

            def env_factory(workload, seed):
                return cls_(workload, seed=seed).to_model_env()
        self.chunk = int(chunk)
        self.env_factory = env_factory
        self.cfg = ddpg_config
        self.buffer_capacity = buffer_capacity
        self.warmup_steps = warmup_steps
        self.eval_runs = eval_runs
        self.overlap = overlap
        self.checkpoint_dir = checkpoint_dir
        self.keep = keep
        # service-wide DeploymentPolicy (core.guardrails); None = off,
        # bitwise the unguarded service
        self.policy = policy
        # service-wide ResiliencePolicy (core.resilience); None = off,
        # bitwise (and by executable identity) the plain service
        self.resilience = resilience
        # host-side chunk supervision: retries are bitwise-invisible on
        # success; a chunk that keeps failing is SKIPPED and its sessions
        # quarantined through the leave path at the next boundary (the
        # supervisor's on_failure is forced to "skip" inside advance —
        # a persistent service must survive, not crash)
        self.supervisor = supervisor
        self.chaos = chaos
        # service-wide SharingConfig (core.sharing); None = off, bitwise
        # (and by executable identity) the non-sharing service. Sessions
        # with the same workload x objective bind into cells of up to
        # ``cell_size`` seats at advance() boundaries; a cell's merged
        # replay window and averaging clock live in ``_cells`` and die with
        # its last member.
        self.sharing = sharing
        self.cell_size = cell_size
        self._cell_modes = cell_modes
        self._cells: dict = {}      # cell id -> {key, seats, steps, buf}
        self._next_cell = 0
        self._obs_mask = None       # resolved lazily from the first env
        self.total_steps = 0
        self._slots: list = []          # slot index -> sid or None (leases)
        self._sessions: dict = {}       # sid -> _Session (leased only)
        self._join_queue: list = []     # _Session, in request order
        self._leave_queue: list = []    # sid, in request order
        self._completed: dict = {}      # sid -> TuningResult
        self._next_sid = 0
        self._actor_tx = None
        self._critic_tx = None
        self.last_stats: dict = {}

    # -- membership requests ------------------------------------------------

    def request_join(self, workload: str, weights: Mapping[str, float],
                     seed: int, label: Optional[str] = None) -> int:
        """Queue a new tuning session; leased at the next boundary.

        The session is fully initialized NOW (env build + default-config
        evaluation, mirroring ``FleetTuner.from_grid``) so the join order —
        not the boundary order — fixes its RNG streams. Returns its sid.
        """
        sid = self._next_sid
        self._next_sid += 1
        if label is None:
            label = f"{workload}|{'+'.join(sorted(weights))}|seed{seed}"
        self._join_queue.append(
            self._new_session(sid, workload, dict(weights), seed, label))
        return sid

    def request_leave(self, sid: int) -> None:
        """Queue a session's departure; finalized at the next boundary."""
        if sid not in self._sessions and \
                all(s.sid != sid for s in self._join_queue):
            raise KeyError(f"unknown or already-finished session {sid}")
        if sid not in self._leave_queue:
            self._leave_queue.append(sid)

    def result(self, sid: int) -> TuningResult:
        """The ``TuningResult`` of a departed session."""
        if sid not in self._completed:
            raise KeyError(f"session {sid} has not left (or never existed)")
        return self._completed[sid]

    @property
    def active(self) -> dict:
        """{sid: label} of currently leased sessions."""
        return {sid: s.label for sid, s in self._sessions.items()}

    def lease_table(self) -> list:
        """slot index -> sid (or None): the service's chunk-row leases."""
        return list(self._slots)

    # -- session construction ------------------------------------------------

    def _new_session(self, sid, workload, weights, seed, label,
                     evaluate_default: bool = True) -> _Session:
        env = self.env_factory(workload, seed)
        if self.cfg is None:
            self.cfg = DDPGConfig.for_env(env)
        scal = Scalarizer(weights=weights, specs=env.metric_specs)
        # identical to FleetAgent's per-seed streams (width-1 vmap init
        # produces the same per-key values as any other width)
        states, (atx, ctx) = fleet_init(
            jnp.stack([jax.random.PRNGKey(seed)]), self.cfg)
        if self._actor_tx is None:
            self._actor_tx, self._critic_tx = atx, ctx
        ddpg = jax.tree_util.tree_map(lambda x: np.asarray(x)[0], states)
        cap, k, m = self.buffer_capacity, self.cfg.state_dim, \
            self.cfg.action_dim
        buf = {"s": np.zeros((cap, k), np.float32),
               "a": np.zeros((cap, m), np.float32),
               "r": np.zeros((cap,), np.float32),
               "s2": np.zeros((cap, k), np.float32),
               "next": 0, "size": 0}
        default_config = env.param_space.default_config()
        if evaluate_default:
            default_metrics = evaluate_config(env, default_config,
                                              self.eval_runs)
        else:
            default_metrics = {}  # restore path fills from the checkpoint
        guard = None
        if self.policy is not None:
            from repro.core.guardrails import init_guard_state
            guard = init_guard_state(
                env.param_space, default_config,
                scal.objective(default_metrics) if default_metrics else 0.0)
        health = None
        if self.resilience is not None:
            from repro.core.resilience import init_health_state
            health = init_health_state(ddpg, self.resilience)
        return _Session(
            sid=sid, label=label, workload=workload, weights=weights,
            seed=seed, env=env, scalarizer=scal, ddpg=ddpg, buf=buf,
            learn_key=np.asarray(jax.random.PRNGKey(seed + 3)),
            noise=OUNoise(m, seed=seed + 1),
            warmup_plan=lhs_warmup_plan(
                np.random.default_rng(seed + 2), self.warmup_steps, m),
            steps_taken=0,
            default_config=dict(default_config),
            default_metrics=dict(default_metrics),
            cur_config=dict(default_config),
            cur_metrics=dict(default_metrics),
            best_config=dict(default_config),
            best_metrics=dict(default_metrics),
            best_objective=(scal.objective(default_metrics)
                            if default_metrics else float("-inf")),
            history=[], restart_seconds=0.0, joined_at=time.perf_counter(),
            guard=guard, health=health)

    # -- boundary: apply the request queue -----------------------------------

    def _lease(self, sess: _Session) -> None:
        for i, sid in enumerate(self._slots):
            if sid is None:
                self._slots[i] = sess.sid
                break
        else:
            self._slots.append(sess.sid)
        self._sessions[sess.sid] = sess

    def _apply_requests(self) -> None:
        # leaves first, so a same-boundary join can reuse the freed slot
        for sid in self._leave_queue:
            if sid in self._sessions:
                self._finalize(self._sessions.pop(sid))
                self._slots[self._slots.index(sid)] = None
            else:  # joined and left within one boundary: never leased
                sess = next(s for s in self._join_queue if s.sid == sid)
                self._join_queue.remove(sess)
                self._finalize(sess)
        self._leave_queue = []
        for sess in self._join_queue:
            self._lease(sess)
        self._join_queue = []
        if self._cell_modes:
            self._bind_cells()

    # -- cell topology (experience sharing) ----------------------------------

    @staticmethod
    def _cell_key(sess: _Session) -> tuple:
        return (sess.workload, tuple(sorted(sess.weights.items())))

    def _new_cell_buf(self) -> dict:
        cap, k, m = self.buffer_capacity, self.cfg.state_dim, \
            self.cfg.action_dim
        return {"s": np.zeros((cap, k), np.float32),
                "a": np.zeros((cap, m), np.float32),
                "r": np.zeros((cap,), np.float32),
                "s2": np.zeros((cap, k), np.float32),
                "next": 0, "size": 0}

    def _bind_cells(self) -> None:
        """Re-bind cell membership at a boundary (sharing only).

        A cell is ``cell_size`` seats keyed by (workload, objective):
        departing sessions free their seat, joining sessions take the lowest
        free seat of the lowest matching cell (or found a new cell). Seats —
        not slots — fix a member's lane inside the cell program, so
        surviving members keep their lane across churn. A cell whose last
        member leaves is dropped WITH its merged replay window: experience
        belongs to the tenants that generated it."""
        for cid in sorted(self._cells):
            rec = self._cells[cid]
            rec["seats"] = [sid if sid in self._sessions else None
                            for sid in rec["seats"]]
            if all(sid is None for sid in rec["seats"]):
                del self._cells[cid]
        seated = {sid for rec in self._cells.values()
                  for sid in rec["seats"] if sid is not None}
        for sid in sorted(self._sessions):  # sid order: deterministic
            if sid in seated:
                continue
            key = self._cell_key(self._sessions[sid])
            for cid in sorted(self._cells):
                rec = self._cells[cid]
                if rec["key"] == key and None in rec["seats"]:
                    rec["seats"][rec["seats"].index(None)] = sid
                    break
            else:
                buf = (self._new_cell_buf()
                       if self.sharing.shared_replay else None)
                self._cells[self._next_cell] = {
                    "key": key,
                    "seats": [sid] + [None] * (self.cell_size - 1),
                    "steps": 0, "buf": buf}
                self._next_cell += 1

    def _session_guardrail_stats(self, sess: _Session) -> Optional[dict]:
        if self.policy is None:
            return None
        from repro.core.guardrails import empty_counters, guardrail_stats
        return guardrail_stats(self.policy, sess.guard,
                               sess.guard_counters or empty_counters(),
                               space=sess.env.param_space)

    def guardrail_stats(self, sid: int) -> Optional[dict]:
        """An ACTIVE session's exported guardrail record (None when off)."""
        if sid not in self._sessions:
            raise KeyError(f"session {sid} is not active")
        return self._session_guardrail_stats(self._sessions[sid])

    def _session_health_stats(self, sess: _Session) -> Optional[dict]:
        if self.resilience is None:
            return None
        from repro.core.resilience import empty_health_counters, health_stats
        return health_stats(self.resilience, sess.health,
                            sess.health_counters or empty_health_counters())

    def health_stats(self, sid: int) -> Optional[dict]:
        """An ACTIVE session's exported health record (None when off)."""
        if sid not in self._sessions:
            raise KeyError(f"session {sid} is not active")
        return self._session_health_stats(self._sessions[sid])

    def _finalize(self, sess: _Session) -> None:
        """§III-E final recommendation for one departing session."""
        state_vec = normalize_state(sess.cur_metrics, sess.env.metric_specs,
                                    sess.env.state_metrics)
        a = np.asarray(actor_apply(
            jax.tree_util.tree_map(jnp.asarray, sess.ddpg.actor),
            jnp.asarray(state_vec, jnp.float32)))
        policy_config = sess.env.param_space.to_config(
            np.clip(a, 0.0, 1.0).astype(np.float32))
        config, best_metrics, replaced = recommend_final(
            sess.scalarizer, sess.best_config, policy_config,
            lambda c: evaluate_config(sess.env, c, self.eval_runs))
        if replaced:
            sess.best_config = dict(config)
        self._completed[sess.sid] = TuningResult(
            best_config=dict(sess.best_config),
            best_objective=sess.scalarizer.objective(best_metrics),
            best_metrics=best_metrics,
            default_config=dict(sess.default_config),
            default_metrics=dict(sess.default_metrics),
            history=list(sess.history),
            simulated_restart_seconds=float(sess.restart_seconds),
            wall_seconds=time.perf_counter() - sess.joined_at,
            guardrail_stats=self._session_guardrail_stats(sess),
            health_stats=self._session_health_stats(sess))

    # -- the serving loop ----------------------------------------------------

    def advance(self, steps: int) -> list:
        """One boundary + ``steps`` fused tuning iterations for every active
        session. Returns the sids that advanced (slot order)."""
        self._apply_requests()
        order = [sid for sid in self._slots if sid is not None]
        if not order or steps <= 0:
            return []
        sessions = [self._sessions[sid] for sid in order]
        quarantined = self._advance_sessions(sessions, steps)
        self.total_steps += steps
        for sid in quarantined:
            # the chunk exhausted its supervised retries: its sessions keep
            # their pre-episode state and leave through the normal path at
            # the next boundary — bit-neutral for every surviving session
            self.request_leave(sid)
        return order

    def _resolve_obs_mask(self, env):
        if self.sharing is None or self.sharing.observation_scopes is None:
            return None
        if self._obs_mask is None:
            from repro.core.sharing import resolve_obs_mask
            self._obs_mask = resolve_obs_mask(
                self.sharing, env.metric_specs, env.state_metrics)
        return self._obs_mask

    def _advance_sessions(self, sessions: Sequence[_Session],
                          steps: int) -> list:
        """Run one ``steps``-long episode segment for ``sessions`` through
        the chunked (double-buffered) episode program — the service-side
        mirror of ``core.episode.run_fleet_episode_scan``, with per-session
        ages, FIFO cursors and exploration streams first-class.

        With experience sharing on, program rows are CELL-ordered (seat
        order within each cell) instead of slot-ordered: vacant seats ride
        as inactive replicas of the cell's first live member — they compute
        but never write to the merged window, carry zero averaging weight,
        and their results are discarded — so a ragged cell runs the same
        fixed-shape cell program as a full one.

        Returns the sids to QUARANTINE: with a ``ChunkSupervisor``, a chunk
        that exhausts its retries is skipped — its rows' host state is
        untouched (the drain never ran) and its sessions are handed back to
        ``advance`` for the leave path. The chunk schedule is pure
        scheduling, so skipping chunk i never perturbs chunk j."""
        step_fns = {s.env.model.step_fn for s in sessions}
        if len(step_fns) != 1:
            raise ValueError("all service sessions must share one env model "
                             "structure (same space / model class)")
        cell_modes = self._cell_modes
        cs = self.cell_size
        shared_replay = cell_modes and self.sharing.shared_replay
        obs_mask = self._resolve_obs_mask(sessions[0].env)
        uindex = {s.sid: j for j, s in enumerate(sessions)}

        # -- per-session exploration, consumed ONCE per unique session -------
        # (each session consumes ITS OWN streams at ITS OWN age — mixed-age
        # chunks and, under sharing, mixed-age cells stay exact)
        u = len(sessions)
        cfg = self.cfg
        k_dim, m_dim = cfg.state_dim, cfg.action_dim
        use_warmup_u = np.zeros((u, steps), bool)
        warmup_u = np.zeros((u, steps, m_dim), np.float32)
        noise_u = np.zeros((u, steps, m_dim), np.float32)
        for j, s in enumerate(sessions):
            s0 = s.steps_taken
            for t in range(steps):
                if s0 + t < self.warmup_steps:
                    use_warmup_u[j, t] = True
                    warmup_u[j, t] = s.warmup_plan[s0 + t]
                else:
                    noise_u[j, t] = s.noise()
            s.steps_taken += steps

        if cell_modes:
            # cell-ordered rows; vacant seats replicate the first live
            # member (inactive, non-primary: results + state discarded)
            rows, ridx, active_rows, primary_rows, row_cells = \
                [], [], [], [], []
            for cid in sorted(self._cells):
                rec = self._cells[cid]
                live = [sid for sid in rec["seats"] if sid is not None]
                rep = self._sessions[live[0]]
                for sid in rec["seats"]:
                    s = self._sessions[sid] if sid is not None else rep
                    rows.append(s)
                    ridx.append(uindex[s.sid])
                    active_rows.append(sid is not None)
                    primary_rows.append(sid is not None)
                    row_cells.append(cid)
            ridx = np.asarray(ridx, np.int64)
            active_rows = np.asarray(active_rows, bool)
            primary_rows = np.asarray(primary_rows, bool)
        else:
            rows = list(sessions)
            ridx = np.arange(u)
            active_rows = np.ones((u,), bool)
            primary_rows = np.ones((u,), bool)
            row_cells = []
        n = len(rows)
        c = self.chunk  # fixed lease width: ONE compiled width, always
        num_chunks = -(-n // c)
        space = rows[0].env.param_space
        env0 = rows[0].env
        use_warmup = use_warmup_u[ridx]
        warmup = warmup_u[ridx]
        noise = noise_u[ridx]

        def stack_np(trees):
            return jax.tree_util.tree_map(
                lambda *xs: np.stack([np.asarray(x) for x in xs]), *trees)

        params = stack_np([s.env.model.params for s in rows])
        env_states = stack_np([s.env.model_state for s in rows])
        ddpg_states = stack_np([s.ddpg for s in rows])
        lo, span = metric_bounds(env0.metric_specs, env0.state_metrics)
        k = lo.shape[0]
        lo = np.broadcast_to(lo, (n, k))
        span = np.broadcast_to(span, (n, k))
        w_vec = np.stack([s.scalarizer.weight_vector(s.env.state_metrics)
                          for s in rows])
        state_vecs = np.stack([
            normalize_state(s.cur_metrics, s.env.metric_specs,
                            s.env.state_metrics) for s in rows])
        objectives = np.array(
            [np.float32(s.scalarizer.objective(s.cur_metrics))
             for s in rows], np.float32)
        if shared_replay:
            # cell-granular merged windows: [G, cap, ...] + [G] cursors
            cell_ids = sorted(self._cells)
            cbufs = [self._cells[cid]["buf"] for cid in cell_ids]
            buf_np = tuple(
                np.stack([cb[key] for cb in cbufs])
                for key in ("s", "a", "r", "s2"))
            next_slots = np.array([cb["next"] for cb in cbufs], np.int32)
            sizes = np.array([cb["size"] for cb in cbufs], np.int32)
        else:
            buf_np = tuple(
                np.stack([s.buf[key] for s in rows])
                for key in ("s", "a", "r", "s2"))
            next_slots = np.array([s.buf["next"] for s in rows], np.int32)
            sizes = np.array([s.buf["size"] for s in rows], np.int32)
        learn_keys = np.stack([s.learn_key for s in rows])

        if cell_modes:
            # the averaging cadence fires on each CELL's own step clock (a
            # cell-level event: every seat agrees, whatever its member ages)
            avg_now = np.zeros((n, steps), bool)
            if self.sharing.averaging:
                for j, cid in enumerate(row_cells):
                    cst = self._cells[cid]["steps"]
                    for t in range(steps):
                        avg_now[j, t] = \
                            ((cst + t + 1) % self.sharing.avg_every) == 0
            active = np.broadcast_to(active_rows[:, None],
                                     (n, steps)).copy()

        base_fields = dict(
            action_idx=np.zeros((n, steps, space.dim), space.index_dtype()),
            metrics=np.zeros((n, steps, k), np.float32),
            rewards=np.zeros((n, steps), np.float32),
            objectives=np.zeros((n, steps), np.float32),
            restarts=np.zeros((n, steps), np.float32))
        guarded = self.policy is not None
        resilient = self.resilience is not None
        if guarded:
            from repro.core.guardrails import (
                GuardedCarry, GuardedEpisodeTrace)
            guard = stack_np([s.guard for s in rows])
            out = GuardedEpisodeTrace(
                **base_fields,
                guard_events=np.zeros((n, steps), np.uint8),
                shadow_objectives=np.zeros((n, steps), np.float32))
        elif resilient:
            from repro.core.resilience import (
                ResilientCarry, ResilientEpisodeTrace)
            health = stack_np([s.health for s in rows])
            out = ResilientEpisodeTrace(
                **base_fields,
                health_events=np.zeros((n, steps), np.uint8))
        else:
            out = EpisodeTrace(**base_fields)

        fn = _compiled_episode(env0.model.step_fn, space, cfg,
                               self._actor_tx, self._critic_tx, True,
                               cfg.updates_per_step, fleet=True, devices=None,
                               policy=self.policy, sharing=self.sharing,
                               cell_size=cs, obs_mask=obs_mask,
                               resilience=self.resilience)
        peak = [live_device_bytes()]
        t0 = time.perf_counter()

        def stage(ci):
            a, b = ci * c, min(n, (ci + 1) * c)
            pad = c - (b - a)

            def chunk_of(tree):
                return jax.tree_util.tree_map(
                    lambda x: jax.device_put(_pad_rows(x[a:b], pad)), tree)

            def group_chunk_of(tree):
                # cell-granular slice: chunk ci covers whole cells
                ga, gb = a // cs, b // cs
                gpad = pad // cs
                return jax.tree_util.tree_map(
                    lambda x: jax.device_put(_pad_rows(x[ga:gb], gpad)),
                    tree)

            buf_of = group_chunk_of if shared_replay else chunk_of
            carry = EpisodeCarry(
                env_state=chunk_of(env_states),
                ddpg=chunk_of(ddpg_states),
                buffer=BufferState(
                    s=buf_of(buf_np[0]), a=buf_of(buf_np[1]),
                    r=buf_of(buf_np[2]), s2=buf_of(buf_np[3]),
                    next_slot=buf_of(next_slots), size=buf_of(sizes)),
                learn_key=chunk_of(learn_keys),
                state_vec=chunk_of(state_vecs),
                objective=chunk_of(objectives))
            if guarded:
                carry = GuardedCarry(base=carry, guard=chunk_of(guard))
            elif resilient:
                carry = ResilientCarry(base=carry, health=chunk_of(health))
            if cell_modes:
                xs = (chunk_of(use_warmup), chunk_of(warmup),
                      chunk_of(noise), chunk_of(avg_now), chunk_of(active))
            else:
                xs = (chunk_of(use_warmup), chunk_of(warmup),
                      chunk_of(noise))
            args = (chunk_of(params), chunk_of(w_vec), chunk_of(lo),
                    chunk_of(span), carry, xs)
            # sample peak while the staged operands are live — counts the
            # in-flight transfer buffers the drain-side sample misses
            peak[0] = max(peak[0], live_device_bytes())
            return args

        def drain(ci, out_pair):
            carry, trace = out_pair
            a, b = ci * c, min(n, (ci + 1) * c)
            cnt = b - a
            peak[0] = max(peak[0], live_device_bytes())

            def write_back(dst_tree, src_tree):
                jax.tree_util.tree_map(
                    lambda d, s: d.__setitem__(slice(a, b),
                                               np.asarray(s)[:cnt]),
                    dst_tree, src_tree)

            if guarded:
                out.guard_events[a:b] = np.asarray(trace.guard_events)[:cnt]
                out.shadow_objectives[a:b] = np.asarray(
                    trace.shadow_objectives)[:cnt]
                write_back(guard, carry.guard)
                carry = carry.base
            elif resilient:
                out.health_events[a:b] = np.asarray(
                    trace.health_events)[:cnt]
                write_back(health, carry.health)
                carry = carry.base
            out.action_idx[a:b] = np.asarray(trace.action_idx)[:cnt]
            out.metrics[a:b] = np.asarray(trace.metrics)[:cnt]
            out.rewards[a:b] = np.asarray(trace.rewards)[:cnt]
            out.objectives[a:b] = np.asarray(trace.objectives)[:cnt]
            out.restarts[a:b] = decode_restarts(
                np.asarray(trace.restarts)[:cnt])
            write_back(env_states, carry.env_state)
            write_back(ddpg_states, carry.ddpg)
            if shared_replay:
                ga, gb = a // cs, b // cs
                gcnt = gb - ga
                for dst, sr in zip(buf_np, (carry.buffer.s, carry.buffer.a,
                                            carry.buffer.r,
                                            carry.buffer.s2)):
                    dst[ga:gb] = np.asarray(sr)[:gcnt]
                next_slots[ga:gb] = np.asarray(carry.buffer.next_slot)[:gcnt]
                sizes[ga:gb] = np.asarray(carry.buffer.size)[:gcnt]
            else:
                write_back(buf_np[0], carry.buffer.s)
                write_back(buf_np[1], carry.buffer.a)
                write_back(buf_np[2], carry.buffer.r)
                write_back(buf_np[3], carry.buffer.s2)
                next_slots[a:b] = np.asarray(carry.buffer.next_slot)[:cnt]
                sizes[a:b] = np.asarray(carry.buffer.size)[:cnt]
            learn_keys[a:b] = np.asarray(carry.learn_key)[:cnt]

        sup = self.supervisor
        if sup is not None and sup.on_failure != "skip":
            # a persistent service must survive a dead chunk: quarantine,
            # never crash (see __init__)
            sup = sup._replace(on_failure="skip")
        staging_stats: dict = {}
        stream_stats = stream_chunks(
            lambda args: fn(*args), stage, drain, num_chunks,
            overlap=self.overlap, supervisor=sup, chaos=self.chaos,
            staging=staging_stats)
        wall = time.perf_counter() - t0
        failed_rows: set = set()
        quarantined: list = []
        if stream_stats is not None:
            for ci in stream_stats["failed_chunks"]:
                failed_rows.update(range(ci * c, min(n, (ci + 1) * c)))
            quarantined = sorted({rows[j].sid for j in failed_rows
                                  if primary_rows[j]})
        self.last_stats = dict(
            sessions=len(sessions), chunk=c, num_chunks=num_chunks,
            steps=steps, overlap=self.overlap, peak_device_bytes=peak[0],
            executable_cache_size=fn._cache_size(),
            session_steps_per_sec=len(sessions) * steps / max(wall, 1e-9),
            program=fn, cell_size=cs, sharing=self.sharing,
            staging=staging_stats)
        if stream_stats is not None:
            self.last_stats["supervisor"] = stream_stats
            self.last_stats["quarantined"] = list(quarantined)

        # -- write per-session state + decision history back ----------------
        per_step = wall / max(1, steps)

        def row(tree, j):
            return jax.tree_util.tree_map(lambda x: np.asarray(x[j]), tree)

        if shared_replay:
            for g, cid in enumerate(sorted(self._cells)):
                cb = self._cells[cid]["buf"]
                for key, arr in zip(("s", "a", "r", "s2"), buf_np):
                    cb[key] = np.asarray(arr[g])
                cb["next"] = int(next_slots[g])
                cb["size"] = int(sizes[g])
        for cid in sorted(self._cells):
            self._cells[cid]["steps"] += steps
        if guarded:
            from repro.core.guardrails import (
                empty_counters, guardrail_counters, merge_counters)
            round_counters = empty_counters()
        if resilient:
            from repro.core.resilience import (
                empty_health_counters, health_counters,
                merge_health_counters)
        for j, s in enumerate(rows):
            if not primary_rows[j]:
                continue  # vacant-seat replica: everything discarded
            if j in failed_rows:
                # skipped chunk: the drain never ran, so the stacked arrays
                # still hold this row's PRE-episode state and its trace rows
                # are zeros — write nothing back; the session leaves with
                # the state it had at the boundary
                continue
            if resilient:
                s.health = row(health, j)
                s.health_counters = merge_health_counters(
                    s.health_counters or empty_health_counters(),
                    health_counters(out.health_events[j]))
            if guarded:
                s.guard = row(guard, j)
                delta = guardrail_counters(out.guard_events[j],
                                           out.restarts[j])
                s.guard_counters = merge_counters(
                    s.guard_counters or empty_counters(), delta)
                round_counters = merge_counters(round_counters, delta)
            s.env.model_state = row(env_states, j)
            s.ddpg = row(ddpg_states, j)
            if not shared_replay:
                for key, arr in zip(("s", "a", "r", "s2"), buf_np):
                    s.buf[key] = np.asarray(arr[j])
                s.buf["next"] = int(next_slots[j])
                s.buf["size"] = int(sizes[j])
            s.learn_key = np.asarray(learn_keys[j])
            rep = replay_compact_trace(
                s.env, out, j, start=len(s.history), per_step=per_step,
                prev_config=s.cur_config, best_objective=s.best_objective,
                restart_seconds=s.restart_seconds,
                finite_baseline=resilient)
            s.history.extend(rep["records"])
            s.restart_seconds = rep["restart_seconds"]
            if rep["best"] is not None:
                s.best_objective = rep["best"]["objective"]
                s.best_config = dict(rep["best"]["config"])
                s.best_metrics = dict(rep["best"]["metrics"])
            s.cur_config = rep["cur_config"]
            if rep["cur_metrics"] is not None:
                s.cur_metrics = rep["cur_metrics"]
        if guarded:  # this round's fleet-aggregate guardrail counters
            self.last_stats["guardrails"] = round_counters
        return quarantined

    # -- checkpoint / restore ------------------------------------------------

    def checkpoint(self, directory: Optional[str] = None) -> str:
        """Write the full service state through ``checkpoint/store.py``.

        Call at a boundary: pending join/leave requests are part of the
        NEXT boundary, not of durable state — raise instead of silently
        dropping them. Completed sessions' results were already handed to
        their callers and are not re-persisted."""
        directory = directory or self.checkpoint_dir
        if directory is None:
            raise ValueError("no checkpoint directory configured")
        if self._join_queue or self._leave_queue:
            raise RuntimeError(
                "pending join/leave requests; apply them first with "
                "advance() (advance(0) is a membership-only boundary)")
        tree, extra = {"sessions": {}}, {
            "chunk": self.chunk, "warmup_steps": self.warmup_steps,
            "buffer_capacity": self.buffer_capacity,
            "eval_runs": self.eval_runs, "overlap": bool(self.overlap),
            "keep": self.keep, "total_steps": self.total_steps,
            "next_sid": self._next_sid,
            "slots": [(-1 if s is None else s) for s in self._slots],
            "cfg": {**self.cfg._asdict(),
                    "hidden": list(self.cfg.hidden)},
            # json round-trips Infinity for an unbounded restart budget
            "policy": (dict(self.policy._asdict())
                       if self.policy is not None else None),
            "resilience": (dict(self.resilience._asdict())
                           if self.resilience is not None else None),
            "supervisor": (dict(self.supervisor._asdict())
                           if self.supervisor is not None else None),
            "sharing": (dict(self.sharing._asdict())
                        if self.sharing is not None else None),
            "cell_size": self.cell_size,
            "next_cell": self._next_cell,
            # cell topology: key + seat order are part of durable state —
            # a member's lane inside the cell program must survive resume
            "cells": {str(cid): {
                "workload": rec["key"][0],
                "weights": [[k, v] for k, v in rec["key"][1]],
                "seats": [(-1 if sid is None else sid)
                          for sid in rec["seats"]],
                "steps": rec["steps"],
                "buf_next": (rec["buf"]["next"]
                             if rec["buf"] is not None else -1),
                "buf_size": (rec["buf"]["size"]
                             if rec["buf"] is not None else -1),
            } for cid, rec in self._cells.items()},
            "sessions": {}}
        if any(rec["buf"] is not None for rec in self._cells.values()):
            tree["cells"] = {
                str(cid): {k: rec["buf"][k] for k in ("s", "a", "r", "s2")}
                for cid, rec in self._cells.items()
                if rec["buf"] is not None}
        for sid, s in self._sessions.items():
            tree["sessions"][str(sid)] = {
                "ddpg": s.ddpg,
                "buffer": {k: s.buf[k] for k in ("s", "a", "r", "s2")},
                "env_params": s.env.model.params,
                "env_state": s.env.model_state,
                "learn_key": s.learn_key,
                "noise_x": s.noise.state_dict()["x"],
                "warmup_plan": s.warmup_plan,
            }
            if s.guard is not None:
                tree["sessions"][str(sid)]["guard_live_action"] = \
                    np.asarray(s.guard.live_action, np.float32)
                tree["sessions"][str(sid)]["guard_fallback_action"] = \
                    np.asarray(s.guard.fallback_action, np.float32)
            if s.health is not None:
                # the last-good snapshot is a full DDPGState pytree: a
                # resumed session must be able to reset to the SAME state
                tree["sessions"][str(sid)]["health_snapshot"] = \
                    s.health.snapshot
            nd = s.noise.state_dict()
            extra["sessions"][str(sid)] = {
                "label": s.label, "workload": s.workload,
                "weights": s.weights, "seed": s.seed,
                "steps_taken": s.steps_taken,
                "buffer_next": s.buf["next"], "buffer_size": s.buf["size"],
                "noise_t": nd["t"], "noise_bitgen": nd["bitgen"],
                "default_config": s.default_config,
                "default_metrics": s.default_metrics,
                "cur_config": s.cur_config, "cur_metrics": s.cur_metrics,
                "best_config": s.best_config, "best_metrics": s.best_metrics,
                "best_objective": s.best_objective,
                "restart_seconds": s.restart_seconds,
                "restart_events": [[sc, sec]
                                   for sc, sec in s.env.restart_events],
                "last_config": s.env._last_config,
                "history": [dataclasses.asdict(r) for r in s.history],
            }
            if s.guard is not None:
                extra["sessions"][str(sid)]["guard"] = {
                    "fallback_obj": float(s.guard.fallback_obj),
                    "budget_spent": float(s.guard.budget_spent),
                    "watch_left": int(s.guard.watch_left),
                    "promotions": int(s.guard.promotions),
                    "rollbacks": int(s.guard.rollbacks),
                    "counters": dict(s.guard_counters or {}),
                }
            if s.health is not None:
                extra["sessions"][str(sid)]["health"] = {
                    "resets": int(s.health.resets),
                    "nonfinite": int(s.health.nonfinite),
                    "degraded": bool(s.health.degraded),
                    "since_snap": int(s.health.since_snap),
                    "counters": dict(s.health_counters or {}),
                }
        return save_checkpoint(directory, self.total_steps, tree,
                               keep=self.keep, extra=extra)

    @classmethod
    def restore(cls, directory: str, *, env_factory=None, env_cls=None,
                step: Optional[int] = None,
                fallback: bool = False) -> "FleetService":
        """Rebuild a service from a checkpoint, bit-identically.

        Environments are rebuilt from ``env_factory(workload, seed)`` (they
        must be the same definition the checkpoint was taken with — restored
        model params are verified against the rebuilt ones and a mismatch
        raises). Array state is CRC-verified by the store and restored
        through ``restore_into`` against the freshly-built template, so a
        missing leaf raises ``KeyError`` instead of reinitializing.

        ``fallback=True`` survives a corrupted newest checkpoint by walking
        the keep-k history to the newest verifiable step (the restored
        service's ``total_steps`` tells how far back it reached); the
        checkpointed resilience/supervisor policies come along, so a crashed
        self-healing service resumes still self-healing.
        """
        step, flat, extra = restore_checkpoint(directory, step,
                                               fallback=fallback)
        cfg_d = dict(extra["cfg"])
        cfg_d["hidden"] = tuple(cfg_d["hidden"])
        policy = None
        if extra.get("policy") is not None:
            from repro.core.guardrails import DeploymentPolicy
            policy = DeploymentPolicy(**extra["policy"])
        resilience = None
        if extra.get("resilience") is not None:
            from repro.core.resilience import ResiliencePolicy
            resilience = ResiliencePolicy(**extra["resilience"])
        supervisor = None
        if extra.get("supervisor") is not None:
            from repro.core.resilience import ChunkSupervisor
            supervisor = ChunkSupervisor(**extra["supervisor"])
        sharing = None
        if extra.get("sharing") is not None:
            from repro.core.sharing import SharingConfig
            sh_d = dict(extra["sharing"])
            if sh_d.get("observation_scopes") is not None:
                sh_d["observation_scopes"] = tuple(
                    sh_d["observation_scopes"])
            sharing = SharingConfig(**sh_d)
        svc = cls(chunk=extra["chunk"], env_factory=env_factory,
                  env_cls=env_cls, ddpg_config=DDPGConfig(**cfg_d),
                  buffer_capacity=extra["buffer_capacity"],
                  warmup_steps=extra["warmup_steps"],
                  eval_runs=extra["eval_runs"], overlap=extra["overlap"],
                  checkpoint_dir=directory, keep=extra["keep"],
                  policy=policy, sharing=sharing,
                  cell_size=extra.get("cell_size", 1),
                  resilience=resilience, supervisor=supervisor)
        svc.total_steps = extra["total_steps"]
        svc._next_sid = extra["next_sid"]
        svc._slots = [None if s < 0 else int(s) for s in extra["slots"]]
        svc._next_cell = extra.get("next_cell", 0)
        for cid_s, cm in extra.get("cells", {}).items():
            cid = int(cid_s)
            buf = None
            if cm["buf_next"] >= 0:
                buf = svc._new_cell_buf()
                template = {k: buf[k] for k in ("s", "a", "r", "s2")}
                sub = {k[len(f"cells/{cid_s}/"):]: v for k, v in flat.items()
                       if k.startswith(f"cells/{cid_s}/")}
                restored = jax.tree_util.tree_map(
                    np.asarray, restore_into(template, sub))
                for k in ("s", "a", "r", "s2"):
                    buf[k] = restored[k]
                buf["next"] = int(cm["buf_next"])
                buf["size"] = int(cm["buf_size"])
            svc._cells[cid] = {
                "key": (cm["workload"],
                        tuple((k, v) for k, v in cm["weights"])),
                "seats": [None if sid < 0 else int(sid)
                          for sid in cm["seats"]],
                "steps": int(cm["steps"]), "buf": buf}
        for sid_s, meta in extra["sessions"].items():
            sid = int(sid_s)
            s = svc._new_session(sid, meta["workload"], dict(meta["weights"]),
                                 meta["seed"], meta["label"],
                                 evaluate_default=False)
            template = {
                "ddpg": s.ddpg,
                "buffer": {k: s.buf[k] for k in ("s", "a", "r", "s2")},
                "env_params": s.env.model.params,
                "env_state": s.env.model_state,
                "learn_key": s.learn_key,
                "noise_x": s.noise.state_dict()["x"],
                "warmup_plan": s.warmup_plan,
            }
            if policy is not None:
                template["guard_live_action"] = np.asarray(
                    s.guard.live_action, np.float32)
                template["guard_fallback_action"] = np.asarray(
                    s.guard.fallback_action, np.float32)
            if resilience is not None:
                template["health_snapshot"] = s.health.snapshot
            sub = {k[len(f"sessions/{sid_s}/"):]: v for k, v in flat.items()
                   if k.startswith(f"sessions/{sid_s}/")}
            restored = jax.tree_util.tree_map(
                np.asarray, restore_into(template, sub))
            if not all(np.array_equal(a, b) for a, b in zip(
                    jax.tree_util.tree_leaves(restored["env_params"]),
                    jax.tree_util.tree_leaves(s.env.model.params))):
                raise ValueError(
                    f"session {sid}: environment definition drifted — "
                    "rebuilt model params differ from the checkpoint")
            s.ddpg = restored["ddpg"]
            for k in ("s", "a", "r", "s2"):
                s.buf[k] = restored["buffer"][k]
            s.buf["next"] = int(meta["buffer_next"])
            s.buf["size"] = int(meta["buffer_size"])
            s.env.model_state = restored["env_state"]
            s.learn_key = restored["learn_key"]
            s.noise.load_state_dict({
                "x": restored["noise_x"], "t": meta["noise_t"],
                "bitgen": meta["noise_bitgen"]})
            s.warmup_plan = restored["warmup_plan"]
            s.steps_taken = int(meta["steps_taken"])
            s.default_config = dict(meta["default_config"])
            s.default_metrics = dict(meta["default_metrics"])
            s.cur_config = dict(meta["cur_config"])
            s.cur_metrics = dict(meta["cur_metrics"])
            s.best_config = dict(meta["best_config"])
            s.best_metrics = dict(meta["best_metrics"])
            s.best_objective = float(meta["best_objective"])
            s.restart_seconds = float(meta["restart_seconds"])
            s.env.restart_events = [
                (sc, sec) for sc, sec in meta["restart_events"]]
            s.env._last_config = dict(meta["last_config"])
            s.history = [StepRecord(**r) for r in meta["history"]]
            if policy is not None:
                from repro.core.guardrails import GuardState
                gm = meta["guard"]
                s.guard = GuardState(
                    live_action=restored["guard_live_action"],
                    fallback_action=restored["guard_fallback_action"],
                    fallback_obj=np.float32(gm["fallback_obj"]),
                    budget_spent=np.float32(gm["budget_spent"]),
                    watch_left=np.int32(gm["watch_left"]),
                    promotions=np.int32(gm["promotions"]),
                    rollbacks=np.int32(gm["rollbacks"]))
                s.guard_counters = dict(gm["counters"])
            if resilience is not None:
                from repro.core.resilience import HealthState
                hm = meta["health"]
                s.health = HealthState(
                    snapshot=restored["health_snapshot"],
                    resets=np.int32(hm["resets"]),
                    nonfinite=np.int32(hm["nonfinite"]),
                    degraded=np.bool_(hm["degraded"]),
                    since_snap=np.int32(hm["since_snap"]))
                s.health_counters = dict(hm["counters"])
            svc._sessions[sid] = s
        return svc
