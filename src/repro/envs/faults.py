"""Deterministic fault injection for guardrail testing.

``FaultInjectedModel`` wraps any pure ``EnvModel`` and corrupts named
metrics over a step-indexed schedule — a throughput collapse at step k, an
iowait spike, a metric dropout — without touching the wrapped dynamics,
restart accounting or RNG stream. The wrapper is itself a pure ``EnvModel``
(scan/vmap/shard_map-safe, all branch-free ``jnp.where``), so faulted
environments ride the fused episode engine and the chunked fleet runtime
unchanged: ``tests/test_guardrails.py`` injects a degradation mid-episode
and pins that the ``DeploymentPolicy`` rolls the live config back within
its window.

Schedule semantics: the fault clock counts TUNING transitions only
(``eval_run=True`` probes — shadow scoring, ``evaluate_config`` — observe
the current clock but never advance it), so "collapse at step k" means the
k-th committed tuning step regardless of how many shadow probes ran. A
fault row is active for ``start <= t < start + duration``; shadow and live
draws within one guarded step see the SAME clock, so a shadow probe scores
a proposal under the same fault regime the live system would run it in.

Runtime chaos (PR 9): ``ChaosConfig`` bundles the fault classes the
resilience subsystem defends against — in-graph NaN corruption of an
observed metric (mode="nan", which the ``ResiliencePolicy`` health check
must catch and quarantine) plus host-side transient staging exceptions and
slow-chunk stalls, delivered through ``HostChaos.before_chunk`` which the
supervised ``stream_chunks`` path invokes before every stage attempt.
Transient failures are DETERMINISTIC (chunk i fails its first n attempts,
then succeeds), so a retried run is byte-for-byte reproducible.
"""

from __future__ import annotations

import functools
import time
from typing import NamedTuple, Sequence, Tuple

from repro.envs.base import EnvModel

FAULT_MODES = ("scale", "dropout", "nan")


class FaultSpec(NamedTuple):
    """One step-indexed metric corruption.

    ``metric``    name from the wrapped model's ``state_metrics``.
    ``start``     first tuning step (0-based) the fault is active.
    ``duration``  number of tuning steps the fault stays active.
    ``mode``      "scale" multiplies the metric by ``scale``; "dropout"
                  zeroes it (a collector blackout); "nan" replaces it with
                  NaN (a poisoned sample the resilience layer must catch).
    ``scale``     multiplier for mode="scale" (ignored otherwise).
    """

    metric: str
    start: int
    duration: int
    mode: str = "scale"
    scale: float = 0.2


class FaultyEnvState(NamedTuple):
    base: object   # the wrapped model's EnvState
    step: object   # i32 tuning-step clock (eval probes do not advance it)


@functools.lru_cache(maxsize=None)
def _build_fault_fns(base_init, base_step, rows: tuple):
    """Pure init/step closures; cached on (wrapped fns, schedule) so every
    session of a fleet sharing one schedule shares ONE step_fn identity
    (the episode-program cache keys on it)."""
    import jax.numpy as jnp

    def init_fn(params, key):
        return FaultyEnvState(base=base_init(params, key),
                              step=jnp.int32(0))

    def step_fn(params, state, unit_action, eval_run):
        base, vec, cost = base_step(params, state.base, unit_action,
                                    eval_run)
        t = state.step
        for mi, start, duration, mode, scale in rows:
            active = (t >= start) & (t < start + duration)
            v = vec[mi]
            if mode == "dropout":
                faulted = jnp.float32(0.0)
            elif mode == "nan":
                faulted = jnp.float32(jnp.nan)
            else:
                faulted = v * jnp.float32(scale)
            vec = vec.at[mi].set(jnp.where(active, faulted, v))
        # eval_run is a static bool: probes replay the same clock
        step = t if eval_run else t + jnp.int32(1)
        return FaultyEnvState(base=base, step=step), vec, cost

    return init_fn, step_fn


class FaultInjectedModel(EnvModel):
    """An ``EnvModel`` whose observed metrics follow a fault schedule.

    Delegates space, specs, params and restart scope to the wrapped model;
    only the emitted metric vector is corrupted while a fault row is
    active. Determinism is the wrapped model's: same key, same schedule,
    same trajectory."""

    def __init__(self, base: EnvModel, faults: Sequence[FaultSpec]):
        names = list(base.state_metrics)
        rows = []
        for f in faults:
            if f.metric not in names:
                raise ValueError(
                    f"unknown metric {f.metric!r}; the wrapped model "
                    f"exposes {names}")
            if f.mode not in FAULT_MODES:
                raise ValueError(
                    f"unknown fault mode {f.mode!r}; use one of "
                    f"{FAULT_MODES}")
            if f.start < 0 or f.duration <= 0:
                raise ValueError(
                    f"fault needs start >= 0 and duration > 0, got {f}")
            rows.append((names.index(f.metric), int(f.start),
                         int(f.duration), f.mode, float(f.scale)))
        self.base = base
        self.faults = tuple(faults)
        self.param_space = base.param_space
        self.metric_specs = base.metric_specs
        self.state_metrics = names
        self.params = base.params
        self.dfs_scope = base.dfs_scope
        self._init_fn, self._step_fn = _build_fault_fns(
            base.init_fn, base.step_fn, tuple(rows))

    @property
    def init_fn(self):
        return self._init_fn

    @property
    def step_fn(self):
        return self._step_fn


# ---------------------------------------------------------------------------
# Canonical fault shapes (the ones the guardrail suite pins)
# ---------------------------------------------------------------------------

def throughput_collapse(start: int, duration: int = 8,
                        to_fraction: float = 0.2) -> FaultSpec:
    """Throughput drops to ``to_fraction`` of its true value at ``start``."""
    return FaultSpec("throughput", start, duration, "scale", to_fraction)


def latency_spike(start: int, duration: int = 8, factor: float = 4.0,
                  metric: str = "cpu_usage_iowait") -> FaultSpec:
    """Latency pressure: the model exposes no latency metric directly, so a
    spike surfaces as io-wait inflation (``cpu_usage_iowait`` by default)."""
    return FaultSpec(metric, start, duration, "scale", factor)


def metric_dropout(metric: str, start: int, duration: int = 8) -> FaultSpec:
    """Collector blackout: ``metric`` reads zero while active."""
    return FaultSpec(metric, start, duration, "dropout")


def nan_poison(metric: str, start: int, duration: int = 1) -> FaultSpec:
    """``metric`` reads NaN while active — the canonical divergence trigger
    for the resilience suite (the health check must catch it before the
    poisoned sample reaches the replay window)."""
    return FaultSpec(metric, start, duration, "nan")


# ---------------------------------------------------------------------------
# Runtime chaos: the fault classes the resilience subsystem defends against
# ---------------------------------------------------------------------------

class TransientChunkError(RuntimeError):
    """A deterministic, injected transient staging failure (the kind a real
    fleet sees from a flaky device transfer or a preempted host thread).
    The supervised ``stream_chunks`` path retries these; an unsupervised
    stream propagates them."""


class ChaosConfig(NamedTuple):
    """Declarative chaos plan spanning both failure domains.

    In-graph (compiled into the episode program via ``FaultInjectedModel``):
      ``nan_metric``    metric name to poison with NaN, or None.
      ``nan_start``     first tuning step the poison is active.
      ``nan_duration``  number of poisoned tuning steps.

    Host-side (delivered by ``HostChaos.before_chunk``):
      ``fail_chunks``   ((chunk_index, n_failures), ...) — chunk fails its
                        first ``n_failures`` stage attempts with
                        ``TransientChunkError``, then succeeds.
      ``stall_chunks``  ((chunk_index, seconds), ...) — chunk sleeps before
                        staging (trips a wall-clock watchdog, no failure).
    """

    nan_metric: str | None = None
    nan_start: int = 0
    nan_duration: int = 1
    fail_chunks: Tuple[Tuple[int, int], ...] = ()
    stall_chunks: Tuple[Tuple[int, float], ...] = ()

    def fault_specs(self) -> Tuple[FaultSpec, ...]:
        """The in-graph half, as ``FaultSpec`` rows for
        ``FaultInjectedModel``; empty when no metric poison is planned."""
        if self.nan_metric is None:
            return ()
        return (nan_poison(self.nan_metric, self.nan_start,
                           self.nan_duration),)

    def host(self) -> "HostChaos | None":
        """The host-side half; None when no host faults are planned."""
        if not self.fail_chunks and not self.stall_chunks:
            return None
        return HostChaos(self)


class HostChaos:
    """Stateless-per-attempt chaos driver handed to supervised streams.

    ``before_chunk(ci, attempt)`` is called by ``stream_chunks`` before each
    stage attempt: it raises ``TransientChunkError`` while ``attempt`` is
    below the planned failure count for chunk ``ci`` (so retries
    deterministically clear the fault), and sleeps for planned stalls.
    Because the failure schedule keys on (chunk, attempt) rather than wall
    clock or randomness, the retried run's numerics are byte-for-byte those
    of a fault-free run.
    """

    def __init__(self, config: ChaosConfig):
        self.config = config
        self._fails = {int(c): int(n) for c, n in config.fail_chunks}
        self._stalls = {int(c): float(s) for c, s in config.stall_chunks}

    def before_chunk(self, chunk_index: int, attempt: int) -> None:
        stall = self._stalls.get(chunk_index)
        if stall:
            time.sleep(stall)
        n = self._fails.get(chunk_index, 0)
        if attempt < n:
            raise TransientChunkError(
                f"injected transient failure {attempt + 1}/{n} staging "
                f"chunk {chunk_index}")
