"""Calibrated Lustre-cluster simulator (the paper's evaluation environment).

Hardware model = the paper's cluster (§III-B): 6 OST nodes + 3 client nodes on
a single 1 GbE switch, HDD-backed OSTs. The two tuned static parameters are the
paper's (§III-A): ``stripe_count`` in {1..6} and ``stripe_size`` in powers of
two from 64 KiB to 64 MiB (Lustre defaults: count 1, size 1 MiB).

The response surface encodes the real mechanisms that make these parameters
matter on such a cluster:
  * striping parallelism P(sc): more OSTs serve one file -> higher aggregate
    bandwidth, sub-linear (gamma) and with cross-client contention (beta);
    large sequential writes scale best (the paper's +250.4% headroom),
    metadata-heavy small-file work *degrades* with striping (File Server).
  * stripe-size response S(ss): RPC efficiency vs seek/imbalance trade-off,
    workload-dependent optimum (small for small random I/O, large for
    streaming), expressed on l = log2(ss / 64 KiB).
  * interaction X(sc, ss): very large stripes on many OSTs cause imbalance
    (fewer stripes than OSTs in flight) — parameters are not independent.
  * aggregate caps: 3 x 117 MB/s client NICs; 6 x ~160 MB/s HDDs.
  * multiplicative lognormal noise, per-run and per-sample, workload-specific
    (File Server has the highest variance, matching the paper's observation).

All Table-I metrics are derived *consistently* with the produced throughput
(queueing-style: in-flight RPC counts rise super-linearly near saturation,
dirty/grant bytes follow the write share and stripe width, MDS iowait follows
metadata intensity). That coupling is what gives Magpie's metric-state its
advantage over black-box search — exactly the paper's thesis.

This module is a *simulator* of the paper's physical testbed: the RL algorithm
above it is unchanged. Calibration targets & checks live in
tests/test_env_calibration.py.
"""

from __future__ import annotations

import numpy as np

from repro.core.action_mapping import ParamSpace, ParamSpec
from repro.envs.base import TuningEnvironment
from repro.envs.metrics import (
    LUSTRE_STATE_METRICS,
    MetricsCollector,
    couple_client_knobs,
    lustre_metric_specs,
    MiB,
)
from repro.envs.workloads import WORKLOADS, Workload, param_arrays

# -- cluster constants (paper §III-B) ---------------------------------------
NUM_OSTS = 6
NUM_CLIENTS = 3
CLIENT_NIC_MBPS = 117.0          # 1 GbE payload
HDD_MBPS = 160.0                 # per-OST sequential media bandwidth
NET_CAP = NUM_CLIENTS * CLIENT_NIC_MBPS
L_DEFAULT = 4.0                  # log2(1 MiB / 64 KiB)
PAGE_KIB = 4.0                   # client page size (max_pages_per_rpc unit)

STRIPE_SIZES = tuple(int(64 * 1024 * 2 ** i) for i in range(11))  # 64KiB..64MiB


def paper_param_space() -> ParamSpace:
    """The paper's two static parameters (§III-A)."""
    return ParamSpace(specs=(
        ParamSpec("stripe_count", "discrete", minimum=1, maximum=NUM_OSTS, default=1),
        ParamSpec("stripe_size", "choice", values=STRIPE_SIZES,
                  default=int(1 * MiB)),
    ))


def extended_param_space() -> ParamSpace:
    """Beyond-paper: adds an OSS service-thread count (DFS-restart scope)."""
    return ParamSpace(specs=(
        ParamSpec("stripe_count", "discrete", minimum=1, maximum=NUM_OSTS, default=1),
        ParamSpec("stripe_size", "choice", values=STRIPE_SIZES,
                  default=int(1 * MiB)),
        ParamSpec("service_threads", "choice",
                  values=(8, 16, 32, 64, 128, 256, 512), default=64),
    ))


def magpie8_param_space() -> ParamSpace:
    """The realistic 8-knob mixed-type space (``LustreSimV2``).

    Layers the DIAL/CARAT-style client knobs on the paper's layout pair plus
    the OSS thread count; defaults are Lustre's. Kinds exercise every
    ``ParamSpec`` flavour: discrete, log2-integer, boolean and categorical.
    """
    return ParamSpace(specs=(
        # layout (the paper's §III-A pair, workload-restart scope)
        ParamSpec("stripe_count", "discrete", minimum=1, maximum=NUM_OSTS,
                  default=1),
        ParamSpec("stripe_size", "log2_int", minimum=STRIPE_SIZES[0],
                  maximum=STRIPE_SIZES[-1], default=int(1 * MiB)),
        # client-side OSC knobs (lctl set_param scope -> workload restart)
        ParamSpec("max_rpcs_in_flight", "log2_int", minimum=1, maximum=256,
                  default=8),
        ParamSpec("max_pages_per_rpc", "log2_int", minimum=32, maximum=1024,
                  default=256),
        ParamSpec("max_dirty_mb", "log2_int", minimum=4, maximum=2048,
                  default=32),
        ParamSpec("read_ahead_mb", "log2_int", minimum=1, maximum=1024,
                  default=64),
        # wire checksumming (remount -> DFS-restart scope)
        ParamSpec("checksums", "boolean", default=True),
        # OSS service threads (server restart -> DFS-restart scope)
        ParamSpec("service_threads", "categorical",
                  values=(8, 16, 32, 64, 128, 256, 512), default=64),
    ))


def _knob_column(configs, name: str, default: float):
    """Presence mask + float values (``default`` where absent) for one knob."""
    has = np.array([name in c for c in configs])
    val = np.array([float(c.get(name, default)) for c in configs])
    return has, val


def _client_knob_factor(configs, w, sc, l) -> np.ndarray:
    """Multiplicative throughput response of the V2 client knobs.

    Every factor is exactly 1.0 when its knob is absent from the config AND at
    the knob's Lustre default under the default layout — so the paper's 2-D
    space sees the identical surface it always did, while
    ``magpie8_param_space`` configs move on an 8-D response with the
    DIAL/CARAT interactions: RPC concurrency x stripe width, RPC size x stripe
    size, dirty-cache depth x write share, read-ahead x sequentiality.
    """
    n = len(configs)
    factor = np.ones(n)
    wf, meta = w["write_frac"], w["meta_rate"]

    # max_rpcs_in_flight: per-OST concurrency keeps the pipe full; wide
    # layouts split the per-OSC budget across sc OSTs, so striping wider
    # WITHOUT raising the RPC budget starves each OST (CARAT's co-tuning
    # argument); oversized budgets add server-side contention on
    # metadata-heavy work.
    has, rif = _knob_column(configs, "max_rpcs_in_flight", 8.0)
    if has.any():
        per_ost = rif / np.maximum(sc, 1)
        conc = per_ost / (per_ost + 2.0)
        conc0 = 8.0 / (8.0 + 2.0)        # default budget on an unstriped file
        over = 1.0 - 0.03 * meta * np.maximum(
            0.0, np.log2(np.maximum(rif, 1.0)) - 5.0)
        factor *= np.where(has, conc / conc0 * np.maximum(over, 0.7), 1.0)

    # max_pages_per_rpc: the wire RPC is min(pages * 4 KiB, stripe_size);
    # streaming work wants full-size RPCs, small random I/O wastes them.
    has, pages = _knob_column(configs, "max_pages_per_rpc", 256.0)
    if has.any():
        stripe_kib = 2.0 ** l * 64.0
        lr_opt = np.clip(w["l_opt"], 0.0, 4.0)

        def rpc_resp(pg):
            lr = np.log2(np.minimum(pg * PAGE_KIB, stripe_kib) / 64.0)
            return 1.0 + 0.10 * (1.0 - ((lr - lr_opt) / 4.0) ** 2)

        factor *= np.where(
            has, rpc_resp(pages) / rpc_resp(np.full(n, 256.0)), 1.0)

    # max_dirty_mb: write-back pipeline depth — too shallow throttles writers
    # behind RPC completion; very deep caches add flush burstiness.
    has, dirty = _knob_column(configs, "max_dirty_mb", 32.0)
    if has.any():
        h = 1.0 - np.exp(-dirty / 24.0)
        h0 = 1.0 - np.exp(-32.0 / 24.0)
        burst = 1.0 - 0.02 * np.maximum(0.0, np.log2(dirty / 512.0))
        factor *= np.where(has, ((1.0 - wf) + wf * h / h0) * burst, 1.0)

    # read_ahead_mb: prefetch helps sequential reads, pollutes the client
    # cache on random reads.
    has, ra = _knob_column(configs, "read_ahead_mb", 64.0)
    if has.any():
        seq = np.clip(np.log2(w["io_kib"] / 8.0) / 7.0, 0.0, 1.0)
        rf = 1.0 - wf
        h = 1.0 - np.exp(-ra / 48.0)
        h0 = 1.0 - np.exp(-64.0 / 48.0)
        gain = 0.25 * rf * seq * (h / h0 - 1.0)
        waste = 0.12 * rf * (1.0 - seq) * np.clip(
            np.log2(ra / 64.0) / 4.0, 0.0, 1.0)
        factor *= np.where(has, 1.0 + gain - waste, 1.0)

    # checksums: CRC on every RPC burns CPU proportional to the write share;
    # Lustre defaults them ON, so disabling is the (risky) gain.
    has_ck = np.array(["checksums" in c for c in configs])
    ck_on = np.array([bool(c.get("checksums", True)) for c in configs])
    if has_ck.any():
        relief = 1.04 + 0.06 * wf
        factor *= np.where(has_ck & ~ck_on, relief, 1.0)

    return factor


def batch_mean_performance(envs, configs) -> list:
    """Noise-free response surface for N (env, config) sessions in one pass.

    THE surface implementation: ``LustreSimEnv.mean_performance`` is the
    N == 1 case, so the fleet fast path (one vectorized evaluation per fleet
    step) and the scalar path agree by construction. Per-session workload
    shape parameters come from ``workloads.param_arrays``.
    """
    if len(envs) != len(configs):
        raise ValueError("need one config per env")
    for env, config in zip(envs, configs):
        if not env.param_space.validate(config):
            raise ValueError(f"invalid config {config}")

    w = param_arrays([env.workload for env in envs])
    sc = np.array([int(c["stripe_count"]) for c in configs])
    ss = np.array([int(c["stripe_size"]) for c in configs])
    gamma, beta = w["gamma"], w["beta"]
    l_gate, gate_width = w["l_gate"], w["gate_width"]
    l_opt, l_width, s_amp = w["l_opt"], w["l_width"], w["s_amp"]
    base, io_kib = w["base_mbps"], w["io_kib"]

    l = np.log2(ss / (64 * 1024))

    # striping parallelism vs contention
    p = sc ** gamma * np.exp(-beta * (sc - 1))
    # striping-efficiency gate: wide layouts only pay off with stripes big
    # enough for full-size RPCs (narrow ridge in (sc, ss) space -> strong
    # parameter interaction, the paper's 'dependencies among parameters')
    r_gate = 1.0 / (1.0 + np.exp(-(l - l_gate) / gate_width))
    p_eff = np.where(p >= 1.0, 1.0 + (p - 1.0) * r_gate, p)

    # stripe-size response, normalized to 1 at the default (1 MiB)
    def s_raw(ll):
        return 1.0 + s_amp * (1.0 - ((ll - l_opt) / l_width) ** 2)

    s = np.maximum(0.4, s_raw(l)) / np.maximum(0.4, s_raw(L_DEFAULT))
    # interaction: stripes wider than ~16 MiB underfill wide layouts
    x = 1.0 - 0.03 * np.maximum(0, sc - 1) * np.maximum(0.0, l - 8.0)
    x = np.maximum(0.6, x)

    t = base * p_eff * s * x

    # beyond-paper knob: OSS service threads (peak near 128)
    threads = np.array([float(c.get("service_threads", 0)) for c in configs])
    has_threads = threads > 0
    if has_threads.any():
        th = np.where(has_threads, threads, 1.0)
        factor = 0.75 + 0.33 * np.exp(-((np.log2(th) - 7.0) / 3.0) ** 2)
        t = np.where(has_threads, t * factor, t)

    # V2 client knobs (LustreSimV2 / magpie8_param_space); exactly 1 for
    # configs that omit them, so the paper's 2-D surface is unchanged.
    t = t * _client_knob_factor(configs, w, sc, l)

    # physical caps: client NICs in aggregate; sc OSTs of media bandwidth
    t = np.minimum(np.minimum(t, NET_CAP * 0.95), sc * HDD_MBPS * 1.05)

    # IOPS: ops rate = bytes / effective op size; finer stripes raise the
    # server-visible op rate (RPC amplification) — the multi-objective
    # tension of §III-D.
    amp = 1.0 + 0.6 * np.maximum(0.0, (L_DEFAULT - l)) / L_DEFAULT
    iops = t * 1024.0 / io_kib * amp
    util = t / NET_CAP

    return [
        {"throughput": float(t[i]), "iops": float(iops[i]),
         "util": float(util[i]), "l": float(l[i]), "sc": int(sc[i])}
        for i in range(len(envs))
    ]


class LustreSimEnv(TuningEnvironment):
    #: parameters whose change needs a full-DFS restart (vs workload restart)
    DFS_SCOPE = ("service_threads",)

    def __init__(self, workload: str = "file_server", seed: int = 0,
                 extended: bool = False, run_seconds: float = 120.0,
                 sample_period: float = 10.0):
        if workload not in WORKLOADS:
            raise ValueError(f"unknown workload {workload!r}; "
                             f"choose from {sorted(WORKLOADS)}")
        self.workload: Workload = WORKLOADS[workload]
        self.param_space = extended_param_space() if extended else paper_param_space()
        self.metric_specs = lustre_metric_specs()
        self.state_metrics = list(LUSTRE_STATE_METRICS)
        self.run_seconds = run_seconds
        self.sample_period = sample_period
        self.collector = MetricsCollector()
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self.sim_clock = 0.0  # simulated seconds elapsed (runs + restarts)
        self.restart_events: list = []  # (scope, seconds) per config change
        # Latent client-cache warmth in [0,1]: persists across runs, cooled by
        # layout changes, drives the *explainable* share of short-run variance.
        self._warmth = 0.5
        self._last_config: dict = {}

    # ------------------------------------------------------------------
    # Response surface
    # ------------------------------------------------------------------

    def mean_performance(self, config: dict) -> dict:
        """Noise-free steady-state performance + internals for a config.

        Exposed separately so tests/benchmarks can query the true surface
        (e.g. to locate the global optimum for regret checks). The N == 1
        case of ``batch_mean_performance`` — one shared surface implementation.
        """
        return batch_mean_performance([self], [config])[0]

    def _internal_metrics(self, perf: dict, config: dict,
                          rng: np.random.Generator) -> dict:
        """Table-I metrics, consistent with the delivered performance."""
        w = self.workload
        t, util, l, sc = perf["throughput"], perf["util"], perf["l"], perf["sc"]
        rpc_mb = min(2 ** l * 64 / 1024.0, 4.0)  # RPC <= 4 MiB
        latency = 0.05 * (1.0 + 3.0 * util ** 2)  # queueing delay near saturation
        write_mb = t * w.write_frac
        read_mb = t - write_mb

        def jitter(v, s=0.05):
            return float(v * rng.lognormal(0.0, s))

        metrics = {
            "cur_dirty_bytes": jitter(write_mb * 2.0 * MiB),  # ~2 s writeback window
            "cur_grant_bytes": jitter((sc * 32 + write_mb) * MiB),
            "read_rpcs_in_flight": jitter(read_mb / max(rpc_mb, 1e-3) * latency),
            "write_rpcs_in_flight": jitter(write_mb / max(rpc_mb, 1e-3) * latency),
            "pending_read_pages": jitter((read_mb / 4.0) * 256.0 * util ** 2),
            "pending_write_pages": jitter((write_mb / 4.0) * 256.0 * util ** 2),
            "cache_hit_ratio": float(np.clip(
                w.cache_base + 0.45 * (perf.get("warmth", 0.5) - 0.5)
                + 0.03 * (l - L_DEFAULT) - 0.2 * util
                + rng.normal(0.0, 0.02), 0.0, 1.0)),
            "cpu_usage_idle": float(np.clip(
                100.0 - 55.0 * w.meta_rate - 25.0 * util + rng.normal(0, 2.0),
                0.0, 100.0)),
            "cpu_usage_iowait": float(np.clip(
                35.0 * w.meta_rate * (0.5 + util) + 8.0 * util
                + rng.normal(0, 1.5), 0.0, 100.0)),
            "ram_used_percent": float(np.clip(
                28.0 + 40.0 * util + write_mb * 2.0 / (16 * 1024.0) * 100.0
                + rng.normal(0, 1.5), 0.0, 100.0)),
        }
        # Client-knob visibility (no RNG draws -> fleet parity preserved):
        # knob limits clamp the metric they govern, read-ahead/checksums shift
        # cache and CPU metrics. No-op for the paper's 2-D configs.
        seq = float(np.clip(np.log2(w.io_kib / 8.0) / 7.0, 0.0, 1.0))
        return couple_client_knobs(metrics, config, util=util,
                                   stripe_count=sc, write_frac=w.write_frac,
                                   seq=seq)

    # ------------------------------------------------------------------
    # TuningEnvironment interface
    # ------------------------------------------------------------------

    def apply(self, config: dict, eval_run: bool = False) -> dict:
        """Simulate one workload run under ``config``; return windowed metrics.

        ``eval_run``: final-evaluation runs are 30 minutes instead of 2 (paper
        §III-B) — longer runs average down the run-to-run variance by ~sqrt(T).
        """
        return self._run_with_perf(self.mean_performance(config), config,
                                   eval_run)

    def _run_with_perf(self, perf: dict, config: dict,
                       eval_run: bool = False) -> dict:
        """The stochastic half of ``apply``: noise, cache warmth, sampling.

        Split out so the fleet path can compute ``perf`` for every session in
        one vectorized ``batch_mean_performance`` call and still consume each
        environment's RNG stream exactly as the scalar ``apply`` would.
        """
        w = self.workload
        run_seconds = 1800.0 if eval_run else self.run_seconds

        # Latent cache warmth: layout change flushes caches; otherwise AR(1).
        if config != self._last_config:
            self._warmth *= 0.4
        self._last_config = dict(config)
        self._warmth = 0.6 * self._warmth + 0.4 * float(self._rng.uniform())
        # Long evaluation runs reach cache steady state -> neutral warmth.
        warmth_eff = 0.5 if eval_run else self._warmth

        # Explainable variance: warm caches inflate short-run throughput and
        # are visible in cache_hit_ratio — Magpie's critic can attribute it;
        # black-box argmax over noisy samples cannot.
        cache_factor = float(np.exp(w.cache_kappa * (warmth_eff - 0.5)))
        # Unexplainable variance, heteroscedastic: lightly-loaded (bad)
        # configs have unstable queueing and noisier short-run throughput.
        het = 1.4 - 0.8 * min(1.0, perf["util"])
        sigma = w.noise_sigma * het * float(np.sqrt(self.run_seconds / run_seconds))
        run_factor = cache_factor * self._rng.lognormal(0.0, sigma)
        n = max(2, int(self.run_seconds / self.sample_period))
        for i in range(n):
            t_abs = self.sim_clock + (i + 1) * self.sample_period
            sample_factor = self._rng.lognormal(0.0, w.noise_sigma / 2.0)
            tput = perf["throughput"] * run_factor * sample_factor
            iops = perf["iops"] * run_factor * sample_factor
            sample = {"throughput": tput, "iops": iops}
            sample.update(self._internal_metrics(
                {**perf, "throughput": tput, "warmth": warmth_eff}, config,
                self._rng))
            self.collector.ingest(t_abs, sample)
        self.sim_clock += run_seconds
        return self.collector.window_mean(
            self.state_metrics, horizon=self.run_seconds - 1e-6)

    def restart_cost(self, config: dict, prev_config: dict) -> float:
        """Paper §III-F: 12-20 s workload restart; ~30 s extra for DFS restart.

        Every restart is logged to ``restart_events`` with its scope so
        downtime can be attributed per knob class (``restart_summary``) — the
        accounting §III-F argues makes static parameters expensive to tune
        online. The log spans the environment's lifetime; clear
        ``restart_events`` at an episode boundary to scope it (progressive
        tuning reuses the env across ``run()`` calls).
        """
        changed = [k for k in config if config[k] != prev_config.get(k)]
        if not changed:
            return 0.0
        cost = float(self._rng.uniform(12.0, 20.0))  # workload restart
        scope = "workload"
        if any(k in self.DFS_SCOPE for k in changed):
            cost += 30.0  # DFS restart
            scope = "dfs"
        self.sim_clock += cost
        self.restart_events.append((scope, cost))
        return cost

    def restart_summary(self) -> dict:
        """Restart accounting over ``restart_events``: {scope: {count,
        seconds}}. Covers the env's whole life; clear ``restart_events``
        between episodes to get per-episode numbers."""
        out = {"workload": {"count": 0, "seconds": 0.0},
               "dfs": {"count": 0, "seconds": 0.0}}
        for scope, seconds in self.restart_events:
            out[scope]["count"] += 1
            out[scope]["seconds"] += seconds
        return out

    # pure-JAX twin (the fused episode engine's env core) -----------------

    def as_model(self):
        """The pure-functional JAX twin of this environment: same parameter
        space, workload, surface and metric coupling as ``EnvModel`` pure
        functions (``envs.lustre_model.LustreSimModel``). Noise structure
        matches draw-for-draw but flows through a JAX key instead of this
        instance's numpy Generator, so the twin is a *model of the same
        system*, not a bit-replay of this instance's stream."""
        from repro.envs.lustre_model import LustreSimModel
        return LustreSimModel(
            self.workload.name, space=self.param_space,
            dfs_scope=type(self).DFS_SCOPE,
            run_seconds=self.run_seconds, sample_period=self.sample_period)

    def to_model_env(self, seed: int = None):
        """``ModelEnv`` host adapter over ``as_model()`` — a drop-in
        ``TuningEnvironment`` whose ``apply`` is a thin dict shim over the
        pure core (one jitted step per call, bit-identical to the graph)."""
        from repro.envs.base import ModelEnv
        return ModelEnv(self.as_model(),
                        seed=self._seed if seed is None else seed)

    # convenience for tests / benchmarks ---------------------------------

    def _score_batch(self, configs: list, weights: dict) -> np.ndarray:
        """Scalarized noise-free objective for N configs in one surface pass."""
        perfs = batch_mean_performance([self] * len(configs), configs)
        return np.array([
            sum(wt * self.metric_specs[name].norm(p[name])
                for name, wt in weights.items())
            for p in perfs])

    def true_optimum(self, weights: dict) -> tuple:
        """Grid-search the noise-free surface for the scalarized optimum."""
        configs = self.param_space.grid(16)
        scores = self._score_batch(configs, weights)
        i = int(np.argmax(scores))
        return configs[i], float(scores[i])


class LustreSimV2(LustreSimEnv):
    """The 8-knob mixed-type environment (``magpie8_param_space``).

    Same cluster, workloads, metric pipeline and noise model as
    ``LustreSimEnv``; the static-parameter space grows from the paper's 2-D
    layout pair to the realistic 8-D client+server space (DIAL/CARAT knobs),
    with the response-surface interactions and Table-I metric coupling
    implemented in ``_client_knob_factor`` / ``couple_client_knobs``. Under
    the all-defaults configuration the only factor differing from the 2-D
    surface is the service-thread response, so headroom comparisons against
    ``LustreSimEnv`` stay meaningful.

    Restart scopes: ``checksums`` (remount) and ``service_threads`` (server
    restart) need a full-DFS restart; the client OSC knobs and the layout
    pair take a workload restart only.
    """

    DFS_SCOPE = ("service_threads", "checksums")

    def __init__(self, workload: str = "file_server", seed: int = 0,
                 run_seconds: float = 120.0, sample_period: float = 10.0):
        super().__init__(workload, seed=seed, extended=False,
                         run_seconds=run_seconds, sample_period=sample_period)
        self.param_space = magpie8_param_space()

    def true_optimum(self, weights: dict, samples: int = 2048,
                     sweeps: int = 2) -> tuple:
        """Random sample + coordinate descent on the noise-free surface.

        The full 8-D space has ~5.5M distinct configs — exhaustive enumeration stops being
        an oracle exactly where the paper says RL should win. ``samples``
        LHS-free uniform draws seed a coordinate descent that sweeps each
        parameter's full value set (finite for all non-continuous kinds).
        """
        rng = np.random.default_rng(0)
        space = self.param_space
        configs = space.to_configs(rng.uniform(size=(samples, space.dim)))
        scores = self._score_batch(configs, weights)
        i = int(np.argmax(scores))
        best, best_score = configs[i], float(scores[i])
        for _ in range(sweeps):
            for spec in space.specs:
                card = spec.cardinality or 9
                values = spec.from_unit_batch(np.linspace(0.0, 1.0, card))
                cands = [{**best, spec.name: v} for v in values]
                s = self._score_batch(cands, weights)
                j = int(np.argmax(s))
                if float(s[j]) > best_score:
                    best, best_score = cands[j], float(s[j])
        return best, best_score
