"""Calibrated Lustre-cluster simulator (the paper's evaluation environment).

Hardware model = the paper's cluster (§III-B): 6 OST nodes + 3 client nodes on
a single 1 GbE switch, HDD-backed OSTs. The two tuned static parameters are the
paper's (§III-A): ``stripe_count`` in {1..6} and ``stripe_size`` in powers of
two from 64 KiB to 64 MiB (Lustre defaults: count 1, size 1 MiB).

The response surface encodes the real mechanisms that make these parameters
matter on such a cluster:
  * striping parallelism P(sc): more OSTs serve one file -> higher aggregate
    bandwidth, sub-linear (gamma) and with cross-client contention (beta);
    large sequential writes scale best (the paper's +250.4% headroom),
    metadata-heavy small-file work *degrades* with striping (File Server).
  * stripe-size response S(ss): RPC efficiency vs seek/imbalance trade-off,
    workload-dependent optimum (small for small random I/O, large for
    streaming), expressed on l = log2(ss / 64 KiB).
  * interaction X(sc, ss): very large stripes on many OSTs cause imbalance
    (fewer stripes than OSTs in flight) — parameters are not independent.
  * aggregate caps: 3 x 117 MB/s client NICs; 6 x ~160 MB/s HDDs.
  * multiplicative lognormal noise, per-run and per-sample, workload-specific
    (File Server has the highest variance, matching the paper's observation).

All Table-I metrics are derived *consistently* with the produced throughput
(queueing-style: in-flight RPC counts rise super-linearly near saturation,
dirty/grant bytes follow the write share and stripe width, MDS iowait follows
metadata intensity). That coupling is what gives Magpie's metric-state its
advantage over black-box search — exactly the paper's thesis.

This module is a *simulator* of the paper's physical testbed: the RL algorithm
above it is unchanged. Calibration targets & checks live in
tests/test_env_calibration.py.
"""

from __future__ import annotations

import numpy as np

from repro.core.action_mapping import ParamSpace, ParamSpec
from repro.envs.base import TuningEnvironment
from repro.envs.metrics import (
    LUSTRE_STATE_METRICS,
    MetricsCollector,
    lustre_metric_specs,
    MiB,
)
from repro.envs.workloads import WORKLOADS, Workload, param_arrays

# -- cluster constants (paper §III-B) ---------------------------------------
NUM_OSTS = 6
NUM_CLIENTS = 3
CLIENT_NIC_MBPS = 117.0          # 1 GbE payload
HDD_MBPS = 160.0                 # per-OST sequential media bandwidth
NET_CAP = NUM_CLIENTS * CLIENT_NIC_MBPS
L_DEFAULT = 4.0                  # log2(1 MiB / 64 KiB)

STRIPE_SIZES = tuple(int(64 * 1024 * 2 ** i) for i in range(11))  # 64KiB..64MiB


def paper_param_space() -> ParamSpace:
    """The paper's two static parameters (§III-A)."""
    return ParamSpace(specs=(
        ParamSpec("stripe_count", "discrete", minimum=1, maximum=NUM_OSTS, default=1),
        ParamSpec("stripe_size", "choice", values=STRIPE_SIZES,
                  default=int(1 * MiB)),
    ))


def extended_param_space() -> ParamSpace:
    """Beyond-paper: adds an OSS service-thread count (DFS-restart scope)."""
    return ParamSpace(specs=(
        ParamSpec("stripe_count", "discrete", minimum=1, maximum=NUM_OSTS, default=1),
        ParamSpec("stripe_size", "choice", values=STRIPE_SIZES,
                  default=int(1 * MiB)),
        ParamSpec("service_threads", "choice",
                  values=(8, 16, 32, 64, 128, 256, 512), default=64),
    ))


def batch_mean_performance(envs, configs) -> list:
    """Noise-free response surface for N (env, config) sessions in one pass.

    THE surface implementation: ``LustreSimEnv.mean_performance`` is the
    N == 1 case, so the fleet fast path (one vectorized evaluation per fleet
    step) and the scalar path agree by construction. Per-session workload
    shape parameters come from ``workloads.param_arrays``.
    """
    if len(envs) != len(configs):
        raise ValueError("need one config per env")
    for env, config in zip(envs, configs):
        if not env.param_space.validate(config):
            raise ValueError(f"invalid config {config}")

    w = param_arrays([env.workload for env in envs])
    sc = np.array([int(c["stripe_count"]) for c in configs])
    ss = np.array([int(c["stripe_size"]) for c in configs])
    gamma, beta = w["gamma"], w["beta"]
    l_gate, gate_width = w["l_gate"], w["gate_width"]
    l_opt, l_width, s_amp = w["l_opt"], w["l_width"], w["s_amp"]
    base, io_kib = w["base_mbps"], w["io_kib"]

    l = np.log2(ss / (64 * 1024))

    # striping parallelism vs contention
    p = sc ** gamma * np.exp(-beta * (sc - 1))
    # striping-efficiency gate: wide layouts only pay off with stripes big
    # enough for full-size RPCs (narrow ridge in (sc, ss) space -> strong
    # parameter interaction, the paper's 'dependencies among parameters')
    r_gate = 1.0 / (1.0 + np.exp(-(l - l_gate) / gate_width))
    p_eff = np.where(p >= 1.0, 1.0 + (p - 1.0) * r_gate, p)

    # stripe-size response, normalized to 1 at the default (1 MiB)
    def s_raw(ll):
        return 1.0 + s_amp * (1.0 - ((ll - l_opt) / l_width) ** 2)

    s = np.maximum(0.4, s_raw(l)) / np.maximum(0.4, s_raw(L_DEFAULT))
    # interaction: stripes wider than ~16 MiB underfill wide layouts
    x = 1.0 - 0.03 * np.maximum(0, sc - 1) * np.maximum(0.0, l - 8.0)
    x = np.maximum(0.6, x)

    t = base * p_eff * s * x

    # beyond-paper knob: OSS service threads (peak near 128)
    threads = np.array([float(c.get("service_threads", 0)) for c in configs])
    has_threads = threads > 0
    if has_threads.any():
        th = np.where(has_threads, threads, 1.0)
        factor = 0.75 + 0.33 * np.exp(-((np.log2(th) - 7.0) / 3.0) ** 2)
        t = np.where(has_threads, t * factor, t)

    # physical caps: client NICs in aggregate; sc OSTs of media bandwidth
    t = np.minimum(np.minimum(t, NET_CAP * 0.95), sc * HDD_MBPS * 1.05)

    # IOPS: ops rate = bytes / effective op size; finer stripes raise the
    # server-visible op rate (RPC amplification) — the multi-objective
    # tension of §III-D.
    amp = 1.0 + 0.6 * np.maximum(0.0, (L_DEFAULT - l)) / L_DEFAULT
    iops = t * 1024.0 / io_kib * amp
    util = t / NET_CAP

    return [
        {"throughput": float(t[i]), "iops": float(iops[i]),
         "util": float(util[i]), "l": float(l[i]), "sc": int(sc[i])}
        for i in range(len(envs))
    ]


class LustreSimEnv(TuningEnvironment):
    #: parameters whose change needs a full-DFS restart (vs workload restart)
    DFS_SCOPE = ("service_threads",)

    def __init__(self, workload: str = "file_server", seed: int = 0,
                 extended: bool = False, run_seconds: float = 120.0,
                 sample_period: float = 10.0):
        if workload not in WORKLOADS:
            raise ValueError(f"unknown workload {workload!r}; "
                             f"choose from {sorted(WORKLOADS)}")
        self.workload: Workload = WORKLOADS[workload]
        self.param_space = extended_param_space() if extended else paper_param_space()
        self.metric_specs = lustre_metric_specs()
        self.state_metrics = list(LUSTRE_STATE_METRICS)
        self.run_seconds = run_seconds
        self.sample_period = sample_period
        self.collector = MetricsCollector()
        self._rng = np.random.default_rng(seed)
        self.sim_clock = 0.0  # simulated seconds elapsed (runs + restarts)
        # Latent client-cache warmth in [0,1]: persists across runs, cooled by
        # layout changes, drives the *explainable* share of short-run variance.
        self._warmth = 0.5
        self._last_config: dict = {}

    # ------------------------------------------------------------------
    # Response surface
    # ------------------------------------------------------------------

    def mean_performance(self, config: dict) -> dict:
        """Noise-free steady-state performance + internals for a config.

        Exposed separately so tests/benchmarks can query the true surface
        (e.g. to locate the global optimum for regret checks). The N == 1
        case of ``batch_mean_performance`` — one shared surface implementation.
        """
        return batch_mean_performance([self], [config])[0]

    def _internal_metrics(self, perf: dict, rng: np.random.Generator) -> dict:
        """Table-I metrics, consistent with the delivered performance."""
        w = self.workload
        t, util, l, sc = perf["throughput"], perf["util"], perf["l"], perf["sc"]
        rpc_mb = min(2 ** l * 64 / 1024.0, 4.0)  # RPC <= 4 MiB
        latency = 0.05 * (1.0 + 3.0 * util ** 2)  # queueing delay near saturation
        write_mb = t * w.write_frac
        read_mb = t - write_mb

        def jitter(v, s=0.05):
            return float(v * rng.lognormal(0.0, s))

        metrics = {
            "cur_dirty_bytes": jitter(write_mb * 2.0 * MiB),  # ~2 s writeback window
            "cur_grant_bytes": jitter((sc * 32 + write_mb) * MiB),
            "read_rpcs_in_flight": jitter(read_mb / max(rpc_mb, 1e-3) * latency),
            "write_rpcs_in_flight": jitter(write_mb / max(rpc_mb, 1e-3) * latency),
            "pending_read_pages": jitter((read_mb / 4.0) * 256.0 * util ** 2),
            "pending_write_pages": jitter((write_mb / 4.0) * 256.0 * util ** 2),
            "cache_hit_ratio": float(np.clip(
                w.cache_base + 0.45 * (perf.get("warmth", 0.5) - 0.5)
                + 0.03 * (l - L_DEFAULT) - 0.2 * util
                + rng.normal(0.0, 0.02), 0.0, 1.0)),
            "cpu_usage_idle": float(np.clip(
                100.0 - 55.0 * w.meta_rate - 25.0 * util + rng.normal(0, 2.0),
                0.0, 100.0)),
            "cpu_usage_iowait": float(np.clip(
                35.0 * w.meta_rate * (0.5 + util) + 8.0 * util
                + rng.normal(0, 1.5), 0.0, 100.0)),
            "ram_used_percent": float(np.clip(
                28.0 + 40.0 * util + write_mb * 2.0 / (16 * 1024.0) * 100.0
                + rng.normal(0, 1.5), 0.0, 100.0)),
        }
        return metrics

    # ------------------------------------------------------------------
    # TuningEnvironment interface
    # ------------------------------------------------------------------

    def apply(self, config: dict, eval_run: bool = False) -> dict:
        """Simulate one workload run under ``config``; return windowed metrics.

        ``eval_run``: final-evaluation runs are 30 minutes instead of 2 (paper
        §III-B) — longer runs average down the run-to-run variance by ~sqrt(T).
        """
        return self._run_with_perf(self.mean_performance(config), config,
                                   eval_run)

    def _run_with_perf(self, perf: dict, config: dict,
                       eval_run: bool = False) -> dict:
        """The stochastic half of ``apply``: noise, cache warmth, sampling.

        Split out so the fleet path can compute ``perf`` for every session in
        one vectorized ``batch_mean_performance`` call and still consume each
        environment's RNG stream exactly as the scalar ``apply`` would.
        """
        w = self.workload
        run_seconds = 1800.0 if eval_run else self.run_seconds

        # Latent cache warmth: layout change flushes caches; otherwise AR(1).
        if config != self._last_config:
            self._warmth *= 0.4
        self._last_config = dict(config)
        self._warmth = 0.6 * self._warmth + 0.4 * float(self._rng.uniform())
        # Long evaluation runs reach cache steady state -> neutral warmth.
        warmth_eff = 0.5 if eval_run else self._warmth

        # Explainable variance: warm caches inflate short-run throughput and
        # are visible in cache_hit_ratio — Magpie's critic can attribute it;
        # black-box argmax over noisy samples cannot.
        cache_factor = float(np.exp(w.cache_kappa * (warmth_eff - 0.5)))
        # Unexplainable variance, heteroscedastic: lightly-loaded (bad)
        # configs have unstable queueing and noisier short-run throughput.
        het = 1.4 - 0.8 * min(1.0, perf["util"])
        sigma = w.noise_sigma * het * float(np.sqrt(self.run_seconds / run_seconds))
        run_factor = cache_factor * self._rng.lognormal(0.0, sigma)
        n = max(2, int(self.run_seconds / self.sample_period))
        for i in range(n):
            t_abs = self.sim_clock + (i + 1) * self.sample_period
            sample_factor = self._rng.lognormal(0.0, w.noise_sigma / 2.0)
            tput = perf["throughput"] * run_factor * sample_factor
            iops = perf["iops"] * run_factor * sample_factor
            sample = {"throughput": tput, "iops": iops}
            sample.update(self._internal_metrics(
                {**perf, "throughput": tput, "warmth": warmth_eff}, self._rng))
            self.collector.ingest(t_abs, sample)
        self.sim_clock += run_seconds
        return self.collector.window_mean(
            self.state_metrics, horizon=self.run_seconds - 1e-6)

    def restart_cost(self, config: dict, prev_config: dict) -> float:
        """Paper §III-F: 12-20 s workload restart; ~30 s extra for DFS restart."""
        changed = [k for k in config if config[k] != prev_config.get(k)]
        if not changed:
            return 0.0
        cost = float(self._rng.uniform(12.0, 20.0))  # workload restart
        if any(k in self.DFS_SCOPE for k in changed):
            cost += 30.0  # DFS restart
        self.sim_clock += cost
        return cost

    # convenience for tests / benchmarks ---------------------------------

    def true_optimum(self, weights: dict) -> tuple:
        """Grid-search the noise-free surface for the scalarized optimum."""
        best, best_score = None, -np.inf
        for cfg in self.param_space.grid(16):
            perf = self.mean_performance(cfg)
            score = sum(
                wt * self.metric_specs[name].norm(perf[name])
                for name, wt in weights.items())
            if score > best_score:
                best, best_score = cfg, score
        return best, best_score
