"""Synthetic pure-JAX environment model over an arbitrary quantized space.

A reference ``EnvModel`` implementation (and the property-test workhorse):
a random smooth response surface whose metrics depend on the *decoded*
configuration only — the contract every env model must honour so the fused
episode engine (raw actions in-graph) and the host adapter (actions
round-tripped through config dicts) see identical dynamics. Used by
tests/test_episode.py to prove scan/host bitwise equality over random
``ParamSpace``s, and by docs examples that need an env without Lustre
semantics.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_mapping import ParamSpace, jax_coord_maps
from repro.core.scalarization import MetricSpec
from repro.envs.base import EnvModel


class SyntheticEnvState(NamedTuple):
    key: jax.Array
    last_values: jnp.ndarray  # f32 [m], NaN before the first apply


class SyntheticParams(NamedTuple):
    w: jnp.ndarray  # [k, m] surface weights
    b: jnp.ndarray  # [k] surface offsets


@functools.lru_cache(maxsize=None)
def _build_fns(space: ParamSpace, n_metrics: int, noise: float,
               dfs_scope: tuple) -> tuple:
    maps = jax_coord_maps(space)
    m = space.dim
    dfs_mask = jnp.asarray([n in dfs_scope for n in space.names])

    def init_fn(params, key):
        del params
        return SyntheticEnvState(
            key=key, last_values=jnp.full((m,), jnp.nan, jnp.float32))

    def step_fn(params, state, action, eval_run):
        a = jnp.clip(jnp.asarray(action, jnp.float32), 0.0, 1.0)
        d = [maps[j](a[j]) for j in range(m)]
        values = jnp.stack([c["value"] for c in d])
        q = jnp.stack([c["q"] for c in d])  # canonical unit coords
        changed = values != state.last_values
        changed_any = jnp.any(changed)
        dfs_changed = jnp.any(changed & dfs_mask)

        key, k_noise, k_restart = jax.random.split(state.key, 3)
        clean = 5.0 * (1.0 + jnp.tanh(params.w @ q + params.b))  # [k] in (0,10)
        sigma = np.float32(noise) * (0.25 if eval_run else 1.0)
        metrics = clean * jnp.exp(
            sigma * jax.random.normal(k_noise, clean.shape))

        u = jax.random.uniform(k_restart, minval=5.0, maxval=10.0)
        cost = jnp.where(
            changed_any, u + jnp.where(dfs_changed, 20.0, 0.0), 0.0)
        return (SyntheticEnvState(key=key, last_values=values),
                metrics.astype(jnp.float32), cost)

    return init_fn, step_fn


class SyntheticSurfaceModel(EnvModel):
    """Random-but-deterministic smooth surface: metrics
    ``5 * (1 + tanh(W q + b))`` over the canonical unit coordinates ``q`` of
    the decoded config, with multiplicative lognormal noise. ``surface_seed``
    fixes W/b (so two instances share a surface); the episode stream comes
    from the key passed to ``init_state``."""

    def __init__(self, space: ParamSpace, n_metrics: int = 3,
                 surface_seed: int = 0, noise: float = 0.05,
                 dfs_scope: tuple = ()):
        self.param_space = space
        self.dfs_scope = tuple(k for k in dfs_scope if k in space.names)
        self.state_metrics = [f"m{i}" for i in range(n_metrics)]
        self.metric_specs = {
            n: MetricSpec(n, 0.0, 10.0, description="synthetic surface metric")
            for n in self.state_metrics}
        rng = np.random.default_rng(surface_seed)
        self.params = SyntheticParams(
            w=jnp.asarray(rng.normal(0.0, 1.0, (n_metrics, space.dim)),
                          jnp.float32),
            b=jnp.asarray(rng.normal(0.0, 0.5, (n_metrics,)), jnp.float32))
        self._init_fn, self._step_fn = _build_fns(
            space, n_metrics, float(noise), self.dfs_scope)

    @property
    def init_fn(self):
        return self._init_fn

    @property
    def step_fn(self):
        return self._step_fn
