"""Filebench workload models (paper Table II).

Each workload is a parametric I/O character used by the Lustre simulator's
response surface. The shape parameters are calibrated (see tests/test_env_
calibration.py) so the *optimal-over-default* throughput headroom per workload
matches the paper's reported tuning gains: Sequential Write ~+250% (paper:
+250.4%), and a ~92% average across the five workloads (paper: 91.8%).

Response-surface form (see lustre_sim.py):
    T(sc, ss) = base_mbps * P(sc) * S(log2 ss) * X(sc, ss) * noise
    P(sc) = sc^gamma * exp(-beta (sc-1))          # striping parallelism vs contention
    S(l)  = (1 + s_amp (1 - ((l-l_opt)/l_width)^2)) / (same at l_default)
with l = log2(stripe_size / 64 KiB) in [0, 10] and l_default = 4 (1 MiB).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    description: str
    base_mbps: float      # single-OST throughput at default stripe size
    gamma: float          # striping parallelism exponent
    beta: float           # striping contention penalty
    l_opt: float          # optimal log2(stripe/64KiB)
    l_width: float        # stripe-size sensitivity width
    s_amp: float          # stripe-size response amplitude
    io_kib: float         # mean application I/O size (KiB) -> IOPS scale
    write_frac: float     # fraction of bytes written (vs read)
    meta_rate: float      # metadata ops intensity in [0, 1] (MDS load)
    cache_base: float     # baseline client cache hit ratio
    noise_sigma: float    # multiplicative lognormal noise (File Server highest)
    # Striping-efficiency gate: striping across sc OSTs only pays off once the
    # stripe is large enough for full-size RPCs (small stripes on wide layouts
    # shatter each request into tiny per-OST RPCs + seeks). R(l) =
    # sigmoid((l - l_gate)/gate_width); l_gate < 0 disables the gate.
    l_gate: float = -10.0
    gate_width: float = 0.8
    # Sensitivity of short-run measured throughput to client cache warmth, a
    # latent AR(1) state that persists across runs, is cooled by layout
    # changes, is *visible* to Magpie through cache_hit_ratio, and averages
    # out in 30-minute evaluation runs. This is the explainable part of the
    # measurement variance (the unexplainable part is noise_sigma).
    cache_kappa: float = 0.30


def param_arrays(workloads) -> dict:
    """Per-workload shape parameters packed as {field: np.array([N])}.

    The vectorized response surface (``lustre_sim.batch_mean_performance``)
    evaluates N sessions with different workloads in one numpy pass; this
    keeps the field list in the module that owns the dataclass.
    """
    fields = ("base_mbps", "gamma", "beta", "l_opt", "l_width", "s_amp",
              "io_kib", "l_gate", "gate_width", "write_frac", "meta_rate")
    return {f: np.array([getattr(w, f) for w in workloads]) for f in fields}


WORKLOADS = {
    "file_server": Workload(
        name="file_server",
        description="Creates/deletes/appends/reads/writes/attrs on many small files",
        base_mbps=62.0, gamma=0.10, beta=0.15, l_opt=1.0, l_width=3.5, s_amp=0.70,
        io_kib=16.0, write_frac=0.55, meta_rate=0.90, cache_base=0.35,
        noise_sigma=0.18, cache_kappa=0.50,
    ),
    "video_server": Workload(
        name="video_server",
        description="Streams active videos, writes inactive set",
        base_mbps=98.0, gamma=0.25, beta=0.025, l_opt=8.0, l_width=4.5, s_amp=0.30,
        io_kib=512.0, write_frac=0.15, meta_rate=0.10, cache_base=0.55,
        noise_sigma=0.10, cache_kappa=0.35, l_gate=4.0, gate_width=1.0,
    ),
    "seq_write": Workload(
        name="seq_write",
        description="Sequential write of 5 files with multiple threads",
        base_mbps=88.0, gamma=0.68, beta=0.015, l_opt=6.0, l_width=3.0, s_amp=0.55,
        io_kib=1024.0, write_frac=1.00, meta_rate=0.05, cache_base=0.10,
        noise_sigma=0.12, cache_kappa=0.15, l_gate=5.0, gate_width=0.6,
    ),
    "seq_read": Workload(
        name="seq_read",
        description="Sequential read of 5 files with multiple threads",
        base_mbps=105.0, gamma=0.30, beta=0.040, l_opt=7.0, l_width=4.0, s_amp=0.50,
        io_kib=1024.0, write_frac=0.00, meta_rate=0.05, cache_base=0.60,
        noise_sigma=0.10, cache_kappa=0.45, l_gate=4.5, gate_width=0.8,
    ),
    "random_rw": Workload(
        name="random_rw",
        description="One thread random-reads, one random-writes a large file",
        base_mbps=45.0, gamma=0.30, beta=0.060, l_opt=2.0, l_width=4.0, s_amp=0.55,
        io_kib=8.0, write_frac=0.50, meta_rate=0.15, cache_base=0.25,
        noise_sigma=0.14, cache_kappa=0.35,
    ),
}
