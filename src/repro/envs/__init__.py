from repro.envs.base import TuningEnvironment
from repro.envs.metrics import MetricsCollector, lustre_metric_specs
from repro.envs.workloads import WORKLOADS, Workload
from repro.envs.lustre_sim import LustreSimEnv

__all__ = [
    "TuningEnvironment", "MetricsCollector", "lustre_metric_specs",
    "WORKLOADS", "Workload", "LustreSimEnv",
]

# NB: envs.sharding_env is imported lazily (it pulls in launch/roofline);
# `from repro.envs.sharding_env import ShardingEnv` where needed.
