from repro.envs.base import EnvModel, ModelEnv, TuningEnvironment
from repro.envs.metrics import (
    LUSTRE_STATE_METRICS,
    MetricsCollector,
    couple_client_knobs,
    lustre_metric_specs,
)
from repro.envs.workloads import WORKLOADS, Workload
from repro.envs.lustre_sim import (
    LustreSimEnv,
    LustreSimV2,
    batch_mean_performance,
    extended_param_space,
    magpie8_param_space,
    paper_param_space,
)
from repro.envs.lustre_model import LustreParams, LustreSimModel
from repro.envs.synthetic import SyntheticSurfaceModel
from repro.envs.faults import (
    ChaosConfig,
    FaultInjectedModel,
    FaultSpec,
    HostChaos,
    TransientChunkError,
    latency_spike,
    metric_dropout,
    nan_poison,
    throughput_collapse,
)

__all__ = [
    "TuningEnvironment", "EnvModel", "ModelEnv",
    "MetricsCollector", "lustre_metric_specs",
    "LUSTRE_STATE_METRICS", "couple_client_knobs",
    "WORKLOADS", "Workload",
    "LustreSimEnv", "LustreSimV2", "batch_mean_performance",
    "LustreSimModel", "LustreParams", "SyntheticSurfaceModel",
    "paper_param_space", "extended_param_space", "magpie8_param_space",
    "FaultSpec", "FaultInjectedModel",
    "throughput_collapse", "latency_spike", "metric_dropout", "nan_poison",
    "ChaosConfig", "HostChaos", "TransientChunkError",
]

# NB: envs.sharding_env is imported lazily (it pulls in launch/roofline);
# `from repro.envs.sharding_env import ShardingEnv` where needed.
