"""Metric specifications (paper Table I) + a windowed metrics collector.

The collector plays Telegraf+InfluxDB's role in the paper's architecture: it
ingests time-stamped samples from the environment during a workload run and
answers windowed-average queries. The normalization bounds below are the
'domain knowledge' bounds of §II-B-3, sized for the paper's cluster (6 OSTs on
1 GbE, 16 GB RAM nodes).
"""

from __future__ import annotations

import collections
from typing import Mapping

import numpy as np

from repro.core.scalarization import MetricSpec

MiB = 1024.0 * 1024.0


def lustre_metric_specs() -> Mapping[str, MetricSpec]:
    """Table I metrics + the two performance indicators (throughput, IOPS)."""
    specs = [
        # -- OSC (client) scope, paper Table I -------------------------------
        MetricSpec("cur_dirty_bytes", 0.0, 512 * MiB, "OSC",
                   "Bytes written and cached by this OSC."),
        MetricSpec("cur_grant_bytes", 0.0, 2048 * MiB, "OSC",
                   "Space the client reserved for writeback cache."),
        MetricSpec("read_rpcs_in_flight", 0.0, 256.0, "OSC",
                   "Read RPCs issued but incomplete during snapshot."),
        MetricSpec("write_rpcs_in_flight", 0.0, 256.0, "OSC",
                   "Write RPCs issued but incomplete during snapshot."),
        MetricSpec("pending_read_pages", 0.0, 65536.0, "OSC",
                   "Pending read pages queued for I/O in the OSC."),
        MetricSpec("pending_write_pages", 0.0, 65536.0, "OSC",
                   "Pending write pages queued for I/O in the OSC."),
        MetricSpec("cache_hit_ratio", 0.0, 1.0, "OSC",
                   "Hits / total cache accesses."),
        # -- MDS (server) scope ----------------------------------------------
        MetricSpec("cpu_usage_idle", 0.0, 100.0, "MDS",
                   "CPU idle percentage."),
        MetricSpec("cpu_usage_iowait", 0.0, 100.0, "MDS",
                   "CPU iowait percentage."),
        MetricSpec("ram_used_percent", 0.0, 100.0, "OSC&MDS",
                   "Used RAM percentage."),
        # -- performance indicators (objectives; also part of the state so the
        #    reward r_t = Δ(Σ w_i s(i))/Σ w_i s(i) reads them off the state) --
        MetricSpec("throughput", 0.0, 400.0, "OST",
                   "Aggregate MB/s delivered to clients."),
        MetricSpec("iops", 0.0, 60000.0, "OST",
                   "I/O operations per second."),
    ]
    return {s.name: s for s in specs}


#: Fixed state ordering (k = 12): Table-I metrics first, objectives last.
LUSTRE_STATE_METRICS = [
    "cur_dirty_bytes", "cur_grant_bytes", "read_rpcs_in_flight",
    "write_rpcs_in_flight", "pending_read_pages", "pending_write_pages",
    "cache_hit_ratio", "cpu_usage_idle", "cpu_usage_iowait",
    "ram_used_percent", "throughput", "iops",
]


def scope_mask(metric_specs: Mapping[str, MetricSpec], state_metrics,
               scopes) -> np.ndarray:
    """0/1 float32 visibility mask over ``state_metrics`` for ``scopes``.

    A metric is visible when any of its (``&``-joined) scopes is in
    ``scopes`` — e.g. ``ram_used_percent`` ("OSC&MDS") is visible to both an
    OSC-scoped and an MDS-scoped observer. This is the DIAL-style
    decentralized observation model: a client-scope tuner sees only
    client-side (OSC) metrics and must tune from that partial state.
    """
    wanted = {str(s) for s in scopes}
    known = {part for spec in metric_specs.values()
             for part in spec.scope.split("&")}
    unknown = wanted - known
    if unknown:
        raise ValueError(f"unknown metric scopes {sorted(unknown)}; "
                         f"known: {sorted(known)}")
    mask = np.zeros((len(state_metrics),), np.float32)
    for i, name in enumerate(state_metrics):
        parts = set(metric_specs[name].scope.split("&"))
        mask[i] = 1.0 if parts & wanted else 0.0
    return mask


def couple_client_knobs(metrics: dict, config: Mapping, *, util: float,
                        stripe_count: int, write_frac: float,
                        seq: float) -> dict:
    """Couple Table-I metrics to the client knobs of the 8-D space (§III-A).

    The paper's thesis is that server *and client* metrics expose what a knob
    did to the system — black-box search sees only the objective. This helper
    enforces that visibility for the DIAL/CARAT-style client knobs: the metric
    a knob limits is clamped at that limit, and cache/CPU metrics shift with
    read-ahead and checksumming. Knobs absent from ``config`` (the paper's 2-D
    space) leave the metrics untouched, and no RNG is consumed, so the scalar
    and fleet sampling streams stay aligned.

    ``util`` is delivered-throughput / network capacity in [0, 1]; ``seq`` is
    the workload's sequentiality in [0, 1] (0 = random I/O).
    """
    out = dict(metrics)
    if "max_rpcs_in_flight" in config:
        # per-OSC, per-OST concurrency limit aggregated over the stripe width
        cap = float(config["max_rpcs_in_flight"]) * max(1, int(stripe_count))
        spill_r = max(0.0, out["read_rpcs_in_flight"] - cap)
        spill_w = max(0.0, out["write_rpcs_in_flight"] - cap)
        out["read_rpcs_in_flight"] = min(out["read_rpcs_in_flight"], cap)
        out["write_rpcs_in_flight"] = min(out["write_rpcs_in_flight"], cap)
        # RPCs denied a slot queue as pending pages (256 pages per 1 MiB RPC)
        out["pending_read_pages"] += spill_r * 256.0
        out["pending_write_pages"] += spill_w * 256.0
    if "max_dirty_mb" in config:
        cap = float(config["max_dirty_mb"]) * MiB
        out["cur_dirty_bytes"] = min(out["cur_dirty_bytes"], cap)
        out["cur_grant_bytes"] = min(out["cur_grant_bytes"],
                                     2.0 * cap + 32.0 * MiB)
    if "read_ahead_mb" in config:
        ra = float(config["read_ahead_mb"])
        h = 1.0 - np.exp(-ra / 48.0)
        h0 = 1.0 - np.exp(-64.0 / 48.0)
        shift = 0.10 * (1.0 - write_frac) * seq * (h / h0 - 1.0)
        out["cache_hit_ratio"] = float(
            np.clip(out["cache_hit_ratio"] + shift, 0.0, 1.0))
    if "checksums" in config and bool(config["checksums"]):
        # CRC32 on every RPC burns client/server CPU proportional to traffic
        out["cpu_usage_idle"] = float(
            np.clip(out["cpu_usage_idle"] - 8.0 * util, 0.0, 100.0))
    return out


class MetricsCollector:
    """Ring-buffered time-series store with windowed-average queries.

    ``ingest(t, {name: value})`` appends samples; ``window_mean(names, horizon)``
    averages the last ``horizon`` seconds — what the paper's 'Metrics Collector'
    queries from InfluxDB after each action step.
    """

    def __init__(self, capacity: int = 4096):
        self._series: dict = collections.defaultdict(
            lambda: collections.deque(maxlen=capacity)
        )

    def ingest(self, t: float, sample: Mapping[str, float]) -> None:
        for name, value in sample.items():
            self._series[name].append((float(t), float(value)))

    def window_mean(self, names, horizon: float) -> dict:
        out = {}
        for name in names:
            series = self._series.get(name)
            if not series:
                raise KeyError(f"no samples for metric {name!r}")
            t_end = series[-1][0]
            vals = [v for (t, v) in series if t >= t_end - horizon]
            out[name] = sum(vals) / len(vals)
        return out

    def latest(self, name: str) -> float:
        return self._series[name][-1][1]

    def __contains__(self, name: str) -> bool:
        return name in self._series and len(self._series[name]) > 0
