"""Pure-JAX Lustre environment model (the fused episode engine's env core).

``LustreSimModel`` is the jit/vmap-safe twin of ``envs.lustre_sim``: the same
calibrated response surface, client-knob factors, Table-I metric coupling,
cache-warmth AR(1) process and lognormal noise model — expressed as pure
float32 functions over a threaded JAX PRNG key instead of host numpy with a
``np.random.Generator``. That buys three things the numpy simulator cannot
give:

  * whole tuning episodes compile into ONE XLA program
    (``core.episode.run_episode_scan``) — no host boundary per step;
  * fleets vmap/shard over a session axis with per-session workload
    parameters as data (``LustreParams``), one compiled step for any fleet;
  * bit-reproducibility across engines: a host loop calling ``step`` once per
    apply and a ``lax.scan`` over the episode consume the identical stream.

Fidelity contract: the noise-free surface matches
``lustre_sim.batch_mean_performance`` to float32 accuracy (pinned in
tests/test_episode.py); the noise *structure* (which draws exist, what they
multiply) mirrors ``LustreSimEnv._run_with_perf`` draw-for-draw, but the
streams differ (JAX threefry vs numpy PCG64), so individual runs are not
comparable sample-for-sample — distributions are.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_mapping import ParamSpace, jax_coord_maps
from repro.envs.base import EnvModel
from repro.envs.lustre_sim import (
    CLIENT_NIC_MBPS,
    HDD_MBPS,
    L_DEFAULT,
    NET_CAP,
    paper_param_space,
)
from repro.envs.metrics import LUSTRE_STATE_METRICS, MiB, lustre_metric_specs
from repro.envs.workloads import WORKLOADS, Workload


class LustreParams(NamedTuple):
    """Per-session workload shape parameters (traced data, so a fleet mixing
    workloads shares one compiled step)."""

    base_mbps: jnp.ndarray
    gamma: jnp.ndarray
    beta: jnp.ndarray
    l_opt: jnp.ndarray
    l_width: jnp.ndarray
    s_amp: jnp.ndarray
    io_kib: jnp.ndarray
    write_frac: jnp.ndarray
    meta_rate: jnp.ndarray
    cache_base: jnp.ndarray
    noise_sigma: jnp.ndarray
    l_gate: jnp.ndarray
    gate_width: jnp.ndarray
    cache_kappa: jnp.ndarray

    @classmethod
    def from_workload(cls, w: Workload) -> "LustreParams":
        return cls(*(jnp.float32(getattr(w, f)) for f in cls._fields))


class LustreEnvState(NamedTuple):
    """Carried env state: PRNG chain, latent cache warmth, and the decoded
    value vector of the last applied configuration (NaN before the first
    apply, so the first apply always counts as a config change — matching
    ``LustreSimEnv``'s empty ``_last_config``)."""

    key: jax.Array
    warmth: jnp.ndarray        # f32 scalar in [0, 1]
    last_values: jnp.ndarray   # f32 [m] decoded parameter values


@functools.lru_cache(maxsize=None)
def build_lustre_fns(space: ParamSpace, dfs_scope: tuple,
                     run_seconds: float, sample_period: float) -> tuple:
    """(init_fn, step_fn) for one parameter space (cached: fleets sharing a
    space share the function objects, hence one jit cache entry)."""
    maps = jax_coord_maps(space)
    names = space.names
    m = space.dim
    pos = {n: j for j, n in enumerate(names)}
    if "stripe_count" not in pos or "stripe_size" not in pos:
        raise ValueError("Lustre model needs stripe_count and stripe_size")
    dfs_mask = jnp.asarray([n in dfs_scope for n in names])
    n_samples = max(2, int(run_seconds / sample_period))

    def init_fn(params, key):
        del params
        return LustreEnvState(key=key, warmth=jnp.float32(0.5),
                              last_values=jnp.full((m,), jnp.nan, jnp.float32))

    def mean_perf(params, d):
        """Noise-free surface for one decoded config — the in-graph twin of
        ``lustre_sim.batch_mean_performance`` (N == 1)."""
        p = params
        sc = d[pos["stripe_count"]]["value"]
        l = d[pos["stripe_size"]]["log2"] - 16.0  # log2(bytes / 64 KiB)

        # striping parallelism vs contention, gated by stripe size
        par = sc ** p.gamma * jnp.exp(-p.beta * (sc - 1.0))
        r_gate = 1.0 / (1.0 + jnp.exp(-(l - p.l_gate) / p.gate_width))
        p_eff = jnp.where(par >= 1.0, 1.0 + (par - 1.0) * r_gate, par)

        def s_raw(ll):
            return 1.0 + p.s_amp * (1.0 - ((ll - p.l_opt) / p.l_width) ** 2)

        s = jnp.maximum(0.4, s_raw(l)) / jnp.maximum(0.4, s_raw(L_DEFAULT))
        x = jnp.maximum(
            0.6, 1.0 - 0.03 * jnp.maximum(0.0, sc - 1.0)
            * jnp.maximum(0.0, l - 8.0))
        t = p.base_mbps * p_eff * s * x

        if "service_threads" in pos:
            lg_th = d[pos["service_threads"]]["log2"]
            t = t * (0.75 + 0.33 * jnp.exp(-((lg_th - 7.0) / 3.0) ** 2))

        # client-knob factors (exactly the ``_client_knob_factor`` responses)
        if "max_rpcs_in_flight" in pos:
            rif = d[pos["max_rpcs_in_flight"]]["value"]
            lg_rif = d[pos["max_rpcs_in_flight"]]["log2"]
            per_ost = rif / jnp.maximum(sc, 1.0)
            conc = per_ost / (per_ost + 2.0)
            over = 1.0 - 0.03 * p.meta_rate * jnp.maximum(0.0, lg_rif - 5.0)
            t = t * conc / (8.0 / 10.0) * jnp.maximum(over, 0.7)
        if "max_pages_per_rpc" in pos:
            lg_pg = d[pos["max_pages_per_rpc"]]["log2"]
            lr_opt = jnp.clip(p.l_opt, 0.0, 4.0)

            def rpc_resp(lr):
                return 1.0 + 0.10 * (1.0 - ((lr - lr_opt) / 4.0) ** 2)

            # wire RPC = min(pages * 4 KiB, stripe_size), in log2(KiB / 64)
            t = t * rpc_resp(jnp.minimum(lg_pg - 4.0, l)) \
                / rpc_resp(jnp.minimum(4.0, l))
        if "max_dirty_mb" in pos:
            dirty = d[pos["max_dirty_mb"]]["value"]
            lg_dirty = d[pos["max_dirty_mb"]]["log2"]
            h = 1.0 - jnp.exp(-dirty / 24.0)
            h0 = 1.0 - np.exp(-32.0 / 24.0)
            burst = 1.0 - 0.02 * jnp.maximum(0.0, lg_dirty - 9.0)
            t = t * ((1.0 - p.write_frac) + p.write_frac * h / h0) * burst
        if "read_ahead_mb" in pos:
            ra = d[pos["read_ahead_mb"]]["value"]
            lg_ra = d[pos["read_ahead_mb"]]["log2"]
            seq = jnp.clip(jnp.log2(p.io_kib / 8.0) / 7.0, 0.0, 1.0)
            rf = 1.0 - p.write_frac
            h = 1.0 - jnp.exp(-ra / 48.0)
            h0 = 1.0 - np.exp(-64.0 / 48.0)
            gain = 0.25 * rf * seq * (h / h0 - 1.0)
            waste = 0.12 * rf * (1.0 - seq) * jnp.clip(
                (lg_ra - 6.0) / 4.0, 0.0, 1.0)
            t = t * (1.0 + gain - waste)
        if "checksums" in pos:
            ck_on = d[pos["checksums"]]["value"] >= 0.5
            t = t * jnp.where(ck_on, 1.0, 1.04 + 0.06 * p.write_frac)

        t = jnp.minimum(jnp.minimum(t, NET_CAP * 0.95), sc * HDD_MBPS * 1.05)
        amp = 1.0 + 0.6 * jnp.maximum(0.0, L_DEFAULT - l) / L_DEFAULT
        iops = t * 1024.0 / p.io_kib * amp
        return {"throughput": t, "iops": iops, "util": t / NET_CAP,
                "l": l, "sc": sc}

    def perf_fn(params, action):
        """Noise-free surface for one unit action (tests/benchmarks)."""
        a = jnp.clip(jnp.asarray(action, jnp.float32), 0.0, 1.0)
        return mean_perf(params, [maps[j](a[j]) for j in range(m)])

    def step_fn(params, state, action, eval_run):
        p = params
        a = jnp.clip(jnp.asarray(action, jnp.float32), 0.0, 1.0)
        d = [maps[j](a[j]) for j in range(m)]
        values = jnp.stack([c["value"] for c in d])
        changed = values != state.last_values  # NaN != v on the first apply
        changed_any = jnp.any(changed)
        dfs_changed = jnp.any(changed & dfs_mask)

        key, k_w, k_run, k_samp, k_restart, k_metrics = jax.random.split(
            state.key, 6)

        # latent cache warmth: layout change flushes caches; AR(1) otherwise
        warmth = jnp.where(changed_any, state.warmth * 0.4, state.warmth)
        warmth = 0.6 * warmth + 0.4 * jax.random.uniform(k_w)
        warmth_eff = jnp.float32(0.5) if eval_run else warmth

        perf = mean_perf(params, d)
        t, iops, util = perf["throughput"], perf["iops"], perf["util"]
        l, sc = perf["l"], perf["sc"]

        # run-level noise: explainable (cache warmth) x heteroscedastic
        run_len = 1800.0 if eval_run else run_seconds
        cache_factor = jnp.exp(p.cache_kappa * (warmth_eff - 0.5))
        het = 1.4 - 0.8 * jnp.minimum(1.0, util)
        sigma = p.noise_sigma * het * np.float32(
            np.sqrt(run_seconds / run_len))
        run_factor = cache_factor * jnp.exp(sigma * jax.random.normal(k_run))
        sample_factor = jnp.exp(
            (p.noise_sigma / 2.0)
            * jax.random.normal(k_samp, (n_samples,)))
        tput = t * run_factor * sample_factor      # [n_samples]
        iops_s = iops * run_factor * sample_factor

        # Table-I metrics, consistent with the delivered per-sample throughput
        ks = jax.random.split(k_metrics, 10)

        def jitter(v, k, s=0.05):
            return v * jnp.exp(s * jax.random.normal(k, (n_samples,)))

        rpc_mb = jnp.minimum(jnp.exp2(l - 4.0), 4.0)  # RPC <= 4 MiB
        latency = 0.05 * (1.0 + 3.0 * util ** 2)
        write_mb = tput * p.write_frac
        read_mb = tput - write_mb
        cur_dirty = jitter(write_mb * 2.0 * MiB, ks[0])
        cur_grant = jitter((sc * 32.0 + write_mb) * MiB, ks[1])
        read_rpcs = jitter(read_mb / jnp.maximum(rpc_mb, 1e-3) * latency,
                           ks[2])
        write_rpcs = jitter(write_mb / jnp.maximum(rpc_mb, 1e-3) * latency,
                            ks[3])
        pend_r = jitter((read_mb / 4.0) * 256.0 * util ** 2, ks[4])
        pend_w = jitter((write_mb / 4.0) * 256.0 * util ** 2, ks[5])
        cache_hit = jnp.clip(
            p.cache_base + 0.45 * (warmth_eff - 0.5)
            + 0.03 * (l - L_DEFAULT) - 0.2 * util
            + 0.02 * jax.random.normal(ks[6], (n_samples,)), 0.0, 1.0)
        cpu_idle = jnp.clip(
            100.0 - 55.0 * p.meta_rate - 25.0 * util
            + 2.0 * jax.random.normal(ks[7], (n_samples,)), 0.0, 100.0)
        iowait = jnp.clip(
            35.0 * p.meta_rate * (0.5 + util) + 8.0 * util
            + 1.5 * jax.random.normal(ks[8], (n_samples,)), 0.0, 100.0)
        ram = jnp.clip(
            28.0 + 40.0 * util + write_mb * 2.0 / (16.0 * 1024.0) * 100.0
            + 1.5 * jax.random.normal(ks[9], (n_samples,)), 0.0, 100.0)

        # client-knob visibility (``envs.metrics.couple_client_knobs``)
        if "max_rpcs_in_flight" in pos:
            cap = d[pos["max_rpcs_in_flight"]]["value"] * jnp.maximum(sc, 1.0)
            pend_r = pend_r + jnp.maximum(0.0, read_rpcs - cap) * 256.0
            pend_w = pend_w + jnp.maximum(0.0, write_rpcs - cap) * 256.0
            read_rpcs = jnp.minimum(read_rpcs, cap)
            write_rpcs = jnp.minimum(write_rpcs, cap)
        if "max_dirty_mb" in pos:
            cap = d[pos["max_dirty_mb"]]["value"] * MiB
            cur_dirty = jnp.minimum(cur_dirty, cap)
            cur_grant = jnp.minimum(cur_grant, 2.0 * cap + 32.0 * MiB)
        if "read_ahead_mb" in pos:
            ra = d[pos["read_ahead_mb"]]["value"]
            seq = jnp.clip(jnp.log2(p.io_kib / 8.0) / 7.0, 0.0, 1.0)
            h = 1.0 - jnp.exp(-ra / 48.0)
            h0 = 1.0 - np.exp(-64.0 / 48.0)
            shift = 0.10 * (1.0 - p.write_frac) * seq * (h / h0 - 1.0)
            cache_hit = jnp.clip(cache_hit + shift, 0.0, 1.0)
        if "checksums" in pos:
            ck_on = d[pos["checksums"]]["value"] >= 0.5
            cpu_idle = jnp.where(
                ck_on, jnp.clip(cpu_idle - 8.0 * util, 0.0, 100.0), cpu_idle)

        # Windowed mean over the run's samples, in LUSTRE_STATE_METRICS order.
        # Serial left-to-right fold, NOT jnp.mean: XLA's reduce emitter picks
        # context-dependent reduction trees on CPU, which would let the fused
        # episode and the host-adapter step disagree by ulps under
        # cancellation — the bitwise engine-parity contract forbids that.
        def smean(x):
            acc = x[0]
            for i in range(1, n_samples):
                acc = acc + x[i]
            return acc / n_samples

        metrics_vec = jnp.stack([
            smean(cur_dirty), smean(cur_grant), smean(read_rpcs),
            smean(write_rpcs), smean(pend_r), smean(pend_w),
            smean(cache_hit), smean(cpu_idle), smean(iowait),
            smean(ram), smean(tput), smean(iops_s),
        ]).astype(jnp.float32)

        # §III-F restart downtime: 12-20 s workload restart, +30 s DFS scope
        u = jax.random.uniform(k_restart, minval=12.0, maxval=20.0)
        cost = jnp.where(
            changed_any, u + jnp.where(dfs_changed, 30.0, 0.0), 0.0)

        new_state = LustreEnvState(key=key, warmth=warmth, last_values=values)
        return new_state, metrics_vec, cost

    return init_fn, step_fn, perf_fn


class LustreSimModel(EnvModel):
    """``EnvModel`` over the calibrated Lustre surface.

    ``space`` defaults to the paper's 2-D layout pair; pass
    ``magpie8_param_space()`` (with ``dfs_scope=("service_threads",
    "checksums")``) for the 8-knob V2 environment — or build either via
    ``LustreSimEnv.as_model()`` / ``LustreSimV2.as_model()``.
    """

    def __init__(self, workload: str = "file_server",
                 space: ParamSpace = None,
                 dfs_scope: tuple = ("service_threads",),
                 run_seconds: float = 120.0, sample_period: float = 10.0):
        if workload not in WORKLOADS:
            raise ValueError(f"unknown workload {workload!r}; "
                             f"choose from {sorted(WORKLOADS)}")
        self.workload = WORKLOADS[workload]
        self.param_space = space if space is not None else paper_param_space()
        self.dfs_scope = tuple(k for k in dfs_scope
                               if k in self.param_space.names)
        self.metric_specs = lustre_metric_specs()
        self.state_metrics = list(LUSTRE_STATE_METRICS)
        self.run_seconds = run_seconds
        self.sample_period = sample_period
        self.params = LustreParams.from_workload(self.workload)
        self._init_fn, self._step_fn, self._perf_fn = build_lustre_fns(
            self.param_space, self.dfs_scope, run_seconds, sample_period)

    @property
    def init_fn(self):
        return self._init_fn

    @property
    def step_fn(self):
        return self._step_fn

    def mean_performance(self, config: dict) -> dict:
        """Noise-free steady-state performance for a config — the float32
        in-graph twin of ``LustreSimEnv.mean_performance`` (fidelity pinned
        in tests/test_episode.py)."""
        perf = self._perf_fn(self.params, self.param_space.to_action(config))
        return {k: float(v) for k, v in perf.items()}
