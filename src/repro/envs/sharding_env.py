"""The framework-tuning environment: Magpie's technique applied to *this
system's own* static parameters (beyond-paper, DESIGN.md §2).

A distributed JAX training job has exactly the paper's problem shape:
  * static parameters whose change forces an expensive recompile ("restart"):
    gradient-accumulation microbatches, remat policy, layer-scan unroll;
  * rich internal metrics that explain performance (the compiled artifact's
    roofline terms, per-device memory, collective counts) — the analogue of
    the paper's OSC/MDS metrics;
  * a scalar objective: steps/second upper bound = 1 / max(roofline terms),
    with OOM configurations behaving like crashed runs (near-zero reward).

The DDPG agent, replay buffer, scalarization and tuning loop are the SAME
code as the paper reproduction — only the environment differs. The restart
cost is the real, measured compile time.
"""

from __future__ import annotations

import time
import traceback
from typing import Optional

import numpy as np

from repro.core.action_mapping import ParamSpace, ParamSpec
from repro.core.scalarization import MetricSpec
from repro.envs.base import TuningEnvironment


SHARDING_STATE_METRICS = [
    "compute_s", "memory_s", "collective_s", "mem_gb", "useful_ratio",
    "compile_s", "coll_count", "fits", "steps_per_s",
]


def sharding_metric_specs():
    specs = [
        MetricSpec("compute_s", 0.0, 10.0, "roofline", "compute term"),
        MetricSpec("memory_s", 0.0, 10.0, "roofline", "HBM term"),
        MetricSpec("collective_s", 0.0, 10.0, "roofline", "ICI term"),
        MetricSpec("mem_gb", 0.0, 64.0, "device", "peak HBM estimate"),
        MetricSpec("useful_ratio", 0.0, 2.0, "roofline",
                   "model flops / structural flops"),
        MetricSpec("compile_s", 0.0, 600.0, "host", "restart analogue"),
        MetricSpec("coll_count", 0.0, 200.0, "hlo", "collective op count"),
        MetricSpec("fits", 0.0, 1.0, "device", "fits in 16 GB HBM"),
        MetricSpec("steps_per_s", 0.0, 20.0, "objective",
                   "1 / max(roofline terms), 0 if OOM"),
    ]
    return {s.name: s for s in specs}


class ShardingEnv(TuningEnvironment):
    """Tunes TrainConfig's static parameters for one (arch x shape x mesh)."""

    def __init__(self, arch: str, shape: str = "train_4k", mesh=None,
                 smoke: bool = False, seed: int = 0,
                 microbatch_choices=(1, 2, 4, 8, 16, 32),
                 batch_override: int = 0, seq_override: int = 0):
        from repro.launch.mesh import make_production_mesh
        self.arch = arch
        self.shape = shape
        self.smoke = smoke
        # smoke mode reduces the cell shape too (CPU test budget)
        self.batch_override = batch_override or (8 if smoke else 0)
        self.seq_override = seq_override or (64 if smoke else 0)
        self.mesh = mesh if mesh is not None else make_production_mesh()
        default_mb = (8 if 8 in microbatch_choices
                      else microbatch_choices[len(microbatch_choices) // 2])
        self.param_space = ParamSpace(specs=(
            ParamSpec("microbatches", "choice", values=microbatch_choices,
                      default=default_mb),
            ParamSpec("remat", "choice", values=("none", "dots", "full"),
                      default="full"),
            ParamSpec("scan_unroll", "choice", values=(1, 2, 4), default=1),
            ParamSpec("gather_weights_once", "choice", values=(0, 1),
                      default=0),
        ))
        self.metric_specs = sharding_metric_specs()
        self.state_metrics = list(SHARDING_STATE_METRICS)
        self._last_compile_s = 0.0
        self._cache: dict = {}
        self.evals = 0

    def apply(self, config: dict, eval_run: bool = False) -> dict:
        del eval_run  # the dry-run is deterministic; no long-run variant
        key = tuple(sorted(config.items()))
        if key in self._cache:
            return dict(self._cache[key])
        from repro.launch.cells import build_cell
        from repro.roofline.analysis import (
            collective_bytes_from_hlo, model_flops, roofline_terms,
        )
        from repro.roofline.hw import TPU_V5E
        from repro.roofline.structural import structural_costs
        from repro.training.steps import TrainConfig
        from repro import configs as cfgs

        self.evals += 1
        tc = TrainConfig(microbatches=int(config["microbatches"]),
                         remat=str(config["remat"]),
                         scan_unroll=int(config["scan_unroll"]),
                         gather_weights_once=bool(
                             config.get("gather_weights_once", 0)))
        t0 = time.time()
        metrics = {name: 0.0 for name in self.state_metrics}
        try:
            cell = build_cell(self.arch, self.shape, self.mesh, tc=tc,
                              smoke=self.smoke,
                              batch_override=self.batch_override,
                              seq_override=self.seq_override)
            B = cell.args[2]["tokens"].shape[0] if cell.kind == "train" else 0
            if B and B % tc.microbatches != 0:
                raise ValueError("microbatches must divide global batch")
            compiled = cell.lower(self.mesh).compile()
            self._last_compile_s = time.time() - t0
            chips = int(np.prod(list(self.mesh.shape.values())))
            sc = structural_costs(cell.fn, *cell.args)
            coll = collective_bytes_from_hlo(compiled.as_text())
            terms = roofline_terms(sc["flops"] / chips, sc["bytes"] / chips,
                                   coll["weighted_bytes"])
            ma = compiled.memory_analysis()
            peak = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
            fits = peak < TPU_V5E.hbm_bytes or self.smoke
            shape = cfgs.SHAPES[self.shape]
            cfg = (cfgs.get_smoke_config(self.arch) if self.smoke
                   else cfgs.get_config(self.arch))
            mf = model_flops(cfg, shape.kind, shape.batch, shape.seq)
            metrics.update(
                compute_s=terms["compute_s"], memory_s=terms["memory_s"],
                collective_s=terms["collective_s"], mem_gb=peak / 1e9,
                useful_ratio=mf / chips / max(sc["flops"] / chips, 1e-9),
                compile_s=self._last_compile_s,
                coll_count=float(sum(coll["counts"].values())),
                fits=float(fits),
                steps_per_s=(1.0 / terms["step_s_lower_bound"] if fits
                             else 1e-3),
            )
        except Exception:  # infeasible config == crashed run
            self._last_compile_s = time.time() - t0
            metrics["compile_s"] = self._last_compile_s
            metrics["steps_per_s"] = 1e-3
        self._cache[key] = dict(metrics)
        return metrics

    def restart_cost(self, config: dict, prev_config: dict) -> float:
        """The measured recompile time IS the static-parameter restart cost."""
        if config == prev_config:
            return 0.0
        return self._last_compile_s
