"""Tuning-environment protocol (the paper's 'Environment': DFS + workloads).

An environment owns the static-parameter space and produces a metric dict per
evaluation. ``apply`` runs (or simulates) the workload under a configuration
and returns raw metric values; ``restart_cost`` accounts the restart downtime
the paper highlights as the distinguishing cost of *static* parameters.
"""

from __future__ import annotations

import abc
from typing import Mapping

from repro.core.action_mapping import ParamSpace
from repro.core.scalarization import MetricSpec


class TuningEnvironment(abc.ABC):
    param_space: ParamSpace
    metric_specs: Mapping[str, MetricSpec]
    state_metrics: list  # ordered metric names forming the RL state vector

    @abc.abstractmethod
    def apply(self, config: dict, eval_run: bool = False) -> dict:
        """Apply a configuration, run the workload, return raw metrics.

        ``eval_run=True`` marks a long final-evaluation run (lower variance);
        environments without that notion may ignore it."""

    @abc.abstractmethod
    def restart_cost(self, config: dict, prev_config: dict) -> float:
        """Seconds of downtime incurred by switching prev_config -> config."""

    @property
    def state_dim(self) -> int:
        return len(self.state_metrics)

    @property
    def action_dim(self) -> int:
        return self.param_space.dim
