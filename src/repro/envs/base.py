"""Tuning-environment protocol (the paper's 'Environment': DFS + workloads).

Two layers live here:

``TuningEnvironment`` — the host-side dict protocol the Fig. 1 loop consumes.
An environment owns the static-parameter space and produces a metric dict per
evaluation. ``apply`` runs (or simulates) the workload under a configuration
and returns raw metric values; ``restart_cost`` accounts the restart downtime
the paper highlights as the distinguishing cost of *static* parameters.

``EnvModel`` — the pure-functional JAX twin: ``init_state(key) -> EnvState``
and ``step(state, unit_action) -> (EnvState, metrics_vec, restart_cost)`` as
jit/vmap-safe pure functions. The fused episode engine (``core.episode``)
compiles whole tuning episodes — act, env step, reward, buffer store, learn —
into one XLA program over these models, and vmaps/shards them across a fleet
session axis. ``ModelEnv`` adapts any ``EnvModel`` back to the dict protocol
(one jitted step per ``apply``), so the host-loop tuner drives the *same*
graph the fused engine scans over — that is what makes the two engines
bit-comparable.
"""

from __future__ import annotations

import abc
import functools
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.action_mapping import ParamSpace
from repro.core.scalarization import MetricSpec


class TuningEnvironment(abc.ABC):
    param_space: ParamSpace
    metric_specs: Mapping[str, MetricSpec]
    state_metrics: list  # ordered metric names forming the RL state vector

    @abc.abstractmethod
    def apply(self, config: dict, eval_run: bool = False) -> dict:
        """Apply a configuration, run the workload, return raw metrics.

        ``eval_run=True`` marks a long final-evaluation run (lower variance);
        environments without that notion may ignore it."""

    @abc.abstractmethod
    def restart_cost(self, config: dict, prev_config: dict) -> float:
        """Seconds of downtime incurred by switching prev_config -> config."""

    @property
    def state_dim(self) -> int:
        return len(self.state_metrics)

    @property
    def action_dim(self) -> int:
        return self.param_space.dim


class EnvModel(abc.ABC):
    """A tuning environment as pure jit/vmap-safe JAX functions.

    Contract:
      * ``params`` is a pytree of arrays (per-instance constants such as
        workload shape parameters). Everything *structural* — the parameter
        space, metric order, sample counts — is baked into ``step_fn`` /
        ``init_fn``, so a fleet of models sharing one space shares one
        compiled step and stacks only ``params``.
      * ``init_fn(params, key) -> EnvState`` and
        ``step_fn(params, state, unit_action, eval_run) -> (EnvState,
        metrics_vec, restart_cost)`` are pure. ``metrics_vec`` is the raw
        metric vector ordered like ``state_metrics``; ``restart_cost`` the
        §III-F downtime in seconds (0 when the decoded configuration did not
        change). ``eval_run`` is a static Python bool.
      * all stochasticity flows through the JAX key threaded in ``EnvState``,
        and the number of random draws per step is static — a host loop
        calling ``step`` once per apply and a ``lax.scan`` over the whole
        episode consume the identical stream.
      * the space must be quantized (``ParamSpace.is_quantized``) and
        dynamics must depend on the action only through its decoded values
        (``core.action_mapping.jax_coord_maps``), so raw actions and
        dict-round-tripped actions are interchangeable.
    """

    param_space: ParamSpace
    metric_specs: Mapping[str, MetricSpec]
    state_metrics: list
    params: Any
    #: parameter names whose change needs a full-DFS restart
    dfs_scope: tuple = ()

    @property
    @abc.abstractmethod
    def init_fn(self) -> Callable:
        """Pure ``(params, key) -> EnvState``."""

    @property
    @abc.abstractmethod
    def step_fn(self) -> Callable:
        """Pure ``(params, state, unit_action, eval_run) -> (EnvState,
        metrics_vec, restart_cost)``."""

    # -- bound conveniences (the protocol named in ISSUE 3) ------------------

    def init_state(self, key) -> Any:
        return self.init_fn(self.params, key)

    def step(self, state, unit_action, eval_run: bool = False) -> tuple:
        """One jitted env transition (compilation cached per step_fn)."""
        return _jit_step(self.step_fn, eval_run)(self.params, state,
                                                 unit_action)

    @property
    def state_dim(self) -> int:
        return len(self.state_metrics)

    @property
    def action_dim(self) -> int:
        return self.param_space.dim


def fusion_barrier(tree):
    """vmap-compatible ``optimization_barrier`` over a pytree.

    ``lax.optimization_barrier`` has no batching rule in current JAX; the
    fleet engine vmaps episode bodies over the session axis — and the
    shared-experience cell engine vmaps the cell axis inside the group axis,
    two levels deep — so the barrier is wrapped in ``custom_vmap`` whose rule
    re-enters the barrier itself: each vmap level peels one ``custom_vmap``
    layer (batching an identity barrier is the barrier of the batched
    value), and the innermost application emits the raw
    ``optimization_barrier``, so single-vmap callers compile the exact same
    HLO as before."""
    return _fusion_barrier(tree)


@functools.lru_cache(maxsize=1)
def _make_fusion_barrier():
    import jax
    from jax.custom_batching import custom_vmap

    @custom_vmap
    def barrier(tree):
        return jax.lax.optimization_barrier(tree)

    @barrier.def_vmap
    def _barrier_vmap(axis_size, in_batched, tree):
        del axis_size
        # re-enter the custom_vmap so nested vmap peels another layer
        return barrier(tree), in_batched[0]

    return barrier


def _fusion_barrier(tree):
    return _make_fusion_barrier()(tree)


def barriered_step(step_fn: Callable, params, state, action, eval_run: bool):
    """One env transition as an isolated fusion island.

    ``fusion_barrier`` pins the env subgraph's boundaries so XLA cannot fuse
    env arithmetic with whatever surrounds it. Every consumer of an
    ``EnvModel`` — the host adapter below, probe batches, and the fused
    episode engine (``core.episode``) — runs the step through THIS wrapper
    inside a ``lax.scan`` body, so the env island compiles the same way in
    all of them and cross-program results stay within ulps (bitwise for most
    data; XLA CPU codegen is context-dependent, so exact equality of every
    float cannot be promised across different programs)."""
    state, action = fusion_barrier((state, action))
    return fusion_barrier(step_fn(params, state, action, eval_run))


@functools.lru_cache(maxsize=None)
def _jit_step_scan(step_fn: Callable, eval_run: bool) -> Callable:
    """Chain ``step_fn`` over [N, m] actions with ONE dispatch.

    The single-``apply`` path is the N == 1 case, so host applies and probe
    batches are bitwise-equal by construction; the scan-body structure
    matches the episode engine's (see ``barriered_step``)."""
    import jax

    def scanned(params, state, actions):
        def body(st, a):
            st, vec, cost = barriered_step(step_fn, params, st, a, eval_run)
            return st, (vec, cost)
        return jax.lax.scan(body, state, actions)
    return jax.jit(scanned)


def _jit_step(step_fn: Callable, eval_run: bool) -> Callable:
    """Single-step apply = length-1 probe batch (same compiled loop body)."""
    scanned = _jit_step_scan(step_fn, eval_run)

    def one(params, state, action):
        state, (vecs, costs) = scanned(params, state, action[None])
        return state, vecs[0], costs[0]
    return one


class ModelEnv(TuningEnvironment):
    """Thin host adapter: dict-based ``apply`` over a pure ``EnvModel`` core.

    Bit-identical to the pure core by construction — ``apply`` only encodes
    the config to a unit action, runs one jitted ``step`` and names the
    resulting metric vector; no arithmetic happens on the host. Restart costs
    are computed inside the step (they are part of the pure transition) and
    surfaced through ``restart_cost`` to keep the Fig. 1 loop's call order.
    """

    def __init__(self, model: EnvModel, seed: int = 0):
        if not model.param_space.is_quantized:
            raise ValueError(
                "ModelEnv needs a quantized ParamSpace (continuous kinds do "
                "not survive the dict round trip bit-exactly)")
        self.model = model
        self.param_space = model.param_space
        self.metric_specs = model.metric_specs
        self.state_metrics = list(model.state_metrics)
        self.seed = seed
        import jax
        self.model_state = model.init_state(jax.random.PRNGKey(seed))
        self.restart_events: list = []  # (scope, seconds) per config change
        #: downtime accrued by tuning applies since the last restart_cost()
        #: read; None = no tuning apply happened (eval-only protocols fall
        #: back to the diff-based host draw below)
        self._pending_restart = None
        self._fallback_rng = np.random.default_rng(seed + 17)
        self._last_scope = "workload"
        self._last_config: dict = {}

    def _scope(self, config: dict, prev: dict) -> str:
        changed = [k for k in config if config[k] != prev.get(k)]
        return "dfs" if any(k in self.model.dfs_scope for k in changed) else \
            "workload"

    def apply(self, config: dict, eval_run: bool = False) -> dict:
        if not self.param_space.validate(config):
            raise ValueError(f"invalid config {config}")
        action = self.param_space.to_action(config)
        self.model_state, vec, cost = self.model.step(
            self.model_state, action, eval_run=eval_run)
        if not eval_run:
            # Tuning applies accrue downtime until the loop reads it via
            # restart_cost(); evaluation runs are re-measurements, not
            # online config switches, and are never charged (same as the
            # host-loop tuner, which only calls restart_cost on tuning steps).
            self._pending_restart = (self._pending_restart or 0.0) + float(cost)
        self._last_scope = self._scope(config, self._last_config)
        self._last_config = dict(config)
        vec = np.asarray(vec)
        return {name: float(v) for name, v in zip(self.state_metrics, vec)}

    def apply_batch(self, configs: list, eval_run: bool = False) -> tuple:
        """N chained applies in one dispatch: (metric dicts, restart costs).

        Bitwise-equal to ``[self.apply(c) for c in configs]`` plus reading
        each apply's restart cost — the batch runs the same step body over
        the same key chain via ``lax.scan``. Used by the search baselines'
        probe batches. Leaves ``_pending_restart`` untouched: the per-config
        costs are returned directly."""
        if not configs:
            return [], np.zeros(0)
        for c in configs:
            if not self.param_space.validate(c):
                raise ValueError(f"invalid config {c}")
        actions = self.param_space.to_actions(configs)
        self.model_state, (vecs, costs) = _jit_step_scan(
            self.model.step_fn, eval_run)(self.model.params, self.model_state,
                                          actions)
        vecs = np.asarray(vecs)
        costs = np.asarray(costs, np.float64)
        prev = self._last_config
        for c, cost in zip(configs, costs):
            if not eval_run and cost > 0:
                self.restart_events.append((self._scope(c, prev), float(cost)))
            prev = c
        self._last_scope = self._scope(configs[-1], self._last_config)
        self._last_config = dict(configs[-1])
        metric_dicts = [
            {name: float(v) for name, v in zip(self.state_metrics, row)}
            for row in vecs]
        return metric_dicts, (costs if not eval_run else np.zeros(len(configs)))

    def restart_cost(self, config: dict, prev_config: dict) -> float:
        """Seconds of downtime for switching prev_config -> config.

        The Fig. 1 loop calls ``apply(config)`` then
        ``restart_cost(config, prev)`` once per step, so this returns exactly
        that step's restart seconds (drawn inside the pure step). Protocols
        that only ran evaluation applies (e.g. grid search's
        evaluate-then-account loop) accrue nothing in the step, so the cost
        is drawn host-side from the diff of the two configs — same §III-F
        ranges, separate RNG stream."""
        cost, self._pending_restart = self._pending_restart, None
        if cost is None:
            changed = [k for k in config or {}
                       if config[k] != (prev_config or {}).get(k)]
            if not changed:
                return 0.0
            cost = float(self._fallback_rng.uniform(12.0, 20.0))
            if any(k in self.model.dfs_scope for k in changed):
                cost += 30.0
            self._last_scope = self._scope(config, prev_config or {})
        if cost > 0:
            self.restart_events.append((self._last_scope, cost))
        return cost

    def restart_summary(self) -> dict:
        """{scope: {count, seconds}} over the adapter's lifetime."""
        out = {"workload": {"count": 0, "seconds": 0.0},
               "dfs": {"count": 0, "seconds": 0.0}}
        for scope, seconds in self.restart_events:
            out[scope]["count"] += 1
            out[scope]["seconds"] += seconds
        return out
