"""repro: Magpie (DDPG static-parameter auto-tuning) as a first-class feature of a
multi-pod JAX training/serving framework.

Subpackages
-----------
core        The paper's contribution: DDPG tuner, replay buffer, action mapping,
            scalarization, and the BestConfig baseline.
envs        Tuning environments: the calibrated Lustre/Filebench simulator (paper
            reproduction) and the sharding environment (the framework tuning itself).
models      Model substrate for the 10 assigned architectures.
kernels     Pallas TPU kernels (+ pure-jnp oracles) for the compute hot-spots.
sharding    Logical-axis sharding rules.
optim       AdamW / Adafactor / schedules (used by both the RL agent and LM training).
data        Deterministic sharded synthetic data pipeline.
checkpoint  Fault-tolerant checkpointing.
training    train_step / serve_step / trainer loop.
launch      Production mesh, multi-pod dry-run, end-to-end drivers.
roofline    Roofline-term extraction from compiled artifacts.
configs     One config per assigned architecture + the paper's Lustre tuning config.
"""

__version__ = "1.0.0"
