from repro.sharding.rules import (
    ShardingRules, TRAIN_RULES, SERVE_RULES, defs_to_pspecs, spec_for,
    batch_pspec, cache_pspecs,
)

__all__ = [
    "ShardingRules", "TRAIN_RULES", "SERVE_RULES", "defs_to_pspecs",
    "spec_for", "batch_pspec", "cache_pspecs",
]
