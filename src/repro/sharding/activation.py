"""Activation sharding constraints, threaded through the model code via a
context (the model modules know logical shapes, not mesh axes).

``activation_sharding(mesh, batch)`` selects the batch mesh axes once;
``constrain_batch(x)`` applies ``with_sharding_constraint(x, P(batch_axes,
None, ...))`` when a context is active and is a no-op otherwise (single-device
tests, plain eager use). This pins the batch dim of embeddings / layer-scan
carries so SPMD never falls back to batch-replicated activations (the
"involuntary full rematerialization" failure mode of sharded-table gathers).
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, PartitionSpec as P

_ctx = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_size: int, rules=None):
    from repro.sharding.rules import _axis_size, _cand_names
    if rules is None:
        from repro.sharding.rules import TRAIN_RULES as rules  # noqa: N813
    batch_axes, size = None, 1
    for cand in rules.candidates("batch"):
        names = _cand_names(cand)
        if (set(names) <= set(mesh.axis_names)
                and batch_size % _axis_size(mesh, cand) == 0):
            batch_axes = tuple(names)
            size = _axis_size(mesh, cand)
            break
    expert_axes, expert_size = None, 1
    for cand in rules.candidates("experts"):
        names = _cand_names(cand)
        if set(names) <= set(mesh.axis_names):
            expert_axes = tuple(names)
            expert_size = _axis_size(mesh, cand)
            break
    prev = (getattr(_ctx, "batch_axes", None), getattr(_ctx, "size", 1),
            getattr(_ctx, "expert_axes", None),
            getattr(_ctx, "expert_size", 1))
    _ctx.batch_axes, _ctx.size = batch_axes, size
    _ctx.expert_axes, _ctx.expert_size = expert_axes, expert_size
    try:
        yield
    finally:
        (_ctx.batch_axes, _ctx.size, _ctx.expert_axes,
         _ctx.expert_size) = prev


def constrain_batch(x):
    """Constrain dim 0 to the active batch axes (other dims unconstrained)."""
    axes = getattr(_ctx, "batch_axes", None)
    if axes is None or x is None:
        return x
    if x.ndim == 0 or x.shape[0] % getattr(_ctx, "size", 1) != 0:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_experts(x):
    """Constrain dim 0 to the expert mesh axes (MoE dispatch buffers) — this
    is what turns the token-dispatch into an all-to-all instead of an
    all-gather of every token on every device."""
    axes = getattr(_ctx, "expert_axes", None)
    if axes is None or x is None:
        return x
    if x.ndim == 0 or x.shape[0] % getattr(_ctx, "expert_size", 1) != 0:
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
