"""Logical-axis -> mesh-axis sharding rules (GSPMD / pjit).

One vocabulary of logical axes (models/base.py) and one rules table shard
every parameter, activation and cache of all 10 architectures. A rule maps a
logical axis to an ordered list of *candidates*; each candidate is a mesh
axis name or a tuple of names (sharded over their product). Assignment is
greedy per tensor: a candidate is taken iff its mesh axes exist, are not
already used by another dim of the same tensor, and divide the dim size —
otherwise the dim falls back to replication. This makes the same table valid
for the 16x16 pod mesh, the 2x16x16 multi-pod mesh, and tiny test meshes.

Two standard rule sets:
  TRAIN_RULES: TP over "model" (heads/mlp/vocab/expert_mlp), FSDP over
    ("pod","data") for embed + experts (params, grads and optimizer state all
    shard; GSPMD all-gathers weights per scan step) — the MaxText-style
    production default that makes 72B/480B-class optimizer states fit.
  SERVE_RULES: weights TP-only on "model" where they fit (no optimizer
    state), experts still over ("data","model"); the decode KV-cache shards
    its *sequence* dim over "model" (flash-decode style) because kv_heads
    (4..8) < 16 makes head sharding impossible, and batch over ("pod","data").
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.base import ParamDef


Candidate = object  # str | tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Mapping[str, tuple]

    def candidates(self, logical: str) -> tuple:
        return self.rules.get(logical, ())


TRAIN_RULES = ShardingRules(rules={
    # activations / inputs
    "batch": ((("pod", "data")), ("data",)),
    "seq": (),
    # params
    "embed": (("pod", "data"), "data"),        # FSDP
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "q_head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": (("pod", "data"), "data"),      # EP == FSDP axis for experts
    "expert_mlp": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "ssm_bc": (),
    "state": (),
    "conv": (),
    "q_lora": (),
    "kv_lora": (),
    # caches (unused in training)
    "cache_seq": ("model",),
    "enc_seq": (),
    "layers": (),
})

SERVE_RULES = ShardingRules(rules={
    "batch": ((("pod", "data")), ("data",)),
    "seq": (),
    "embed": (),                               # replicate: no optimizer state
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "q_head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("data",),                      # EP still needed at 480B
    "expert_mlp": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "ssm_bc": (),
    "state": (),
    "conv": (),
    "q_lora": (),
    "kv_lora": (),
    "cache_seq": ("model",),
    "enc_seq": (),
    "layers": (),
})


def _axis_size(mesh: Mesh, cand) -> int:
    names = (cand,) if isinstance(cand, str) else tuple(cand)
    size = 1
    for n in names:
        size *= dict(zip(mesh.axis_names, mesh.devices.shape))[n]
    return size


def _cand_names(cand) -> tuple:
    return (cand,) if isinstance(cand, str) else tuple(cand)


def spec_for(shape: Sequence[int], axes: Sequence[str], rules: ShardingRules,
             mesh: Mesh) -> P:
    """Greedy per-tensor assignment of mesh axes to dims."""
    mesh_names = set(mesh.axis_names)
    used: set = set()
    out = []
    for size, logical in zip(shape, axes):
        assigned = None
        for cand in rules.candidates(logical):
            names = _cand_names(cand)
            if not set(names) <= mesh_names:
                continue
            if set(names) & used:
                continue
            if size % _axis_size(mesh, cand) != 0:
                continue
            assigned = cand if isinstance(cand, str) else tuple(names)
            used |= set(names)
            break
        out.append(assigned)
    # trailing Nones can be dropped but keep explicit for readability
    return P(*out)


def defs_to_pspecs(defs, rules: ShardingRules, mesh: Mesh):
    """ParamDef pytree -> PartitionSpec pytree."""
    return jax.tree_util.tree_map(
        lambda d: spec_for(d.shape, d.axes, rules, mesh), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def defs_to_shardings(defs, rules: ShardingRules, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda d: NamedSharding(mesh, spec_for(d.shape, d.axes, rules, mesh)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def batch_pspec(mesh: Mesh, batch_size: int, extra_dims: int = 1,
                rules: ShardingRules = TRAIN_RULES) -> P:
    """[batch, ...] inputs: batch over ("pod","data") where divisible."""
    for cand in rules.candidates("batch"):
        names = _cand_names(cand)
        if (set(names) <= set(mesh.axis_names)
                and batch_size % _axis_size(mesh, cand) == 0):
            return P(tuple(names), *([None] * extra_dims))
    return P(*([None] * (extra_dims + 1)))


def cache_pspecs(cache_specs, rules: ShardingRules, mesh: Mesh):
    """Cache ParamDef pytree -> PartitionSpecs (same mechanism as params)."""
    return defs_to_pspecs(cache_specs, rules, mesh)
