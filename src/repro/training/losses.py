"""Loss functions (fp32 logsumexp regardless of logits dtype)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0) -> jnp.ndarray:
    """Mean next-token CE. logits [B,S,V] (any float dtype), labels [B,S].

    ``z_loss``: MaxText/PaLM-style logit-norm regularizer weight (stabilizes
    bf16 training of large-vocab heads)."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    # one-hot contraction, not take_along_axis: a gather over the
    # vocab-sharded logits would force SPMD to replicate them; the one-hot
    # einsum keeps the vocab dim sharded and reduces to [B,S] locally.
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.einsum("bsv,bsv->bs", logits32, onehot)
    loss = jnp.mean(lse - gold)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
