"""The training driver: checkpointed, preemption-safe, straggler-aware.

Fault-tolerance model (designed for 1000+ node jobs, exercised at CPU scale):
  * checkpoint/restart — atomic keep-k checkpoints every N steps
    (checkpoint/store.py); resume picks the latest intact checkpoint and the
    deterministic data pipeline skip-ahead regenerates exactly the batches a
    never-failed run would have seen.
  * preemption — SIGTERM/SIGINT installs a flag; the loop checkpoints at the
    next step boundary and exits cleanly (standard TPU-preemption protocol).
  * stragglers — a wall-clock watchdog tracks the rolling median step time;
    a step exceeding ``watchdog_factor`` x median is counted, and after
    ``watchdog_limit`` consecutive slow steps the trainer checkpoints and
    raises StragglerAbort — the cluster layer (launch script) restarts the
    job excluding the slow host. On a single process this demotes to
    detection + logging, which is what the unit tests exercise.
  * elastic re-scale — launch/elastic.py reshards the latest checkpoint onto
    a different mesh and the data pipeline re-shards by construction.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro import checkpoint as ckpt


class StragglerAbort(RuntimeError):
    pass


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    log_every: int = 10
    watchdog_factor: float = 3.0     # slow-step threshold (x median)
    watchdog_limit: int = 3          # consecutive slow steps before abort
    watchdog_warmup: int = 5         # steps before the watchdog arms


class Trainer:
    def __init__(self, train_step: Callable, pipeline, params, opt_state,
                 tcfg: TrainerConfig, to_batch: Optional[Callable] = None):
        self.train_step = train_step
        self.pipeline = pipeline
        self.params = params
        self.opt_state = opt_state
        self.tcfg = tcfg
        self.to_batch = to_batch or (lambda b: b)
        self.step = 0
        self.metrics_log: list = []
        self._step_times: list = []
        self._slow_streak = 0
        self._preempted = False
        self._orig_handlers: dict = {}

    # -- preemption -------------------------------------------------------

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._preempted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._orig_handlers[sig] = signal.signal(sig, handler)
            except ValueError:       # non-main thread (tests)
                pass

    def _restore_signal_handlers(self):
        for sig, h in self._orig_handlers.items():
            signal.signal(sig, h)

    # -- checkpointing ----------------------------------------------------

    def state_tree(self) -> dict:
        return {"params": self.params, "opt_state": self.opt_state}

    def save(self) -> Optional[str]:
        if not self.tcfg.checkpoint_dir:
            return None
        return ckpt.save_checkpoint(
            self.tcfg.checkpoint_dir, self.step, self.state_tree(),
            keep=self.tcfg.keep_checkpoints,
            extra={"metrics_tail": self.metrics_log[-1]
                   if self.metrics_log else {}})

    def try_resume(self) -> bool:
        if not self.tcfg.checkpoint_dir:
            return False
        latest = ckpt.latest_step(self.tcfg.checkpoint_dir)
        if latest is None:
            return False
        step, flat, _ = ckpt.restore_checkpoint(self.tcfg.checkpoint_dir,
                                                latest)
        restored = ckpt.restore_into(self.state_tree(), flat)
        self.params = restored["params"]
        self.opt_state = restored["opt_state"]
        self.step = step
        return True

    # -- watchdog ---------------------------------------------------------

    def _watchdog(self, dt: float) -> None:
        self._step_times.append(dt)
        if len(self._step_times) <= self.tcfg.watchdog_warmup:
            return
        median = statistics.median(self._step_times[:-1][-50:])
        if dt > self.tcfg.watchdog_factor * max(median, 1e-9):
            self._slow_streak += 1
            if self._slow_streak >= self.tcfg.watchdog_limit:
                self.save()
                raise StragglerAbort(
                    f"step {self.step}: {self._slow_streak} consecutive "
                    f"steps > {self.tcfg.watchdog_factor}x median "
                    f"({median:.3f}s) — checkpointed; restart excluding "
                    f"the straggling host")
        else:
            self._slow_streak = 0

    # -- main loop ----------------------------------------------------------

    def run(self) -> dict:
        self._install_signal_handlers()
        try:
            while self.step < self.tcfg.total_steps:
                t0 = time.perf_counter()
                batch = self.to_batch(self.pipeline.batch(self.step))
                self.params, self.opt_state, metrics = self.train_step(
                    self.params, self.opt_state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                self.step += 1
                metrics.update(step=self.step, seconds=dt)
                self.metrics_log.append(metrics)
                if self.step % self.tcfg.log_every == 0:
                    print(f"step {self.step}: loss={metrics['loss']:.4f} "
                          f"grad_norm={metrics['grad_norm']:.3f} "
                          f"({dt:.3f}s)", flush=True)
                if (self.tcfg.checkpoint_dir
                        and self.step % self.tcfg.checkpoint_every == 0):
                    self.save()
                if self._preempted:
                    self.save()
                    print(f"preempted at step {self.step}; checkpointed",
                          flush=True)
                    break
                self._watchdog(dt)
            else:
                if self.tcfg.checkpoint_dir:
                    self.save()
            return {"step": self.step, "metrics": self.metrics_log,
                    "preempted": self._preempted}
        finally:
            self._restore_signal_handlers()
