"""Gradient compression: top-k sparsification with error feedback.

Distributed-optimization trick for bandwidth-bound data parallelism: each
worker reduces only the top-k |g| entries per tensor and accumulates the
residual locally; error feedback keeps the method convergent (Karimireddy et
al., 2019). Two pieces:

1. ``topk_error_feedback``: a GradientTransformation that composes into the
   optimizer chain (sparsify + residual accumulation) — demonstrates the
   convergence behaviour and is what the trainer enables via config.
2. ``compress_and_pmean``: the per-leaf primitive to call *inside* a
   jax.shard_map'd DP step, pairing the sparsification with the
   cross-shard mean. On TPU a sparse all-reduce is executed as a dense
   masked all-reduce unless a custom collective is written; the production
   win comes from pairing with reduce-scatter over index-aligned blocks —
   trade-off documented in DESIGN.md.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.transform import GradientTransformation


def _compress_leaf(g, r, fraction: float):
    """Returns (sent, new_residual): top-|fraction| entries of g+r."""
    acc = g.astype(jnp.float32) + r
    flat = acc.reshape(-1)
    k = max(1, int(flat.size * fraction))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    sent = jnp.where(jnp.abs(acc) >= thresh, acc, 0.0)
    return sent.astype(g.dtype), acc - sent


class ErrorFeedbackState(NamedTuple):
    residual: Any


def topk_error_feedback(fraction: float = 0.01) -> GradientTransformation:
    """Keep the top-``fraction`` |values| per tensor; feed the rest back."""
    if not 0.0 < fraction <= 1.0:
        raise ValueError("fraction must be in (0, 1]")

    def init(params):
        return ErrorFeedbackState(residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(updates, state, params=None):
        del params
        pairs = jax.tree_util.tree_map(
            lambda g, r: _compress_leaf(g, r, fraction),
            updates, state.residual)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731
        sent = jax.tree_util.tree_map(lambda x: x[0], pairs, is_leaf=is_pair)
        resid = jax.tree_util.tree_map(lambda x: x[1], pairs, is_leaf=is_pair)
        return sent, ErrorFeedbackState(residual=resid)

    return GradientTransformation(init, update)


def compress_and_pmean(g, r, axis_name: str, fraction: float = 0.01):
    """Per-leaf: sparsify (with residual r) then pmean over ``axis_name``.
    Call inside shard_map/pmap on the DP axis. Returns (reduced, new_r)."""
    sent, new_r = _compress_leaf(g, r, fraction)
    return jax.lax.pmean(sent, axis_name), new_r
