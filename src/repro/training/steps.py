"""jit-able step builders: train_step (grad accumulation via lax.scan over
microbatches, remat policies, optional gradient compression) and the serving
steps (prefill / decode). These are what launch/dryrun.py lowers and what the
trainer/server drivers execute.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.models import forward, prefill as model_prefill, decode_step as \
    model_decode_step
from repro.models.base import ArchConfig
from repro.training.losses import cross_entropy


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Static training-step parameters (the 'static parameters' the Magpie
    sharding environment tunes — changing any of these forces a recompile,
    the distributed-training analogue of the paper's restart cost)."""
    microbatches: int = 1          # gradient-accumulation splits
    remat: str = "none"            # none | dots | full
    attn_impl: str = "auto"        # ref | chunked | auto
    scan_unroll: int = 1           # layer-scan unroll factor
    gather_weights_once: bool = False  # hoist FSDP all-gather out of the
                                   # microbatch loop (see launch/cells.py)
    aux_weight: float = 0.01       # MoE load-balance loss weight
    z_loss: float = 0.0
    clip_norm: float = 1.0


def make_train_step(cfg: ArchConfig, tx: optim.GradientTransformation,
                    tc: TrainConfig = TrainConfig()) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``batch``: {"tokens","labels"[,"positions","input_embeds"]}."""

    def loss_fn(params, tokens, labels, positions, input_embeds):
        logits, aux = forward(cfg, params, tokens, positions=positions,
                              input_embeds=input_embeds,
                              attn_impl=tc.attn_impl, remat=tc.remat,
                              unroll=tc.scan_unroll)
        loss = cross_entropy(logits, labels, z_loss=tc.z_loss)
        return loss + tc.aux_weight * aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        positions = batch.get("positions")
        input_embeds = batch.get("input_embeds")

        if tc.microbatches <= 1:
            (_, (loss, aux)), grads = grad_fn(params, tokens, labels,
                                              positions, input_embeds)
        else:
            m = tc.microbatches
            B = tokens.shape[0]
            assert B % m == 0, (B, m)

            def split(x):
                return (None if x is None
                        else x.reshape((m, B // m) + x.shape[1:]))

            mb = jax.tree_util.tree_map(
                split, (tokens, labels, positions, input_embeds),
                is_leaf=lambda x: x is None)

            def acc_fn(carry, xs):
                g_acc, loss_acc, aux_acc = carry
                tok, lab, pos, emb = xs
                (_, (l, a)), g = grad_fn(params, tok, lab, pos, emb)
                g_acc = jax.tree_util.tree_map(
                    lambda x, y: x + y.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + l, aux_acc + a), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32),
                         jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            loss, aux = loss / m, aux / m

        grad_norm = optim.global_norm(grads)
        if tc.clip_norm:
            factor = jnp.minimum(1.0, tc.clip_norm / (grad_norm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * factor, grads)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": grad_norm}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, batch: int, max_seq: int,
                      attn_impl: str = "auto") -> Callable:
    """prefill_step(params, tokens[, positions, input_embeds]) ->
    (logits, cache). The cache is built inside (zeros) so the step is a pure
    function of params+prompt."""
    from repro.models import make_cache

    def prefill_step(params, tokens, positions=None, input_embeds=None):
        cache = make_cache(cfg, batch, max_seq)
        return model_prefill(cfg, params, tokens, cache, positions=positions,
                             input_embeds=input_embeds, attn_impl=attn_impl)

    return prefill_step


def make_decode_step(cfg: ArchConfig) -> Callable:
    """decode_step(params, tokens [B,1], cache, cache_index) ->
    (logits, new_cache). This is `serve_step` for the decode_* shape cells."""
    def decode(params, tokens, cache, cache_index):
        return model_decode_step(cfg, params, tokens, cache, cache_index)
    return decode
