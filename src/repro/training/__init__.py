from repro.training.losses import cross_entropy
from repro.training.steps import (
    TrainConfig, make_train_step, make_prefill_step, make_decode_step,
)
from repro.training.trainer import Trainer, TrainerConfig

__all__ = [
    "cross_entropy", "TrainConfig", "make_train_step", "make_prefill_step",
    "make_decode_step", "Trainer", "TrainerConfig",
]
