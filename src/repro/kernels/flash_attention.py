"""Blocked causal GQA flash attention — Pallas TPU kernel (fwd + bwd).

VMEM tiling: q/k/v blocks (block_q|block_k, head_dim) with fp32 accumulators;
online-softmax running (m, l) scratch persists across the kv grid dimension
(TPU grids iterate sequentially, minor-most fastest, so accumulating across
the last grid dim into revisited output blocks is legal).

Backward is the standard two-kernel flash bwd: dq accumulates over kv blocks;
dk/dv accumulate over (group-head, q-block) pairs — the GQA group dim is
pre-folded into the fastest grid dim so each dk/dv output block is visited in
consecutive grid steps only.

Layouts: q [B, H, S, D]; k/v [B, Kv, S, D]; H = g * Kv.
Validated against kernels.ref.attention_ref in interpret mode (CPU); the TPU
path is selected by kernels.ops when jax.default_backend() == 'tpu'.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK = 128


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                scale, block_q, block_k, causal):
    i, j = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(jnp.isfinite(m_new)[:, None],
                      jnp.exp(s - safe_m[:, None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev),
                          jnp.exp(m_prev - safe_m), 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:  # statically skip blocks strictly above the diagonal
        pl.when(j * block_k <= i * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _fin():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(jnp.isfinite(m_ref[...]),
                                  m_ref[...] + jnp.log(l), 0.0)


def _flash_fwd(q, k, v, *, causal, block_q, block_k, interpret):
    B, H, S, D = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(D)
    nq, nk = S // block_q, Sk // block_k
    grid = (B, H, nq, nk)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, block_q, block_k, causal):
    i, j = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        p = jnp.exp(s - lse_ref[0, 0][:, None])            # (bq, bk)
        dp = jnp.dot(do_ref[0, 0].astype(jnp.float32),
                     v_ref[0, 0].astype(jnp.float32).T,
                     preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0][:, None])
        acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    if causal:
        pl.when(j * block_k <= i * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(j == nk - 1)
    def _fin():
        dq_ref[0, 0] = (acc_ref[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, do_ref, lse_ref, delta_ref, k_ref, v_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                scale, block_q, block_k, causal, nq):
    j, t = pl.program_id(2), pl.program_id(3)  # kv block, (g, qblock) folded
    nt = pl.num_programs(3)
    i = t % nq                                             # q block index

    @pl.when(t == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                 # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            qpos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, -jnp.inf)
        p = jnp.exp(s - lse_ref[0, 0, 0][:, None])
        do = do_ref[0, 0, 0].astype(jnp.float32)
        dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v_ref[0, 0].astype(jnp.float32).T,
                     preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0, 0][:, None])
        dk_acc[...] += jnp.dot(ds.T, q / scale,
                               preferred_element_type=jnp.float32) * scale

    if causal:
        pl.when(j * block_k <= i * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(t == nt - 1)
    def _fin():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_bwd(res, dout, *, causal, block_q, block_k, interpret):
    q, k, v, out, lse = res
    B, H, S, D = q.shape
    Kv, Sk = k.shape[1], k.shape[2]
    g = H // Kv
    scale = 1.0 / math.sqrt(D)
    nq, nk = S // block_q, Sk // block_k
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                               # [B, H, S]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    # group-major views so (g, q-block) folds into ONE fastest grid dim
    qg = q.reshape(B, Kv, g, S, D)
    dog = dout.reshape(B, Kv, g, S, D)
    lseg = lse.reshape(B, Kv, g, S)
    deltag = delta.reshape(B, Kv, g, S)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal, nq=nq),
        grid=(B, Kv, nk, g * nq),
        in_specs=[
            pl.BlockSpec((1, 1, 1, block_q, D),
                         lambda b, kv, j, t: (b, kv, t // nq, t % nq, 0)),
            pl.BlockSpec((1, 1, 1, block_q, D),
                         lambda b, kv, j, t: (b, kv, t // nq, t % nq, 0)),
            pl.BlockSpec((1, 1, 1, block_q),
                         lambda b, kv, j, t: (b, kv, t // nq, t % nq)),
            pl.BlockSpec((1, 1, 1, block_q),
                         lambda b, kv, j, t: (b, kv, t // nq, t % nq)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, kv, j, t: (b, kv, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, kv, j, t: (b, kv, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda b, kv, j, t: (b, kv, j, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, kv, j, t: (b, kv, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, dog, lseg, deltag, k, v)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public entry (custom VJP)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK, block_k: int = DEFAULT_BLOCK,
                    interpret: bool = False):
    """q [B,H,S,D]; k/v [B,Kv,S,D] -> [B,H,S,D]. S divisible by blocks."""
    out, _ = _flash_fwd(q, k, v, causal=causal, block_q=block_q,
                        block_k=block_k, interpret=interpret)
    return out


def _vjp_fwd(q, k, v, causal, block_q, block_k, interpret):
    out, lse = _flash_fwd(q, k, v, causal=causal, block_q=block_q,
                          block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, block_q, block_k, interpret, res, dout):
    return _flash_bwd(res, dout, causal=causal, block_q=block_q,
                      block_k=block_k, interpret=interpret)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)
