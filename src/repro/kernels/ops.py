"""Kernel dispatch layer: every hot spot has a Pallas TPU kernel and a pure-XLA
fallback; selection is automatic (TPU backend -> kernel) and overridable.

    REPRO_KERNELS=xla        force the XLA (jnp) paths everywhere
    REPRO_KERNELS=pallas     force the Pallas kernels (compiled)
    REPRO_KERNELS=interpret  force the Pallas kernels in interpret mode (CPU
                             correctness testing — this is what the test
                             sweeps use)

The dry-run/roofline pipeline runs on the CPU backend and therefore measures
the XLA paths; that is the honest choice — cost_analysis of an opaque custom
call would count zero FLOPs for exactly the ops we care about.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.ddpg_fused import ddpg_fused_learn as _ddpg_fused_learn
from repro.kernels.ddpg_fused import ddpg_fused_xla as _ddpg_fused_xla
from repro.kernels.episode_fused import episode_fused_learn as _episode_learn
from repro.kernels.episode_fused import episode_fused_xla as _episode_xla
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.gmm import gmm as _gmm
from repro.kernels.mamba2_scan import ssd_scan as _ssd_scan
from repro.kernels.rwkv6 import wkv6_scan as _wkv6_scan


def _mode() -> str:
    m = os.environ.get("REPRO_KERNELS", "auto")
    if m == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return m


# ---------------------------------------------------------------------------
# Fused DDPG inner loop (the tuning hot path — paper Table III)
# ---------------------------------------------------------------------------

def ddpg_kernel_mode():
    """'pallas' / 'interpret' when the fused DDPG learner kernel is active,
    ``None`` when the XLA fallback should run. ``core.ddpg._learn_scan``
    consults this before packing parameters for the kernel."""
    m = _mode()
    return m if m in ("pallas", "interpret") else None


def ddpg_inner_loop(packed, batches, *, dims, gamma, tau, actor_lr,
                    critic_lr, mode=None):
    """Whole ``updates_per_step`` DDPG inner loop on the packed layout.

    Pallas kernel (params resident in VMEM across all updates, grid over the
    fleet session axis) under ``pallas``/``interpret``; otherwise the XLA
    twin of the same blocked computation (``ddpg_fused_xla``). Inputs follow
    ``kernels.ddpg_fused.pack_params`` / ``pack_minibatches``, every array
    carrying a leading fleet axis.

    ``mode`` defaults to the ``REPRO_KERNELS`` resolution — but callers that
    sit inside a jit trace must resolve ``ddpg_kernel_mode()`` on the host
    and pass it explicitly (a cached compilation would otherwise pin the
    first call's mode forever; ``core.ddpg`` threads it as a static operand).
    """
    mode = _mode() if mode is None else mode
    if mode in ("pallas", "interpret"):
        return _ddpg_fused_learn(
            packed, batches, dims=dims, gamma=gamma, tau=tau,
            actor_lr=actor_lr, critic_lr=critic_lr,
            interpret=mode == "interpret")
    return _ddpg_fused_xla(packed, batches, dims=dims, gamma=gamma, tau=tau,
                           actor_lr=actor_lr, critic_lr=critic_lr)


# ---------------------------------------------------------------------------
# Whole-episode megakernel (act -> env -> reward -> store -> inner loop)
# ---------------------------------------------------------------------------

_MEGAKERNEL_MODES = ("xla", "pallas", "interpret")


def episode_kernel_mode():
    """Resolve ``REPRO_MEGAKERNEL``: ``None`` (unset/``off``/``0``/``none``)
    keeps the standard scan engine — ``core.episode._compiled_episode`` keys
    on this value, so ``None`` compiles the exact pre-megakernel program.
    ``xla``/``pallas``/``interpret`` select the whole-episode fused
    formulation; ``auto`` means the Pallas kernel on TPU and the XLA twin
    elsewhere. Host-resolved only — never call this inside a jit trace."""
    m = os.environ.get("REPRO_MEGAKERNEL", "off").strip().lower()
    if m in ("", "off", "0", "none"):
        return None
    if m == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if m not in _MEGAKERNEL_MODES:
        raise ValueError(
            f"REPRO_MEGAKERNEL={m!r}: expected one of "
            f"{('off', 'auto') + _MEGAKERNEL_MODES}")
    return m


def episode_inner_loop(operands, *, spec, mode=None):
    """Whole chunk of T-step episodes in one fused program.

    ``pallas``/``interpret`` run the megakernel (one grid instance per
    session, every stateful operand VMEM-resident and aliased across the
    call); ``xla`` runs the identical per-session body vmapped. Inputs
    follow ``kernels.episode_fused.EpisodeOperands``; like
    ``ddpg_inner_loop``, jit-traced callers must resolve the mode on the
    host and pass it explicitly."""
    mode = episode_kernel_mode() if mode is None else mode
    if mode in ("pallas", "interpret"):
        return _episode_learn(operands, spec=spec,
                              interpret=mode == "interpret")
    return _episode_xla(operands, spec=spec)


# ---------------------------------------------------------------------------
# Flash attention: q [B,S,H,D]; k/v [B,S,Kv,D] (model-layout) -> [B,S,H,D]
# ---------------------------------------------------------------------------

def attention(q, k, v, causal: bool = True):
    mode = _mode()
    S = q.shape[1]
    usable = S % 128 == 0 and k.shape[1] % 128 == 0 and q.shape[-1] >= 8
    if mode in ("pallas", "interpret") and usable:
        qt = jnp.swapaxes(q, 1, 2)      # [B,H,S,D]
        kt = jnp.swapaxes(k, 1, 2)
        vt = jnp.swapaxes(v, 1, 2)
        o = _flash(qt, kt, vt, causal, 128, 128, mode == "interpret")
        return jnp.swapaxes(o, 1, 2)
    from repro.models.attention import sdpa
    Sq, Sk = q.shape[1], k.shape[1]
    impl = "chunked" if (Sq * Sk > 4096 * 4096 and Sq % 512 == 0
                         and Sk % 512 == 0) else "ref"
    return sdpa(q, k, v, causal=causal, impl=impl)


# ---------------------------------------------------------------------------
# Mamba2 SSD: x [b,s,h,p], dt [b,s,h], A [h], Bm/Cm [b,s,n]
# ---------------------------------------------------------------------------

def ssd(x, dt, A, Bm, Cm, chunk: int):
    mode = _mode()
    b, s, h, p = x.shape
    if mode in ("pallas", "interpret") and s % chunk == 0:
        xf = jnp.swapaxes(x, 1, 2).reshape(b * h, s, p)
        dtf = jnp.swapaxes(dt, 1, 2).reshape(b * h, s)
        Af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h)
        y, state = _ssd_scan(xf, dtf, Af, Bm, Cm, heads=h, chunk=chunk,
                             interpret=mode == "interpret")
        y = jnp.swapaxes(y.reshape(b, h, s, p), 1, 2)
        n = Bm.shape[-1]
        return y, state.reshape(b, h, n, p)
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)


# ---------------------------------------------------------------------------
# RWKV6 WKV: r/k/v/logw [B,S,H,c], u [H,c]
# ---------------------------------------------------------------------------

def wkv6(r, k, v, logw, u, chunk: int = 64):
    mode = _mode()
    B, S, H, c = r.shape
    if mode in ("pallas", "interpret") and S % chunk == 0:
        def fold(t):
            return jnp.swapaxes(t, 1, 2).reshape(B * H, S, c)
        uf = jnp.broadcast_to(u[None], (B, H, c)).reshape(B * H, c)
        y, state = _wkv6_scan(fold(r), fold(k), fold(v), fold(logw), uf,
                              chunk=chunk, interpret=mode == "interpret")
        y = jnp.swapaxes(y.reshape(B, H, S, c), 1, 2)
        return y, state.reshape(B, H, c, c)
    from repro.models.rwkv import wkv_chunked
    return wkv_chunked(r, k, v, logw, u, min(32, S))


# ---------------------------------------------------------------------------
# Grouped matmul / grouped SwiGLU (MoE experts)
# ---------------------------------------------------------------------------

def grouped_matmul(x, w):
    mode = _mode()
    E, C, D = x.shape
    F = w.shape[-1]
    aligned = C % 128 == 0 and D % 128 == 0 and F % 128 == 0
    if mode in ("pallas", "interpret") and aligned:
        return _gmm(x, w, interpret=mode == "interpret")
    return jnp.einsum("ecd,edf->ecf", x, w)


def grouped_swiglu(x, w_gate, w_up, w_down):
    """[E,C,D] -> [E,C,D]: the MoE expert-FFN hot spot."""
    g = jax.nn.silu(grouped_matmul(x, w_gate))
    u = grouped_matmul(x, w_up)
    return grouped_matmul(g * u, w_down)
