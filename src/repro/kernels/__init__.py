# Pallas TPU kernels for the compute hot spots: ddpg_fused (the paper's
# Table III inner loop — 96 DDPG updates with params resident in VMEM,
# gridded over fleet sessions), flash_attention (fwd+bwd), mamba2_scan
# (chunked SSD), rwkv6 (chunked WKV), gmm (grouped matmul).
# ref.py holds the pure-jnp oracles; ops.py is the dispatch layer
# (Pallas on TPU / XLA fallback on CPU; REPRO_KERNELS=interpret for tests).
